#!/usr/bin/env python
"""Docs consistency gate (CI `docs` job).

Three checks, all cheap and dependency-free:

  1. Internal markdown links in README.md / DESIGN.md / ROADMAP.md resolve to
     files that exist in the repo (http(s) links are skipped; #anchors are
     stripped before the existence check).
  2. Every `DESIGN.md §X` citation in Python docstrings/comments (src/, tests/,
     benchmarks/, examples/) and in README.md names a section that actually
     exists as a `## §X` / `### §X` header in DESIGN.md — and is not reserved.
     DESIGN.md's preamble promises stable section numbers; this keeps the code
     honest about it.
  3. CLI flag drift: every argparse flag of `src/repro/launch/serve.py` must
     be mentioned in README.md, and every `--flag` token README mentions must
     exist in some argparse definition under FLAG_SOURCE_GLOBS
     (src/repro/launch/, benchmarks/, experiments/, tools/) — so the serving
     docs can't silently fall behind the code (or vice versa).

Exit status 0 = clean; 1 = problems (each printed on its own line).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MD_FILES = ["README.md", "DESIGN.md", "ROADMAP.md"]
CODE_DIRS = ["src", "tests", "benchmarks", "examples", "tools"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADER_RE = re.compile(r"^#{2,3}\s+(§\S+)(.*)$", re.M)
CITE_RE = re.compile(r"DESIGN\.md(?:\s+)?([^\n]*)")
SECTION_TOKEN_RE = re.compile(r"§([A-Za-z][\w]*|[\d.]+)")


def design_sections(design_text: str) -> tuple[set[str], set[str]]:
    """-> (citable section names, reserved section names), '§' stripped.

    A header like '## §6–§7 (reserved)' defines 6 and 7, both reserved.
    Subsections (### §5.4) are citable; so are word sections (§Serving).
    """
    citable, reserved = set(), set()
    for m in HEADER_RE.finditer(design_text):
        head, rest = m.group(1), m.group(2)
        names = [t.rstrip(".") for t in SECTION_TOKEN_RE.findall(head + rest.split("\n")[0])]
        is_reserved = "reserved" in (head + rest).lower()
        # expand ranges like §6–§7
        if len(names) == 2 and all(n.isdigit() for n in names) and ("–" in head or "-" in head):
            names = [str(i) for i in range(int(names[0]), int(names[1]) + 1)]
        for n in names:
            (reserved if is_reserved else citable).add(n)
    return citable, reserved


def check_links() -> list[str]:
    errors = []
    for md in MD_FILES:
        path = ROOT / md
        if not path.exists():
            errors.append(f"{md}: file missing")
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                rel = target.split("#")[0]
                if not rel:  # pure-anchor link into the same file
                    continue
                if not (ROOT / rel).exists():
                    errors.append(f"{md}:{i}: broken link -> {target}")
    return errors


def check_design_citations() -> list[str]:
    design = (ROOT / "DESIGN.md").read_text()
    citable, reserved = design_sections(design)
    errors = []
    files = [ROOT / "README.md"]
    for d in CODE_DIRS:
        files.extend(sorted((ROOT / d).rglob("*.py")))
    for path in files:
        if path == Path(__file__).resolve():
            continue
        text = path.read_text()
        for i, line in enumerate(text.splitlines(), 1):
            for tail in CITE_RE.findall(line):
                for name in SECTION_TOKEN_RE.findall(tail):
                    name = name.rstrip(".")
                    if name in citable:
                        continue
                    rel = path.relative_to(ROOT)
                    if name in reserved:
                        errors.append(f"{rel}:{i}: cites reserved DESIGN.md §{name}")
                    else:
                        errors.append(f"{rel}:{i}: cites missing DESIGN.md §{name}")
    return errors


ARGPARSE_FLAG_RE = re.compile(r"""add_argument\(\s*["'](--[A-Za-z][\w-]*)["']""")
# a flag token in prose/code blocks: "--" + letter start, not the "---" rule
README_FLAG_RE = re.compile(r"(?<![\w-])--[A-Za-z][\w-]*")
# CLI-bearing sources whose flags README may legitimately mention
FLAG_SOURCE_GLOBS = ["src/repro/launch/*.py", "benchmarks/*.py", "experiments/*.py", "tools/*.py"]
ALWAYS_KNOWN_FLAGS = {
    "--help",  # argparse built-in
    # XLA runtime flag (an XLA_FLAGS env-var value, not a CLI flag): README's
    # dp/tp example must stay copy-pasteable on a single-CPU box
    "--xla_force_host_platform_device_count",
}


def argparse_flags(path: Path) -> set[str]:
    return set(ARGPARSE_FLAG_RE.findall(path.read_text()))


def check_cli_flags() -> list[str]:
    """launch/serve.py flags <-> README, both directions (docs-drift gate)."""
    readme = (ROOT / "README.md").read_text()
    readme_flags = set(README_FLAG_RE.findall(readme))
    serve = ROOT / "src" / "repro" / "launch" / "serve.py"
    errors = []
    for flag in sorted(argparse_flags(serve)):
        if flag not in readme_flags:
            errors.append(f"README.md: serving flag {flag} ({serve.relative_to(ROOT)}) is undocumented")
    known = set(ALWAYS_KNOWN_FLAGS)
    for pattern in FLAG_SOURCE_GLOBS:
        for path in ROOT.glob(pattern):
            known |= argparse_flags(path)
    for flag in sorted(readme_flags - known):
        errors.append(
            f"README.md: mentions flag {flag}, which no CLI under {', '.join(FLAG_SOURCE_GLOBS)} defines"
        )
    return errors


def main() -> int:
    errors = check_links() + check_design_citations() + check_cli_flags()
    for e in errors:
        print(e)
    if errors:
        print(f"FAIL: {len(errors)} docs problem(s)")
        return 1
    print("docs OK: links resolve, DESIGN.md § citations exist, README and serve flags agree")
    return 0


if __name__ == "__main__":
    sys.exit(main())
