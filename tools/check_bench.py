#!/usr/bin/env python
"""Bench-regression gate (CI `bench-serving` job).

Compares a fresh `benchmarks/bench_serving.py --json / --micro-json` run
against the committed snapshot `BENCH_baseline.json`, so a perf or parity
regression fails the PR instead of silently shipping.

Absolute wall-clock numbers measured on the dev machine do not transfer to
CI runners (different CPUs, shared tenancy), so the gated timing metrics
are *within-run ratios* — both sides of each ratio come from the same
process on the same machine, so runner hardware cancels and the committed
baseline stays meaningful anywhere. The raw absolutes ride in the JSON as
informational context. Per-metric rules:

  * relative throughput (EXAQ engine tok/s over the same run's
    exact-softmax engine) may dip at most `--tolerance` (default 20%)
    below baseline; improvements always pass.
  * relative latency (fused kernel step/chunk time over the same run's
    gather path) is *informational only*: on CPU the fused kernels run
    interpret-mode Pallas against a native-XLA gather, so the ratio
    measures the interpreter, not the kernel, and its run-to-run noise
    (~2x absolute) repeatedly tripped the gate on healthy runs. The
    ratios are still derived and printed for trend-watching; the
    modeled-bytes ratios below are the gated perf claims.
  * parity, hit-rate, agreement, occupancy, and modeled-bytes-ratio
    metrics are exact-or-better: they are deterministic given the pinned
    seed/toolchain, so any dip is a real regression. This includes the
    data-parallel fleet metrics (`serving.dp.*`): replica dispatch is
    deterministic, so the aggregated hit rate and occupancy are too.
  * the bursty-trace latency metrics (`serving.bursty.*_steps`) are
    exact-or-lower ("ceiling"): TTFT and inter-token latency are measured
    in deterministic scheduler ticks, not wall clock, so any rise is a
    real scheduling regression, and improvements always pass.
  * the speculative-decoding metrics (`serving.spec.*`) are deterministic
    too — greedy accept/reject over seeded drafts — so vanilla parity is a
    "bool" gate and the accepted-per-verify / steps-per-token-reduction
    speedup counters are exact-or-better floors.
  * the per-architecture StatePool metrics (`serving.state_archs.*`) gate
    paged-vs-unpaged greedy parity as "bool" per served config (mamba2 /
    moe / hybrid) with occupancy and hit-rate floors — all deterministic
    given the pinned seed.

Metrics in the baseline that no rule matches are informational. Metrics the
rules match that *disappear* from a fresh run fail (a silently dropped
assertion is itself a regression). After an intentional perf change,
regenerate the snapshot with `--update`.

Usage (what CI runs):

    python benchmarks/bench_serving.py --json bench_serving.json \
        --micro-json bench_paged_decode.json
    python tools/check_bench.py --serving bench_serving.json \
        --micro bench_paged_decode.json

Exit status 0 = within tolerance; 1 = regression(s), each printed.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "BENCH_baseline.json"

# machine-portable timing ratios derived at check time from each run's own
# raw numbers: (derived path, numerator path, denominator path)
DERIVED = [
    ("serving.impls.exaq-int2.tok_per_s_rel_exact",
     "serving.impls.exaq-int2.tok_per_s", "serving.impls.exact.tok_per_s"),
    ("serving.impls.exaq-int3.tok_per_s_rel_exact",
     "serving.impls.exaq-int3.tok_per_s", "serving.impls.exact.tok_per_s"),
    ("micro.fused_over_gather_step_ms", "micro.fused_step_ms", "micro.gather_step_ms"),
    ("micro.fused_int8_over_gather_step_ms", "micro.fused_int8_step_ms", "micro.gather_step_ms"),
    ("micro.fused_int4_over_gather_step_ms", "micro.fused_int4_step_ms", "micro.gather_step_ms"),
    ("micro.prefill.fused_over_gather_chunk_ms",
     "micro.prefill.fused_chunk_ms", "micro.prefill.gather_chunk_ms"),
    ("micro.prefill.fused_int8_over_gather_chunk_ms",
     "micro.prefill.fused_int8_chunk_ms", "micro.prefill.gather_chunk_ms"),
    ("micro.prefill.fused_int4_over_gather_chunk_ms",
     "micro.prefill.fused_int4_chunk_ms", "micro.prefill.gather_chunk_ms"),
]

# (dotted-path pattern, rule). Rules: "higher" / "lower" are ratio-tolerant
# in one direction; "floor" is exact-or-better; "ceiling" is exact-or-lower
# (deterministic step-clocked latencies); "bool" must stay truthy.
SPEC = [
    ("serving.impls.*.tok_per_s_rel_exact", "higher"),
    ("serving.impls.*.agreement_vs_exact", "floor"),
    ("serving.paged.*.prefix_hit_rate", "floor"),
    ("serving.paged.*.greedy_parity_vs_slot", "bool"),
    ("serving.kv_dtype.agreement_int8_vs_fp32", "floor"),
    ("serving.kv_dtype.agreement_int4_vs_fp32", "floor"),
    ("serving.kv_dtype.pool_shrink_x", "floor"),
    ("serving.kv_dtype.pool_shrink_int4_x", "floor"),
    ("serving.kv_dtype.int4_vs_int8_pool_x", "floor"),
    ("micro.bytes_reduction_x", "floor"),
    ("micro.int8_vs_bf16_bytes_reduction_x", "floor"),
    ("micro.int4_vs_int8_bytes_reduction_x", "floor"),
    ("micro.int4_vs_bf16_bytes_reduction_x", "floor"),
    ("micro.prefill.bytes_reduction_x", "floor"),
    ("micro.prefill.int8_vs_bf16_bytes_reduction_x", "floor"),
    ("micro.prefill.int4_vs_int8_bytes_reduction_x", "floor"),
    ("micro.prefill.int4_vs_bf16_bytes_reduction_x", "floor"),
    ("serving.dp.greedy_parity_vs_single", "bool"),
    ("serving.dp.aggregate.prefix_hit_rate", "floor"),
    ("serving.dp.aggregate.mean_occupancy", "floor"),
    ("serving.bursty.p50_ttft_steps", "ceiling"),
    ("serving.bursty.p99_ttft_steps", "ceiling"),
    ("serving.bursty.p50_itl_steps", "ceiling"),
    ("serving.bursty.p99_itl_steps", "ceiling"),
    ("serving.bursty.overload.completed", "floor"),
    ("serving.bursty.overload.all_shed_retryable", "bool"),
    ("serving.spec.greedy_parity_vs_vanilla", "bool"),
    ("serving.spec.accepted_per_verify", "floor"),
    ("serving.spec.steps_per_token_reduction_x", "floor"),
    ("serving.state_archs.*.greedy_parity_vs_unpaged", "bool"),
    ("serving.state_archs.*.mean_occupancy", "floor"),
    ("serving.state_archs.*.prefix_hit_rate", "floor"),
]
FLOOR_EPS = 1e-9  # fp-serialization slack for the exact-or-better rules

# derived wall-clock ratios reported but NOT gated: interpret-mode Pallas vs
# native-XLA timings on CI runners measure the interpreter, not the kernel
INFORMATIONAL = [
    "micro.*_over_gather_step_ms",
    "micro.prefill.*_over_gather_chunk_ms",
]


def flatten(obj, prefix=""):
    """Nested dicts -> {dotted.path: leaf}; lists stay leaves."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = obj
    return out


def rule_for(path: str) -> str | None:
    for pattern, rule in SPEC:
        if fnmatch.fnmatch(path, pattern):
            return rule
    return None


def derive(flat: dict) -> dict:
    """Augment a flattened report with the DERIVED within-run ratios."""
    out = dict(flat)
    for name, num, den in DERIVED:
        if num in flat and den in flat and float(flat[den]) != 0.0:
            out[name] = float(flat[num]) / float(flat[den])
    return out


def compare(
    baseline: dict, fresh: dict, tolerance: float, latency_tolerance: float | None = None
) -> tuple[list[str], list[str]]:
    """-> (failures, notes). Both inputs are {"serving": ..., "micro": ...}."""
    lat_tol = tolerance if latency_tolerance is None else latency_tolerance
    base_flat = derive(flatten(baseline))
    fresh_flat = derive(flatten(fresh))
    failures, notes = [], []
    for path, base in sorted(base_flat.items()):
        rule = rule_for(path)
        if rule is None:
            continue
        if path not in fresh_flat:
            failures.append(f"{path}: gated metric missing from the fresh run")
            continue
        new = fresh_flat[path]
        if rule == "bool":
            if bool(base) and not bool(new):
                failures.append(f"{path}: was {base!r}, now {new!r}")
            continue
        base_f, new_f = float(base), float(new)
        if rule == "higher" and new_f < base_f * (1.0 - tolerance):
            failures.append(f"{path}: {new_f:.4g} fell >{tolerance:.0%} below baseline {base_f:.4g}")
        elif rule == "lower" and new_f > base_f * (1.0 + lat_tol):
            failures.append(f"{path}: {new_f:.4g} rose >{lat_tol:.0%} above baseline {base_f:.4g}")
        elif rule == "floor" and new_f < base_f - FLOOR_EPS:
            failures.append(f"{path}: {new_f:.6g} regressed below baseline {base_f:.6g}")
        elif rule == "ceiling" and new_f > base_f + FLOOR_EPS:
            failures.append(f"{path}: {new_f:.6g} rose above baseline {base_f:.6g}")
    for path in sorted(set(fresh_flat) - set(base_flat)):
        if rule_for(path) is not None:
            notes.append(f"{path}: new gated metric not in baseline — refresh it with --update")
    for path in sorted(fresh_flat):
        if any(fnmatch.fnmatch(path, pat) for pat in INFORMATIONAL):
            base_txt = f" (baseline {float(base_flat[path]):.3g})" if path in base_flat else ""
            notes.append(f"informational, not gated: {path} = {float(fresh_flat[path]):.3g}{base_txt}")
    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--serving", required=True, help="fresh bench_serving --json output")
    ap.add_argument("--micro", required=True, help="fresh bench_serving --micro-json output")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.2,
        help="allowed one-sided drift for throughput metrics (default 0.20)",
    )
    ap.add_argument(
        "--latency-tolerance",
        type=float,
        default=None,
        help="accepted for compatibility; wall-clock latency ratios are "
        "informational (printed, never gated) since interpret-mode timings "
        "measure the Pallas interpreter, not the kernel",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the fresh run instead of checking against it",
    )
    args = ap.parse_args()

    fresh = {
        "serving": json.loads(Path(args.serving).read_text()),
        "micro": json.loads(Path(args.micro).read_text()),
    }
    if args.update:
        Path(args.baseline).write_text(json.dumps(fresh, indent=2) + "\n")
        print(f"wrote baseline snapshot to {args.baseline}")
        return 0

    baseline = json.loads(Path(args.baseline).read_text())
    failures, notes = compare(baseline, fresh, args.tolerance, args.latency_tolerance)
    for n in notes:
        print(f"note: {n}")
    for f in failures:
        print(f"REGRESSION: {f}")
    if failures:
        print(f"FAIL: {len(failures)} bench metric(s) regressed past tolerance")
        return 1
    n_gated = sum(1 for p in derive(flatten(baseline)) if rule_for(p) is not None)
    print(
        f"bench OK: {n_gated} gated metrics within tolerance "
        f"(throughput -{args.tolerance:.0%}, parity/ratio/occupancy exact-or-better, "
        f"step-clocked latency exact-or-lower; wall-clock latency ratios informational)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
