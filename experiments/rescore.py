"""Recompute trip-counted cost fields in dryrun JSONs from stored HLO."""
import glob, gzip, json, os, sys
sys.path.insert(0, "/root/repo/src")
from repro.utils import hlo_cost

for jf in sorted(glob.glob("/root/repo/experiments/dryrun/*.json")):
    rec = json.load(open(jf))
    if "skipped" in rec or "error" in rec:
        continue
    tag = os.path.basename(jf).replace(".json", "")
    hf = f"/root/repo/experiments/hlo/{tag}.hlo.gz"
    if not os.path.exists(hf):
        print("missing hlo:", tag)
        continue
    with gzip.open(hf, "rt") as f:
        hlo = f.read()
    tc = hlo_cost.analyze(hlo, rec["devices"])
    rec["tc_flops"] = tc.flops
    rec["tc_bytes"] = tc.bytes
    rec["tc_collectives"] = dict(tc.collectives)
    rec["tc_collectives"]["total"] = tc.collective_total
    rec["tc_collective_counts"] = {k: float(v) for k, v in tc.collective_counts.items()}
    rec["top_collective_sites"] = [
        {"site": k, "bytes": b, "execs": e} for k, b, e in hlo_cost.per_collective_sites(hlo, rec["devices"], top=8)
    ]
    json.dump(rec, open(jf, "w"), indent=1)
print("rescored")
