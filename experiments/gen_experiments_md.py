"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dryrun JSONs (run after any re-sweep)."""

import glob
import json
import os
import sys

sys.path.insert(0, "/root/repo/src")
sys.path.insert(0, "/root/repo")

from benchmarks.bench_roofline import table  # noqa: E402

DRY = "/root/repo/experiments/dryrun"


def gb(x):
    return f"{x/1e9:.2f}"


def dryrun_table(tag):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRY, f"*__{tag}.json"))):
        r = json.load(open(f))
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP: {r['skipped'][:60]} |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR |")
            continue
        ma = r.get("memory_analysis", {})
        per_dev_gb = (ma.get("argument_bytes", 0) + ma.get("temp_bytes", 0) + ma.get("output_bytes", 0)) / 2**30
        tc = r.get("tc_collectives", r["collectives"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['devices']} | {r.get('tc_flops', r['flops']):.3g} "
            f"| {per_dev_gb:.2f} | {gb(tc['total'])} | compiled in {r['compile_s']}s |"
        )
    hdr = ("| arch | shape | devices | per-dev FLOPs (trip-counted) | per-dev mem GiB (arg+temp+out) "
           "| per-dev collective GB | status |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_md():
    rows = table("singlepod")
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops "
           "| roofline frac | what would move it |\n|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | {r['dominant']} | {r['useful_flops_ratio']} "
            f"| {r['roofline_fraction']} | {r['note'].split(':')[1].strip()[:70]} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    print("## §Dry-run — single-pod (16x16 = 256 chips)\n")
    print(dryrun_table("singlepod"))
    print("\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table("multipod"))
    print("\n## §Roofline (single-pod, trip-counted HLO cost model)\n")
    print(roofline_md())
