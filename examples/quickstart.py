"""Quickstart: the EXAQ method end to end in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    PAPER_CLIP_COEFFS, exact_softmax, exaq_params, naive_params,
    optimal_clip_analytic, quantized_softmax,
)
from repro.kernels import ops

# 1) Optimal clipping (paper §3): sigma -> C via Table 1, or our Eq.-14 solver
sigma = 1.7
p2 = exaq_params(sigma, bits=2)                      # paper Table-1 rule
print(f"sigma={sigma}: paper C*={p2.clip:.3f}  (Table 1: {PAPER_CLIP_COEFFS[2]})")
print(f"          analytic Eq.-14 C*={optimal_clip_analytic(sigma, 2):.3f}")
print(f"LUT_exp (4 entries): {np.round(p2.lut_np(), 4)}")

# 2) 2-bit softmax (paper Algo. 2) vs exact (Algo. 1) vs NAIVE clipping
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(0, sigma, (8, 512)), jnp.float32)
# add the outlier tail real attention logits have
x = jnp.where(jnp.asarray(rng.random((8, 512)) < 0.02), x - 20.0, x)
ref = exact_softmax(x)
exaq = quantized_softmax(x, p2)
xmin = float((x - x.max(-1, keepdims=True)).min())
naive = quantized_softmax(x, naive_params(xmin, 2))
print(f"\nsoftmax-output MSE  EXAQ INT2: {float(((exaq-ref)**2).mean()):.2e}")
print(f"softmax-output MSE NAIVE INT2: {float(((naive-ref)**2).mean()):.2e}")

# 3) The fused Pallas kernel (interpret mode on CPU; TPU target)
y = ops.exaq_softmax(x, p2)
print(f"\nPallas kernel vs reference max err: {float(jnp.abs(y-exaq).max()):.2e}")

# 4) Fused flash-EXAQ attention
q = jnp.asarray(rng.normal(0, 1, (1, 4, 128, 64)), jnp.float32)
k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.float32)  # GQA kv=2
v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
o = ops.exaq_attention(q, k, v, p2, 64**-0.5, block_q=64, block_kv=64)
print(f"flash-EXAQ attention out: {o.shape}, finite={bool(jnp.isfinite(o).all())}")
