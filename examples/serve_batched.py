"""Continuous-batching walkthrough: slot engine, per-request sampling, and the
block-paged engine with shared-prefix reuse (DESIGN.md §Serving and §3).

    PYTHONPATH=src python examples/serve_batched.py

Four acts:
  1. ragged concurrent requests through the slot engine, EXAQ INT2 vs exact,
     mixed per-request sampling params, engine occupancy stats;
  2. the same workload on the paged engine — identical greedy tokens, plus
     pool telemetry (blocks, prefix hits, CoW);
  3. a shared-system-prompt demo: every request opens with the same prefix,
     so the paged engine prefills it once and later requests hit the cache;
  4. the int8 KV pool (DESIGN.md §6): same workload, pool stored as int8
     codes + per-block scales — pool memory and modeled decode bytes/step
     vs the fp32 pool, with a greedy-parity check.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.engine import Engine, EngineConfig, PagedEngine
from repro.runtime.sampling import GREEDY, SamplingParams

ARCH, SLOTS, MAX_SEQ, GEN = "yi-6b", 4, 96, 16
# one EngineConfig per engine shape (DESIGN.md §13): the same frozen config
# drives the slot engine and, with paging fields, every paged variant below
SLOT_CONFIG = EngineConfig(max_slots=SLOTS, max_seq=MAX_SEQ, seed=0)
PAGED_CONFIG = EngineConfig(max_slots=SLOTS, max_seq=MAX_SEQ, seed=0,
                            block_size=16, prefill_chunk=32)

rng = np.random.default_rng(0)
base = get_config(ARCH).reduced()
# fp32 params: this demo compares greedy tokens across engines, and a
# *random-init* model has near-tied argmax margins — bf16 activation noise
# can flip ties between mathematically-equal reduction orders. Real
# (trained) heads have confident margins; benchmarks/bench_serving.py
# asserts bit-exact parity there on a trained smoke model.
params = build_model(base.with_quant(softmax_impl="exact")).init(jax.random.PRNGKey(0), jnp.float32)

# --- act 1: slot engine, one shared ragged workload -------------------------
# 6 requests, 3 sampling styles, 4 slots: more requests than slots, so
# finished slots get recycled; per-request params ride as arrays through one
# jitted sampling dispatch (runtime/sampling.py).
prompts = [rng.integers(0, base.vocab_size, int(n)) for n in rng.integers(8, 48, 6)]
styles = [GREEDY, SamplingParams(temperature=0.7, top_k=40), SamplingParams(temperature=1.0, top_p=0.9)]

for impl, bits in (("exact", 2), ("exaq", 2)):
    cfg = base.with_quant(softmax_impl=impl, bits=bits)
    eng = Engine(cfg, params, SLOT_CONFIG)
    uids = [eng.submit(p, GEN, styles[i % len(styles)]) for i, p in enumerate(prompts)]
    results = eng.run()
    # stats: decode_steps / tokens_out / occupancy track how full the
    # continuous batch ran — mean_occupancy near SLOTS means little padding waste
    print(f"--- slot engine impl={impl} int{bits}: {len(results)} requests, "
          f"mean occupancy {eng.mean_occupancy:.2f}/{SLOTS} ---")
    for uid in uids[:3]:
        print(f"  req {uid} ({len(prompts[uid])}-tok prompt):", results[uid].tokens[:10])

# --- act 2: same workload, paged engine -------------------------------------
# The paged engine stores KV in a global pool of fixed-size blocks instead of
# rectangular per-slot rows; the math is identical (DESIGN.md §3 — paging is
# invisible to the softmax), so greedy tokens agree. Exact impl here: 2-bit
# quantization of a *random-init* model's near-tied scores amplifies
# reduce-order tie flips; the trained-model benchmark asserts 100% parity
# for EXAQ-INT2 (benchmarks/bench_serving.py).
cfg = base.with_quant(softmax_impl="exact")
slot_eng = Engine(cfg, params, SLOT_CONFIG)
slot_uids = [slot_eng.submit(p, GEN) for p in prompts]
slot_res = slot_eng.run()
paged = PagedEngine(cfg, params, PAGED_CONFIG)
paged_uids = [paged.submit(p, GEN) for p in prompts]
paged_res = paged.run()
agree = np.concatenate([np.asarray(slot_res[a].tokens) == np.asarray(paged_res[b].tokens)
                        for a, b in zip(slot_uids, paged_uids)])
print(f"--- paged engine: greedy agreement vs slot engine {100 * agree.mean():.1f}%; "
      f"pool {paged.kv_pool_bytes // 1024} KiB in {paged.pool.num_blocks} blocks ---")

# --- act 3: shared-prefix reuse ---------------------------------------------
# Production endpoints prepend the same system prompt to every request. The
# paged engine prefills those blocks once, publishes them under a rolling
# prompt hash, and later requests *retain* the cached blocks instead of
# re-running prefill — watch prefix_hit_rate climb after the first request.
# (Submitting one request first lets it register before the rest arrive;
# requests submitted in the same instant race admission and may all miss.)
system = rng.integers(0, base.vocab_size, 48)  # 3 blocks of 16
reuse = PagedEngine(cfg, params, PAGED_CONFIG)
first = reuse.submit(np.concatenate([system, rng.integers(0, base.vocab_size, 6)]), GEN)
reuse.step_chunk()  # first request prefills + registers the system blocks
late = [reuse.submit(np.concatenate([system, rng.integers(0, base.vocab_size, int(n))]), GEN)
        for n in rng.integers(4, 12, 5)]
reuse.run()
st = reuse.stats
print(f"--- shared-prefix demo: {100 * reuse.prefix_hit_rate:.0f}% of prompt tokens "
      f"served from the prefix cache ({st['prefix_hit_tokens']}/{st['prompt_tokens']}); "
      f"{st['prefill_chunks']} prefill chunks, "
      f"{reuse.pool.stats.cow_copies} copy-on-write forks ---")

# --- act 4: int8 KV pool ------------------------------------------------------
# The pool can store int8 codes with per-(block, kv-head) scales instead of fp
# values (DESIGN.md §6): scatters quantize, reads dequantize (the fused decode
# kernel does it in VMEM after the 8-bit DMA). Storage shrinks ~4x vs fp32 and
# the modeled decode-step KV traffic ~2x vs bf16 — at a quantization error far
# below the EXAQ softmax's own 2-bit grid, so greedy tokens agree.
from repro.kernels.exaq_paged_attention import paged_decode_bytes_model

import dataclasses

engines, results = {}, {}
for label in ("fp32", "int8"):
    eng = PagedEngine(cfg, params, dataclasses.replace(PAGED_CONFIG, kv_dtype=label))
    uids = [eng.submit(p, GEN) for p in prompts]
    res = eng.run()
    engines[label], results[label] = eng, [res[u].tokens for u in uids]
agree = np.concatenate([np.asarray(a) == np.asarray(b)
                        for a, b in zip(results["fp32"], results["int8"])])
mb = engines["fp32"].blocks_per_table
occ = np.full((SLOTS,), MAX_SEQ // 2)  # model traffic at 50% occupancy
bytes_by_dtype = {
    dt: paged_decode_bytes_model(slots=SLOTS, kv_heads=base.num_kv_heads, max_blocks=mb,
                                 block_size=16, head_dim=base.resolved_head_dim,
                                 kv_lens=occ, kv_dtype=dt)["fused_pool_read_bytes"]
    for dt in ("fp32", "bf16", "int8")
}
print(f"--- int8 pool: {engines['fp32'].kv_pool_bytes // 1024} KiB fp32 -> "
      f"{engines['int8'].kv_pool_bytes // 1024} KiB int8 (scales included, "
      f"{engines['fp32'].kv_pool_bytes / engines['int8'].kv_pool_bytes:.1f}x smaller); "
      f"modeled fused decode KV bytes/step/layer at 50% occupancy: "
      f"{bytes_by_dtype['fp32']} fp32 / {bytes_by_dtype['bf16']} bf16 / "
      f"{bytes_by_dtype['int8']} int8; "
      f"greedy agreement vs fp32 pool {100 * agree.mean():.1f}% ---")
