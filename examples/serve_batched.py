"""Continuous-batching serving example: ragged concurrent requests through the
slot-based engine, EXAQ INT2 softmax vs exact, mixed per-request sampling.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.engine import Engine
from repro.runtime.sampling import GREEDY, SamplingParams

ARCH, SLOTS, MAX_SEQ, GEN = "yi-6b", 4, 96, 16

rng = np.random.default_rng(0)
base = get_config(ARCH).reduced()
params = build_model(base.with_quant(softmax_impl="exact")).init(jax.random.PRNGKey(0), jnp.bfloat16)
# one shared ragged workload: 6 requests, 3 sampling styles, 4 slots
prompts = [rng.integers(0, base.vocab_size, int(n)) for n in rng.integers(8, 48, 6)]
styles = [GREEDY, SamplingParams(temperature=0.7, top_k=40), SamplingParams(temperature=1.0, top_p=0.9)]

for impl, bits in (("exact", 2), ("exaq", 2)):
    cfg = base.with_quant(softmax_impl=impl, bits=bits)
    eng = Engine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ, seed=0)
    uids = [eng.submit(p, GEN, styles[i % len(styles)]) for i, p in enumerate(prompts)]
    results = eng.run()
    print(f"--- impl={impl} int{bits}: {len(results)} requests, "
          f"mean occupancy {eng.mean_occupancy:.2f}/{SLOTS} ---")
    for uid in uids[:3]:
        print(f"  req {uid} ({len(prompts[uid])}-tok prompt):", results[uid].tokens[:10])
