"""Batched serving example: prefill + greedy decode with EXAQ INT2 softmax,
compared against exact-softmax serving.

    PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys

for impl in ("exact", "exaq"):
    print(f"--- impl={impl} ---")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-6b", "--reduced",
         "--batch", "4", "--prompt-len", "64", "--gen", "16", "--impl", impl],
        check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
