"""Calibration workflow (paper §5.1.1) + accuracy comparison (Table 2 proxy).

Trains a small LM, calibrates per-layer sigma on held-out batches, then
evaluates perplexity with exact / EXAQ / NAIVE softmax at INT2 and INT3.

    PYTHONPATH=src:. python examples/calibrate_and_eval.py
"""
import benchmarks.bench_accuracy as acc

res = acc.run(train_steps=150)
print(f"calibrated sigma range: [{res['sigma_range'][0]:.2f}, {res['sigma_range'][1]:.2f}]"
      f"  (paper Fig. 6: [0.9, 3.4])")
print(f"{'method':>16s}  perplexity")
print(f"{'exact (Algo.1)':>16s}  {res['exact']:.3f}")
for bits in (2, 3):
    for m in ("exaq_paper", "exaq_analytic", "naive"):
        print(f"{m + f'_int{bits}':>16s}  {res[f'{m}_int{bits}']:.3f}")
