"""End-to-end training driver example: train a small LM for a few hundred
steps with checkpoint/restart and (optionally) EXAQ-STE quantized attention.

    PYTHONPATH=src python examples/train_lm.py            # ~2 min on CPU
    PYTHONPATH=src python examples/train_lm.py --exaq     # EXAQ-STE softmax
    # kill it mid-run and re-run: it resumes from the last checkpoint.

Scale up (e.g. ~100M params): --d-model 768 --layers 12  (same code path the
512-chip dry-run exercises; see src/repro/launch/train.py for the full CLI).
"""
import subprocess
import sys

args = [sys.executable, "-m", "repro.launch.train",
        "--arch", "internlm2-1.8b", "--reduced",
        "--steps", "120", "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/exaq_train_ckpt", "--ckpt-every", "40"]
if "--exaq" in sys.argv:
    args.append("--exaq-train")
if "--big" in sys.argv:  # ~100M-param configuration
    args += ["--d-model", "768", "--layers", "12"]
subprocess.run(args, check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
