"""Paper Table 1 + Figure 3: optimal clipping values vs sigma.

Reports, per bit-width M in {2, 3}:
  * the analytic Eq.-14 optimum over the sigma grid (our closed form),
  * a Monte-Carlo simulated optimum (Fig. 3 procedure),
  * linear fits of both,
  * the paper's published Table-1 coefficients,
and the empirical e^x-MSE of each rule — the reproduction finding of
DESIGN.md §1 quantified.
"""

from __future__ import annotations

import numpy as np

from repro.core import clipping


def run(fast: bool = True):
    rows = []
    sigmas = np.linspace(0.9, 3.4, 6 if fast else 14)
    for bits in (2, 3):
        ana = [clipping.optimal_clip_analytic(float(s), bits, grid=1024, refine=32) for s in sigmas]
        sim = [clipping.simulate_optimal_clip(float(s), bits, trials=16 if fast else 64) for s in sigmas]
        A = np.vstack([sigmas, np.ones_like(sigmas)]).T
        sa, ia = np.linalg.lstsq(A, np.asarray(ana), rcond=None)[0]
        ss, is_ = np.linalg.lstsq(A, np.asarray(sim), rcond=None)[0]
        ps, pi = clipping.PAPER_CLIP_COEFFS[bits]
        rows.append({
            "bits": bits,
            "fit_analytic": (round(float(sa), 3), round(float(ia), 3)),
            "fit_simulated": (round(float(ss), 3), round(float(is_), 3)),
            "paper_table1": (ps, pi),
            "grid_sigma": [round(float(s), 2) for s in sigmas],
            "grid_Cstar_analytic": [round(float(c), 3) for c in ana],
            "grid_Cstar_simulated": [round(float(c), 3) for c in sim],
        })
    return rows


def main():
    for r in run():
        print(f"M={r['bits']}: analytic fit C*={r['fit_analytic'][0]}*s+{r['fit_analytic'][1]}  "
              f"simulated fit C*={r['fit_simulated'][0]}*s+{r['fit_simulated'][1]}  "
              f"paper Table1 C*={r['paper_table1'][0]}*s+{r['paper_table1'][1]}")
    return run()


if __name__ == "__main__":
    main()
