"""Continuous-batching serving benchmark: Poisson arrivals, exact vs EXAQ.

    PYTHONPATH=src python benchmarks/bench_serving.py [--requests 12] [--slots 4]

Drives ``runtime.engine.Engine`` with a Poisson request-arrival trace
(exponential inter-arrival times measured in decode steps — the engine is
step-clocked, so the trace is backend-independent and reproducible) and
reports, for exact / EXAQ-2bit / EXAQ-3bit softmax:

  * decode throughput (tokens/sec over jitted decode chunks, post-compile)
  * mean + max slot occupancy (how full the continuous batch ran)
  * greedy-token agreement vs the exact-softmax engine on the same trace

The smoke model is a 2-layer reduced config briefly overfit on a periodic
token sequence: a random-init model has near-tied logits (argmax margins
below any quantizer's noise floor, so agreement would measure tie-breaking,
not EXAQ), while the trained head has the confident margins of a real LM —
there the paper's serving claim (INT2 softmax preserves greedy outputs) is
checkable and asserted. Runs on CPU (kernels auto-select interpret/jnp).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.optim.adamw import AdamW
from repro.runtime.engine import Engine
from repro.runtime.train import init_train_state, make_train_step

PERIOD, TOK0 = 7, 5  # the learned pattern: TOK0, TOK0+1, ..., cyclic


def make_smoke_model(arch: str, train_steps: int = 60):
    """Reduced 2-layer model overfit on a periodic sequence (confident head)."""
    base = get_config(arch).reduced(num_layers=2)
    cfg = base.with_quant(softmax_impl="exact")
    opt = AdamW(lr=3e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    T = 32
    seq = np.arange(T + 1) % PERIOD + TOK0
    batch = {
        "tokens": jnp.asarray(np.stack([np.roll(seq, -s)[:T] for s in range(8)]), jnp.int32),
        "labels": jnp.asarray(np.stack([np.roll(seq, -s)[1 : T + 1] for s in range(8)]), jnp.int32),
    }
    for _ in range(train_steps):
        state, metrics = step(state, batch)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), state["params"])
    return base, params, float(metrics["loss"])


def make_trace(rng, n_requests: int, rate: float, lo: int, hi: int):
    """Poisson process over decode steps: (arrival_step, prompt_len) pairs."""
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    lens = rng.integers(lo, hi + 1, n_requests)
    return list(zip(arrivals.tolist(), lens.tolist()))


def run_trace(cfg, params, qstate, trace, prompts, *, slots, max_seq, gen, chunk):
    eng = Engine(cfg, params, qstate=qstate, max_slots=slots, max_seq=max_seq,
                 steps_per_sync=chunk, seed=0)
    pending = list(range(len(trace)))
    uid_of = {}
    step_clock = 0  # monotone: advances by decode steps executed, or idle-skips
    last_decode_steps = 0
    while pending or eng.has_work():
        while pending and trace[pending[0]][0] <= step_clock:
            i = pending.pop(0)
            uid_of[i] = eng.submit(prompts[i], gen)
        if eng.has_work():
            eng.step_chunk()
            step_clock += eng.stats["decode_steps"] - last_decode_steps
            last_decode_steps = eng.stats["decode_steps"]
        else:
            step_clock = trace[pending[0]][0]  # idle-skip to the next arrival
    results = eng.run()
    return eng, {i: results[uid_of[i]].tokens for i in range(len(trace))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5, help="arrivals per decode step")
    ap.add_argument("--chunk", type=int, default=4, help="decode steps per jitted chunk")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    base, params, loss = make_smoke_model(args.arch)
    m_exact = build_model(base.with_quant(softmax_impl="exact"))

    lo, hi = 8, 24
    trace = make_trace(rng, args.requests, args.rate, lo, hi)
    pattern = np.arange(hi + PERIOD) % PERIOD + TOK0
    prompts = [np.roll(pattern, -int(rng.integers(0, PERIOD)))[:n] for _, n in trace]
    max_seq = hi + args.gen

    # calibrate the EXAQ clip from observed sigma (paper §5.1.1) — the serving
    # parity claim is about the *calibrated* quantizer
    calib_batch = {"tokens": jnp.asarray(np.stack([pattern[:hi], pattern[1 : hi + 1]]), jnp.int32)}
    stats = m_exact.calibrate(params, calib_batch)

    outputs = {}
    print(f"arch={base.name} (2-layer smoke, train loss {loss:.4f}) "
          f"requests={args.requests} slots={args.slots} gen={args.gen} "
          f"Poisson rate={args.rate}/step")
    for label, impl, bits in (("exact", "exact", 2), ("exaq-int2", "exaq", 2), ("exaq-int3", "exaq", 3)):
        cfg = base.with_quant(softmax_impl=impl, bits=bits)
        qstate = build_model(cfg).qstate_from_stats(stats) if impl == "exaq" else None
        eng, outs = run_trace(cfg, params, qstate, trace, prompts,
                              slots=args.slots, max_seq=max_seq, gen=args.gen, chunk=args.chunk)
        outputs[label] = outs
        toks = sum(len(t) for t in outs.values())
        # first token per request is sampled at prefill admission, outside
        # decode_time — exclude it from the decode-throughput numerator
        tps = (toks - len(trace)) / max(eng.stats["decode_time"], 1e-9)
        print(f"{label:10s} {toks:4d} tokens  {tps:8.1f} tok/s (decode-chunk time)  "
              f"occupancy mean {eng.mean_occupancy:.2f} / max {eng.stats['max_active']} "
              f"of {args.slots} slots")
        assert eng.stats["max_active"] >= 2, "trace never reached 2 concurrent requests"

    for label in ("exaq-int2", "exaq-int3"):
        a = np.concatenate([np.asarray(outputs["exact"][i]) for i in range(args.requests)])
        b = np.concatenate([np.asarray(outputs[label][i]) for i in range(args.requests)])
        agree = float((a == b).mean())
        print(f"greedy agreement vs exact: {label} {100*agree:.1f}%")
        if label == "exaq-int2":
            assert agree == 1.0, f"EXAQ-2bit greedy tokens diverged from exact ({agree:.3f})"
    print("OK: >=2 concurrent ragged requests per jitted step; EXAQ-2bit greedy == exact")


if __name__ == "__main__":
    main()
