"""Continuous-batching serving benchmark: Poisson arrivals, exact vs EXAQ,
slot engine vs paged engine with shared-prefix reuse.

    PYTHONPATH=src python benchmarks/bench_serving.py [--requests 12] [--slots 4] \
        [--json out.json]

Part 1 drives ``runtime.engine.Engine`` with a Poisson request-arrival trace
(exponential inter-arrival times measured in decode steps — the engine is
step-clocked, so the trace is backend-independent and reproducible) and
reports, for exact / EXAQ-2bit / EXAQ-3bit softmax:

  * decode throughput (tokens/sec over jitted decode chunks, post-compile)
  * mean + max slot occupancy (how full the continuous batch ran)
  * greedy-token agreement vs the exact-softmax engine on the same trace

Part 2 replays a *shared-system-prompt* Poisson trace (every request opens
with the same system prefix, as a production endpoint would) through the slot
engine and ``runtime.engine.PagedEngine`` and reports the paged headline
metrics (DESIGN.md §3):

  * prefix-cache hit rate on prompt tokens (asserted >= 50%)
  * tokens of live KV per byte of cache, paged pool vs rectangular slot cache
  * copy-on-write copies / evictions / prefill chunks
  * bit-exact greedy parity with the slot engine on the same trace (asserted)

Part 3 is the paged-decode microbenchmark (DESIGN.md §3, fused paged
decode): one jitted ``decode_step_paged`` at 50% pool occupancy, fused
Pallas kernel vs gather-then-dispatch reference, plus the fused kernel on
an int8 pool (DESIGN.md §6) and a packed-int4 pool (DESIGN.md §10). It
reports the modeled per-step HBM KV bytes (pool-read vs gather-then-read —
asserted >= 2x in the fused kernel's favor; fused-int8 vs fused-bf16 —
asserted >= 1.8x; fused-int4 vs fused-int8 — asserted >= 1.8x, and >= 3.5x
vs bf16, scale + sub-code reads counted; these are the numbers that
transfer to the accelerator) and the measured step latency (directional on
CPU, where the fused kernel runs in Pallas interpret mode while the gather
lowers to native XLA). ``--micro-json`` dumps this part alone for CI
artifact upload.

Part 3b is the paged-*prefill* microbenchmark (DESIGN.md §7): one jitted
``prefill_paged_chunk`` whose window fills 50% of the padded table, fused
Pallas paged-prefill kernel vs gather-then-attend, at bf16 and int8. It
reports the modeled per-layer HBM KV bytes to prefill the whole prompt
(asserted >= 2x in the fused kernel's favor — the gather re-copies the
dense window every chunk, O(prompt^2) bytes) and the measured chunk
latency (directional on CPU). The metrics ride in the ``--micro-json``
object under ``"prefill"``.

Part 4 replays the shared-prefix trace through the paged engine with an
fp32 pool, an int8 pool and a packed-int4 pool (same calibrated EXAQ-INT2
softmax) and asserts greedy decode agrees on >= 99% of tokens for both
quantized pools while the pool shrinks ~4x (int8) and >= 1.8x further
(int4; all scale planes included) — the serving-accuracy claims of
DESIGN.md §6/§10.

Part 5 replays the same trace through a 2-replica ``DataParallelEngine``
(DESIGN.md §9) behind the shared admission queue and asserts bit-exact
greedy parity with the single paged engine, that the deterministic
least-loaded dispatch fed both replicas, and reports per-replica stats
plus aggregated hit rate / occupancy under ``"dp"`` in the JSON.

Part 6 drives the SLA scheduler (DESIGN.md §11) with a bursty, heavy-tail
arrival trace (Pareto gaps between clusters of simultaneous requests — the
adversarial shape for TTFT tails, where a burst lands on a full batch) at
one decode step per chunk and two priority classes, and reports per-request
TTFT and inter-token-latency percentiles in *deterministic scheduler ticks*
(gated exact-or-lower — any rise is a real scheduling regression, runner
hardware can't move them). An overload arm re-runs the trace behind a
``max_inflight`` admission cap and asserts every rejection is a structured
*retryable* ``Rejected`` with a backoff hint while the admitted subset
still completes. All of it rides under ``"bursty"`` in the JSON.

Part 8 serves every non-dense decoder family through the paged StatePool
(DESIGN.md §13): pure-SSM ``mamba2-1.3b``, MoE ``deepseek-moe-16b``, and
hybrid ``zamba2-2.7b``, each on a shared-prefix trace gated on exact greedy
parity against its unpaged reference plus a mean-occupancy floor, under
``"state_archs"`` in the JSON.

The smoke model is a 2-layer reduced config briefly overfit on a periodic
token sequence: a random-init model has near-tied logits (argmax margins
below any quantizer's noise floor, so agreement would measure tie-breaking,
not EXAQ), while the trained head has the confident margins of a real LM —
there the paper's serving claim (INT2 softmax preserves greedy outputs) is
checkable and asserted. Runs on CPU (kernels auto-select interpret/jnp).

``--json`` dumps every reported metric for CI artifact upload.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.optim.adamw import AdamW
from repro.runtime.engine import (DataParallelEngine, Engine, EngineConfig,
                                  PagedEngine, kv_dtype_name)
from repro.runtime.train import init_train_state, make_train_step

PERIOD, TOK0 = 7, 5  # the learned pattern: TOK0, TOK0+1, ..., cyclic


def make_smoke_model(arch: str, train_steps: int = 60):
    """Reduced 2-layer model overfit on a periodic sequence (confident head).

    The training window covers every position the serving traces below can
    reach (shared prefix + ragged tail + generation): beyond the trained
    window the head's argmax margins collapse to RoPE-extrapolation noise,
    where agreement metrics would measure tie-breaking against the
    quantizer's noise floor instead of the pool's fidelity."""
    base = get_config(arch).reduced(num_layers=2)
    cfg = base.with_quant(softmax_impl="exact")
    opt = AdamW(lr=3e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    T = 80
    seq = np.arange(T + 1) % PERIOD + TOK0
    batch = {
        "tokens": jnp.asarray(np.stack([np.roll(seq, -s)[:T] for s in range(8)]), jnp.int32),
        "labels": jnp.asarray(np.stack([np.roll(seq, -s)[1 : T + 1] for s in range(8)]), jnp.int32),
    }
    for _ in range(train_steps):
        state, metrics = step(state, batch)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), state["params"])
    return base, params, float(metrics["loss"])


def make_trace(rng, n_requests: int, rate: float, lo: int, hi: int):
    """Poisson process over decode steps: (arrival_step, prompt_len) pairs."""
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    lens = rng.integers(lo, hi + 1, n_requests)
    return list(zip(arrivals.tolist(), lens.tolist()))


def run_trace(cfg, params, qstate, trace, prompts, *, slots, max_seq, gen, chunk,
              paged=False, block_size=8, prefill_chunk=16, cache_dtype=jnp.bfloat16,
              dp=0, spec_k=0, drafter=None):
    config = EngineConfig(max_slots=slots, max_seq=max_seq, block_size=block_size,
                          prefill_chunk=prefill_chunk, steps_per_sync=chunk, seed=0,
                          kv_dtype=kv_dtype_name(cache_dtype), spec_k=spec_k,
                          drafter=drafter, replicas=dp or 1)
    if dp:
        eng = DataParallelEngine(cfg, params, config, qstate=qstate)
    elif paged:
        eng = PagedEngine(cfg, params, config, qstate=qstate)
    else:
        eng = Engine(cfg, params, config, qstate=qstate)
    pending = list(range(len(trace)))
    uid_of = {}
    step_clock = 0  # monotone: advances by decode steps executed, or idle-skips
    last_decode_steps = 0
    while pending or eng.has_work():
        while pending and trace[pending[0]][0] <= step_clock:
            i = pending.pop(0)
            uid_of[i] = eng.submit(prompts[i], gen)
        if eng.has_work():
            eng.step_chunk()
            step_clock += eng.stats["decode_steps"] - last_decode_steps
            last_decode_steps = eng.stats["decode_steps"]
        else:
            step_clock = trace[pending[0]][0]  # idle-skip to the next arrival
    results = eng.run()
    return eng, {i: results[uid_of[i]].tokens for i in range(len(trace))}


def calibrate_smoke(base, params, hi: int = 24):
    """EXAQ clip calibration from observed sigma (paper §5.1.1) — both the
    slot-engine and paged-engine parity claims are about the *calibrated*
    quantizer, so every exaq engine below shares this qstate source."""
    m_exact = build_model(base.with_quant(softmax_impl="exact"))
    pattern = np.arange(hi + PERIOD) % PERIOD + TOK0
    calib_batch = {"tokens": jnp.asarray(np.stack([pattern[:hi], pattern[1 : hi + 1]]), jnp.int32)}
    return m_exact.calibrate(params, calib_batch)


def bench_impl_agreement(base, params, calib_stats, args, rng, report):
    """Part 1: exact vs EXAQ greedy agreement on the slot engine."""
    lo, hi = 8, 24
    trace = make_trace(rng, args.requests, args.rate, lo, hi)
    pattern = np.arange(hi + PERIOD) % PERIOD + TOK0
    prompts = [np.roll(pattern, -int(rng.integers(0, PERIOD)))[:n] for _, n in trace]
    max_seq = hi + args.gen

    outputs = {}
    for label, impl, bits in (("exact", "exact", 2), ("exaq-int2", "exaq", 2), ("exaq-int3", "exaq", 3)):
        cfg = base.with_quant(softmax_impl=impl, bits=bits)
        qstate = build_model(cfg).qstate_from_stats(calib_stats) if impl == "exaq" else None
        eng, outs = run_trace(cfg, params, qstate, trace, prompts,
                              slots=args.slots, max_seq=max_seq, gen=args.gen, chunk=args.chunk)
        outputs[label] = outs
        toks = sum(len(t) for t in outs.values())
        # first token per request is sampled at prefill admission, outside
        # decode_time — exclude it from the decode-throughput numerator
        tps = (toks - len(trace)) / max(eng.stats["decode_time"], 1e-9)
        print(f"{label:10s} {toks:4d} tokens  {tps:8.1f} tok/s (decode-chunk time)  "
              f"occupancy mean {eng.mean_occupancy:.2f} / max {eng.stats['max_active']} "
              f"of {args.slots} slots")
        assert eng.stats["max_active"] >= 2, "trace never reached 2 concurrent requests"
        report["impls"][label] = {"tokens": toks, "tok_per_s": tps,
                                  "mean_occupancy": eng.mean_occupancy,
                                  "max_active": eng.stats["max_active"]}

    for label in ("exaq-int2", "exaq-int3"):
        a = np.concatenate([np.asarray(outputs["exact"][i]) for i in range(args.requests)])
        b = np.concatenate([np.asarray(outputs[label][i]) for i in range(args.requests)])
        agree = float((a == b).mean())
        print(f"greedy agreement vs exact: {label} {100*agree:.1f}%")
        report["impls"][label]["agreement_vs_exact"] = agree
        if label == "exaq-int2":
            assert agree == 1.0, f"EXAQ-2bit greedy tokens diverged from exact ({agree:.3f})"


def bench_paged(base, params, calib_stats, args, rng, report):
    """Part 2: shared-system-prompt trace, slot engine vs paged engine.

    Every request's prompt is a prefix of the same periodic sequence —
    ``sys_len`` shared system tokens plus a ragged user tail — exactly the
    workload the prefix cache targets (and still in-distribution for the
    overfit smoke head, keeping greedy margins confident)."""
    sys_len, tail_lo, tail_hi = args.shared_prefix, 1, 8
    trace = make_trace(rng, args.requests, args.paged_rate, tail_lo, tail_hi)
    pattern = np.arange(sys_len + tail_hi + PERIOD) % PERIOD + TOK0
    prompts = [pattern[: sys_len + n] for _, n in trace]
    max_seq = sys_len + tail_hi + args.gen

    for impl, bits in (("exact", 2), ("exaq", 2)):
        cfg = base.with_quant(softmax_impl=impl, bits=bits)
        qstate = build_model(cfg).qstate_from_stats(calib_stats) if impl == "exaq" else None
        slot_eng, slot_out = run_trace(cfg, params, qstate, trace, prompts,
                                       slots=args.slots, max_seq=max_seq, gen=args.gen,
                                       chunk=args.chunk)
        paged_eng, paged_out = run_trace(cfg, params, qstate, trace, prompts,
                                         slots=args.slots, max_seq=max_seq, gen=args.gen,
                                         chunk=args.chunk, paged=True,
                                         block_size=args.block_size,
                                         prefill_chunk=args.prefill_chunk)
        parity = all(slot_out[i] == paged_out[i] for i in range(len(trace)))
        hit = paged_eng.prefix_hit_rate
        st = paged_eng.stats
        pst = paged_eng.pool.stats
        # tokens of KV a byte of cache buys: the paged pool only holds blocks,
        # the slot cache holds max_slots * max_seq rows no matter what
        slot_bytes = slot_eng._cache_k.nbytes + slot_eng._cache_v.nbytes
        used_blocks = paged_eng.pool.num_blocks - 1 - paged_eng.pool.num_free
        paged_used_bytes = (paged_eng.kv_pool_bytes // paged_eng.pool.num_blocks) * max(used_blocks, 1)
        tok_per_kib_slot = st["prompt_tokens"] / (slot_bytes / 1024)
        tok_per_kib_paged = st["prompt_tokens"] / (paged_used_bytes / 1024)
        label = f"paged-{impl}{'' if impl == 'exact' else f'-int{bits}'}"
        print(f"{label:16s} prefix-cache hit rate {100*hit:.1f}% "
              f"({st['prefix_hit_tokens']}/{st['prompt_tokens']} prompt tokens), "
              f"{st['prefill_chunks']} prefill chunks of {args.prefill_chunk}, "
              f"{pst.cow_copies} CoW, {pst.evictions} evictions")
        print(f"{'':16s} KV density: {tok_per_kib_paged:.1f} tok/KiB paged (blocks touched) "
              f"vs {tok_per_kib_slot:.1f} tok/KiB slot cache; "
              f"greedy parity vs slot engine: {parity}")
        assert parity, f"paged engine diverged from slot engine ({impl})"
        assert hit >= 0.5, f"prefix-cache hit rate {hit:.2f} < 0.5 on the shared-prefix trace"
        report["paged"][impl] = {
            "prefix_hit_rate": hit,
            "prompt_tokens": st["prompt_tokens"],
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "prefill_chunks": st["prefill_chunks"],
            "cow_copies": pst.cow_copies,
            "evictions": pst.evictions,
            "tok_per_kib_paged": tok_per_kib_paged,
            "tok_per_kib_slot": tok_per_kib_slot,
            "greedy_parity_vs_slot": parity,
        }


def bench_kv_dtype(base, params, calib_stats, args, rng, report):
    """Part 4: int8 and packed-int4 KV pools vs the fp32 pool on the
    shared-prefix trace (DESIGN.md §6/§10).

    Same engine, same trace, same calibrated EXAQ-INT2 softmax — only the
    pool storage format changes. The int8 pool holds int8 codes plus
    per-(block, kv-head) fp32 scales; the int4 pool packs two codes per byte
    under a block-scale x sub-block-code grid. Both quantize on scatter and
    dequantize inside the read paths, so the claim under test is *accuracy*:
    greedy decode must agree with the fp32 pool on >= 99% of tokens
    (asserted), while the pool shrinks ~4x (int8) and ~7x+ (int4, all scale
    planes included, reported)."""
    sys_len, tail_lo, tail_hi = args.shared_prefix, 1, 8
    trace = make_trace(rng, args.requests, args.paged_rate, tail_lo, tail_hi)
    pattern = np.arange(sys_len + tail_hi + PERIOD) % PERIOD + TOK0
    prompts = [pattern[: sys_len + n] for _, n in trace]
    max_seq = sys_len + tail_hi + args.gen

    cfg = base.with_quant(softmax_impl="exaq", bits=2)
    qstate = build_model(cfg).qstate_from_stats(calib_stats)
    engines, outs = {}, {}
    for label, dt in (("fp32", jnp.float32), ("int8", jnp.int8), ("int4", "int4")):
        engines[label], outs[label] = run_trace(
            cfg, params, qstate, trace, prompts, slots=args.slots, max_seq=max_seq,
            gen=args.gen, chunk=args.chunk, paged=True, block_size=args.block_size,
            prefill_chunk=args.prefill_chunk, cache_dtype=dt)
    a = np.concatenate([np.asarray(outs["fp32"][i]) for i in range(len(trace))])
    fp32_bytes = engines["fp32"].kv_pool_bytes
    report["kv_dtype"] = {"tokens_compared": int(a.size),
                         "pool_bytes_fp32": int(fp32_bytes)}
    for label in ("int8", "int4"):
        b = np.concatenate([np.asarray(outs[label][i]) for i in range(len(trace))])
        agree = float((a == b).mean())
        q_bytes = engines[label].kv_pool_bytes
        print(f"{label} KV pool: greedy agreement vs fp32 pool {100*agree:.1f}% "
              f"({int((a == b).sum())}/{a.size} tokens); pool "
              f"{fp32_bytes/2**20:.2f} MiB fp32 -> {q_bytes/2**20:.2f} MiB {label} "
              f"({fp32_bytes/q_bytes:.2f}x smaller, scales included)")
        assert agree >= 0.99, (
            f"{label} KV pool greedy agreement {agree:.3f} < 0.99 vs the fp32 pool"
        )
        report["kv_dtype"][f"agreement_{label}_vs_fp32"] = agree
        report["kv_dtype"][f"pool_bytes_{label}"] = int(q_bytes)
        report["kv_dtype"][f"pool_shrink_{label}_x"] = fp32_bytes / q_bytes
    int4_vs_int8 = (report["kv_dtype"]["pool_bytes_int8"]
                    / report["kv_dtype"]["pool_bytes_int4"])
    report["kv_dtype"]["pool_shrink_x"] = report["kv_dtype"]["pool_shrink_int8_x"]
    report["kv_dtype"]["int4_vs_int8_pool_x"] = int4_vs_int8
    assert int4_vs_int8 >= 1.8, (
        f"int4 pool must be >= 1.8x smaller than int8 (got {int4_vs_int8:.2f}x)"
    )


def bench_dp(base, params, calib_stats, args, rng, report):
    """Part 5: data-parallel replica fleet vs a single paged engine
    (DESIGN.md §9).

    The same shared-prefix Poisson trace runs through one ``PagedEngine``
    and through a ``DataParallelEngine`` of 2 replicas behind the shared
    admission queue. Greedy decode is batch-composition-independent, so the
    fleet must reproduce the single engine's tokens bit-exactly (asserted
    and gated) no matter how the deterministic least-loaded dispatch splits
    the trace. Per-replica stats verify the dispatch actually balanced, and
    the aggregated hit rate / occupancy are gated as floors — dispatch is
    deterministic, so they are too."""
    replicas = 2
    sys_len, tail_lo, tail_hi = args.shared_prefix, 1, 8
    trace = make_trace(rng, args.requests, args.paged_rate, tail_lo, tail_hi)
    pattern = np.arange(sys_len + tail_hi + PERIOD) % PERIOD + TOK0
    prompts = [pattern[: sys_len + n] for _, n in trace]
    max_seq = sys_len + tail_hi + args.gen

    cfg = base.with_quant(softmax_impl="exaq", bits=2)
    qstate = build_model(cfg).qstate_from_stats(calib_stats)
    kw = dict(slots=args.slots, max_seq=max_seq, gen=args.gen, chunk=args.chunk,
              block_size=args.block_size, prefill_chunk=args.prefill_chunk)
    single, single_out = run_trace(cfg, params, qstate, trace, prompts, paged=True, **kw)
    fleet, fleet_out = run_trace(cfg, params, qstate, trace, prompts, dp=replicas, **kw)
    parity = all(single_out[i] == fleet_out[i] for i in range(len(trace)))
    per = fleet.per_replica_stats
    agg_hit = fleet.prefix_hit_rate
    agg_occ = fleet.mean_occupancy
    pst = fleet.pool_stats
    print(f"dp={replicas} fleet: greedy parity vs single paged engine: {parity}; "
          f"aggregate hit rate {100*agg_hit:.1f}%, occupancy {agg_occ:.2f} "
          f"(sum over replicas), {pst.cow_copies} CoW, {pst.evictions} evictions")
    for i, s in enumerate(per):
        print(f"  replica {i}: {s['prefills']} requests, {s['tokens_out']} tokens, "
              f"occupancy {s['mean_occupancy']:.2f}/{args.slots}, "
              f"hit rate {100*s['prefix_hit_rate']:.1f}%")
    assert parity, "dp fleet greedy tokens diverged from the single paged engine"
    assert all(s["prefills"] > 0 for s in per), (
        f"dispatch starved a replica: {[s['prefills'] for s in per]}"
    )
    report["dp"] = {
        "replicas": replicas,
        "greedy_parity_vs_single": parity,
        "aggregate": {
            "prefix_hit_rate": agg_hit,
            "mean_occupancy": agg_occ,
            "requests": fleet.stats["prefills"],
            "cow_copies": pst.cow_copies,
            "evictions": pst.evictions,
        },
        "per_replica": [
            {"requests": s["prefills"], "tokens_out": s["tokens_out"],
             "mean_occupancy": s["mean_occupancy"],
             "prefix_hit_rate": s["prefix_hit_rate"]}
            for s in per
        ],
    }


def make_bursty_trace(rng, n_requests: int, *, burst: int = 4, tail: float = 1.5,
                      scale: float = 6.0):
    """Heavy-tail bursty arrivals: clusters of up to ``burst`` simultaneous
    requests separated by Pareto gaps (in decode steps). Returns
    (arrival_step, tail_len) pairs like ``make_trace``."""
    arrivals, t, i = [], 0, 0
    while i < n_requests:
        k = min(int(rng.integers(1, burst + 1)), n_requests - i)
        arrivals += [t] * k
        i += k
        t += 1 + int(min(rng.pareto(tail) * scale, 64.0))
    lens = rng.integers(1, 9, n_requests)
    return list(zip(arrivals, lens.tolist()))


def _replay_streaming(eng, trace, prompts, gen):
    """Replay ``trace`` one decode step per chunk, recording the engine tick
    at which every token became visible (``tokens_so_far`` — the same
    streaming source the async frontend flushes from). Returns
    (uid_of, rejections, token_ticks, results)."""
    from repro.runtime.engine_core import Rejected

    pending = list(range(len(trace)))
    uid_of, rejections, token_ticks = {}, {}, {}
    step_clock, last_decode = 0, 0
    while pending or eng.has_work():
        while pending and trace[pending[0]][0] <= step_clock:
            i = pending.pop(0)
            r = eng.try_submit(prompts[i], gen, priority=i % 2)
            if isinstance(r, Rejected):
                rejections[i] = r
            else:
                uid_of[i] = r
                token_ticks[r] = []
        if eng.has_work():
            eng.step_chunk(1)
            now = eng.now()
            for uid, ticks in token_ticks.items():
                n = len(eng.tokens_so_far(uid))
                ticks.extend([now] * (n - len(ticks)))
            step_clock += eng.stats["decode_steps"] - last_decode
            last_decode = eng.stats["decode_steps"]
        elif pending:
            step_clock = trace[pending[0]][0]  # idle-skip to the next arrival
    return uid_of, rejections, token_ticks, eng.run()


def bench_bursty(base, params, calib_stats, args, rng, report):
    """Part 6: bursty heavy-tail trace through the SLA scheduler
    (DESIGN.md §11) — deterministic tick-clocked TTFT / inter-token-latency
    percentiles, plus an overload arm behind admission control."""
    sys_len, tail_hi = args.shared_prefix, 8
    trace = make_bursty_trace(rng, args.requests)
    pattern = np.arange(sys_len + tail_hi + PERIOD) % PERIOD + TOK0
    prompts = [pattern[: sys_len + n] for _, n in trace]
    max_seq = sys_len + tail_hi + args.gen

    cfg = base.with_quant(softmax_impl="exaq", bits=2)
    qstate = build_model(cfg).qstate_from_stats(calib_stats)
    config = EngineConfig(max_slots=args.slots, max_seq=max_seq, seed=0,
                          steps_per_sync=1, block_size=args.block_size,
                          prefill_chunk=args.prefill_chunk, kv_dtype="bf16")

    eng = PagedEngine(cfg, params, config, qstate=qstate)
    uid_of, rejections, token_ticks, results = _replay_streaming(
        eng, trace, prompts, args.gen)
    assert not rejections, "no admission limits were set; nothing may be rejected"
    assert all(len(results[u].tokens) == args.gen for u in uid_of.values())
    ttfts = np.array([eng.ttft[u] for u in uid_of.values()])
    itls = np.concatenate([np.diff(t) for t in token_ticks.values()])
    bursty = {
        "requests": len(trace),
        "bursts": len(set(a for a, _ in trace)),
        "p50_ttft_steps": float(np.percentile(ttfts, 50)),
        "p99_ttft_steps": float(np.percentile(ttfts, 99)),
        "p50_itl_steps": float(np.percentile(itls, 50)),
        "p99_itl_steps": float(np.percentile(itls, 99)),
        "preemptions": eng.stats["preemptions"],
    }
    print(f"bursty trace: {bursty['requests']} requests in {bursty['bursts']} bursts, "
          f"2 priority classes, chunk=1; TTFT p50/p99 "
          f"{bursty['p50_ttft_steps']:.0f}/{bursty['p99_ttft_steps']:.0f} ticks, "
          f"inter-token p50/p99 {bursty['p50_itl_steps']:.0f}/"
          f"{bursty['p99_itl_steps']:.0f} ticks "
          f"(deterministic scheduler ticks: decode steps + prefill chunks)")

    # overload arm: the same trace behind a max_inflight admission cap — the
    # cap must shed as structured retryable rejections, never grow the queue,
    # and everything it admits must still complete
    import dataclasses
    cap = args.slots
    eng2 = PagedEngine(cfg, params, dataclasses.replace(config, max_inflight=cap),
                       qstate=qstate)
    uid2, rej2, _, res2 = _replay_streaming(eng2, trace, prompts, args.gen)
    assert rej2, f"bursts of 4 behind max_inflight={cap} must shed something"
    assert all(len(res2[u].tokens) == args.gen for u in uid2.values())
    all_retryable = all(
        r.reason == "max_inflight" and r.retryable and r.backoff_hint > 0
        for r in rej2.values()
    )
    assert all_retryable, "admission-control sheds must be retryable with a backoff hint"
    bursty["overload"] = {
        "max_inflight": cap,
        "completed": len(uid2),
        "shed": len(rej2),
        "all_shed_retryable": all_retryable,
    }
    print(f"overload arm (max_inflight={cap}): {len(uid2)} completed, "
          f"{len(rej2)} shed — all structured retryable with backoff hints")
    report["bursty"] = bursty


def bench_spec(base, params, calib_stats, args, rng, report):
    """Part 7: speculative decoding on the paged pool (DESIGN.md §12).

    The shared-prefix Poisson trace replays twice through the paged engine:
    vanilla greedy decode, then self-drafting speculation (``spec_k=4``,
    n-gram drafter). Greedy accept/reject on the exact fused-verify logits
    is bit-reproducible, so the spec arm must emit the vanilla tokens
    exactly (asserted and gated). The speedup claim is target-model
    forwards per emitted token: a vanilla decode step is one forward, a
    spec round is one fused verify forward that can emit up to k+1 tokens —
    the ratio of the two steps-per-token figures is gated as a floor and
    must clear 1.5x at k=4 on this trace."""
    spec_k, drafter = 4, "ngram"
    sys_len, tail_lo, tail_hi = args.shared_prefix, 1, 8
    trace = make_trace(rng, args.requests, args.paged_rate, tail_lo, tail_hi)
    pattern = np.arange(sys_len + tail_hi + PERIOD) % PERIOD + TOK0
    prompts = [pattern[: sys_len + n] for _, n in trace]
    max_seq = sys_len + tail_hi + args.gen

    cfg = base.with_quant(softmax_impl="exaq", bits=2)
    qstate = build_model(cfg).qstate_from_stats(calib_stats)
    kw = dict(slots=args.slots, max_seq=max_seq, gen=args.gen, chunk=args.chunk,
              paged=True, block_size=args.block_size, prefill_chunk=args.prefill_chunk)
    vanilla, van_out = run_trace(cfg, params, qstate, trace, prompts, **kw)
    spec, spec_out = run_trace(cfg, params, qstate, trace, prompts,
                               spec_k=spec_k, drafter=drafter, **kw)
    parity = all(van_out[i] == spec_out[i] for i in range(len(trace)))
    st = spec.stats
    # target-model forwards per emitted decode token, both arms; the first
    # token per request is sampled at prefill admission in both, so it is
    # excluded from both denominators
    van_tokens = vanilla.stats["tokens_out"] - vanilla.stats["prefills"]
    van_spt = vanilla.stats["decode_steps"] / max(van_tokens, 1)
    spec_spt = st["spec_rounds"] / max(st["spec_emitted"], 1)
    reduction = van_spt / spec_spt
    accepted_per_verify = st["spec_accepted"] / max(st["spec_rounds"], 1)
    print(f"spec_k={spec_k} ({drafter} drafter): greedy parity vs vanilla: {parity}; "
          f"{st['spec_rounds']} verify rounds emitted {st['spec_emitted']} tokens "
          f"({st['spec_accepted']}/{st['spec_drafted']} drafts accepted, "
          f"{accepted_per_verify:.2f} accepted/verify)")
    print(f"{'':14s} steps/token {van_spt:.3f} vanilla -> {spec_spt:.3f} spec "
          f"= {reduction:.2f}x fewer target-model steps per token")
    assert parity, "speculative decode diverged from vanilla greedy tokens"
    assert reduction >= 1.5, (
        f"spec_k={spec_k} cut target-model steps per token only {reduction:.2f}x (< 1.5x)"
    )
    report["spec"] = {
        "spec_k": spec_k,
        "drafter": drafter,
        "greedy_parity_vs_vanilla": parity,
        "rounds": st["spec_rounds"],
        "drafted": st["spec_drafted"],
        "accepted": st["spec_accepted"],
        "tokens": st["spec_emitted"],
        "accepted_per_verify": accepted_per_verify,
        "steps_per_token_reduction_x": reduction,
    }


def bench_state_archs(args, report):
    """Part 8: architecture-agnostic StatePool serving (DESIGN.md §13).

    Serving traces for every non-dense decoder family through the paged
    engine — pure-SSM (``mamba2-1.3b``, per-slot recurrent-state + conv-tail
    planes checkpointed at block granularity), MoE (``deepseek-moe-16b``,
    no pool state but router dispatch batched across live slots), and
    hybrid (``zamba2-2.7b``, attention K/V planes and SSM planes side by
    side in one pool) — each gated on exact greedy-token parity against its
    unpaged reference (``serve.generate``'s rectangular loop for the state
    families, the slot engine for MoE) plus a mean-occupancy floor.

    Models are reduced random-init fp32: the gate compares two fp32
    computation paths over the *same* weights, where argmax margins sit
    orders of magnitude above the fp-noise between chunked and rectangular
    attention, so the trained smoke head (needed for the *quantized*
    agreement floors elsewhere) buys nothing here. State families serve
    with ``ssm_chunk=1`` — the block-checkpoint bitwise-reproducibility
    requirement the engine enforces — and an fp32 pool (state planes are
    never quantized)."""
    import dataclasses

    from repro.runtime import serve as serve_rt
    from repro.runtime.engine import EngineConfig
    from repro.runtime.engine_core import Request

    ARCHS = {
        "mamba2-1.3b": {"num_layers": 2},
        "deepseek-moe-16b": {"num_layers": 2},
        # 2 mamba blocks + the weight-shared attention block = smallest
        # config exercising both plane groups in one pool
        "zamba2-2.7b": {"num_layers": 2, "hybrid_period": 2},
    }
    sys_len, tail, B, slots, gen, bs = 12, 3, 6, 3, 8, 4
    report["state_archs"] = {}
    for arch, overrides in ARCHS.items():
        cfg = get_config(arch).reduced(**overrides)
        if cfg.family in ("ssm", "hybrid"):
            cfg = dataclasses.replace(cfg, ssm_chunk=1)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed), jnp.float32)
        rng = np.random.default_rng(args.seed)
        prefix = rng.integers(1, cfg.vocab_size, size=(sys_len,))
        prompts = [np.concatenate([prefix, rng.integers(1, cfg.vocab_size, (tail,))])
                   for _ in range(B)]

        rect = np.asarray(serve_rt.generate(
            params, cfg, jnp.asarray(np.stack(prompts)), gen, kv_dtype="fp32"))

        config = EngineConfig(max_slots=slots, max_seq=sys_len + tail + gen,
                              block_size=bs, prefill_chunk=2 * bs, kv_dtype="fp32")
        eng = PagedEngine(cfg, params, config)
        uids = [eng.submit(Request(p, gen)) for p in prompts]
        results = eng.run()
        parity = all(list(results[u].tokens) == rect[b].tolist()
                     for b, u in enumerate(uids))
        occ = eng.mean_occupancy
        hit = eng.prefix_hit_rate
        print(f"{arch:18s} ({cfg.family:6s}): greedy parity vs unpaged: {parity}; "
              f"occupancy {occ:.2f}/{slots}, prefix-cache hit rate {100*hit:.1f}% "
              f"({B} requests, {sys_len}-token shared prefix, ssm_chunk="
              f"{cfg.ssm_chunk if cfg.family in ('ssm', 'hybrid') else '-'})")
        assert parity, f"{arch}: paged StatePool diverged from the unpaged reference"
        assert occ > 1.0, f"{arch}: trace never batched ({occ:.2f} mean occupancy)"
        report["state_archs"][arch] = {
            "family": cfg.family,
            "greedy_parity_vs_unpaged": parity,
            "mean_occupancy": occ,
            "prefix_hit_rate": hit,
            "preemptions": eng.stats["preemptions"],
        }


def bench_paged_decode_micro(base, params, args, report):
    """Part 3: fused paged-decode kernel vs HBM gather, one jitted step.

    Greedy-parity of the two paths is covered by the tier-1 suite
    (tests/test_paged_attention.py); here the claims are bandwidth and
    latency. The bytes model counts HBM traffic for the per-layer decode
    attention KV read: the gather path reads each slot's live blocks from
    the pool, writes the dense rectangular per-slot copy, and reads it back
    (each for K and V); the fused kernel touches live blocks only — K twice
    (max + accumulate pass), V once."""
    import time

    from repro.kernels.exaq_paged_attention import paged_decode_bytes_model
    from repro.models import build_model

    S, bs = args.slots, args.block_size
    max_seq = 4 * bs  # 4 blocks per table keeps interpret-mode compile sane
    MB = max_seq // bs
    rng = np.random.default_rng(args.seed)
    lens = np.full((S,), max_seq // 2, np.int32)  # 50% average occupancy
    tables = (1 + np.arange(S * MB, dtype=np.int32)).reshape(S, MB)  # disjoint live tables
    tokens = rng.integers(0, base.vocab_size, (S, 1)).astype(np.int32)
    active = np.ones((S,), bool)

    micro = {"slots": S, "block_size": bs, "max_blocks": MB,
             "occupancy": float(lens.mean() / max_seq)}
    for label, fused, dt in (("fused", True, jnp.bfloat16),
                             ("gather", False, jnp.bfloat16),
                             ("fused_int8", True, jnp.int8),
                             ("fused_int4", True, "int4")):
        cfg = base.with_quant(softmax_impl="exaq", bits=2, use_fused_kernel=fused)
        model = build_model(cfg)
        pool = model.init_block_pool(1 + S * MB, bs, dt)
        step = jax.jit(lambda pr, tk, pl_, tb, ln, ac, m=model: m.decode_step_paged(
            pr, tk, pl_, tb, ln, ac))
        a = (params, jnp.asarray(tokens), pool, jnp.asarray(tables),
             jnp.asarray(lens), jnp.asarray(active))
        jax.block_until_ready(step(*a)[0])  # compile
        iters = 5
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(step(*a)[0])
        micro[f"{label}_step_ms"] = 1e3 * (time.perf_counter() - t0) / iters

    kw = dict(slots=S, kv_heads=base.num_kv_heads, max_blocks=MB, block_size=bs,
              head_dim=base.resolved_head_dim, kv_lens=lens)
    m = paged_decode_bytes_model(kv_dtype="bf16", **kw)
    m_int8 = paged_decode_bytes_model(kv_dtype="int8", **kw)
    m_int4 = paged_decode_bytes_model(kv_dtype="int4", **kw)
    micro["modeled_per_layer"] = m
    micro["modeled_per_layer_int8"] = m_int8
    micro["modeled_per_layer_int4"] = m_int4
    micro["modeled_step_gather_bytes"] = m["gather_then_read_bytes"] * base.num_layers
    micro["modeled_step_fused_bytes"] = m["fused_pool_read_bytes"] * base.num_layers
    micro["modeled_step_fused_int8_bytes"] = m_int8["fused_pool_read_bytes"] * base.num_layers
    micro["modeled_step_fused_int4_bytes"] = m_int4["fused_pool_read_bytes"] * base.num_layers
    micro["bytes_reduction_x"] = m["bytes_reduction_x"]
    micro["int8_vs_bf16_bytes_reduction_x"] = (
        m["fused_pool_read_bytes"] / m_int8["fused_pool_read_bytes"]
    )
    micro["int4_vs_int8_bytes_reduction_x"] = (
        m_int8["fused_pool_read_bytes"] / m_int4["fused_pool_read_bytes"]
    )
    micro["int4_vs_bf16_bytes_reduction_x"] = (
        m["fused_pool_read_bytes"] / m_int4["fused_pool_read_bytes"]
    )
    print(f"paged-decode micro ({S} slots, {MB}x{bs}-token blocks, "
          f"{100*micro['occupancy']:.0f}% occupancy): "
          f"modeled KV bytes/step {micro['modeled_step_gather_bytes']} gather -> "
          f"{micro['modeled_step_fused_bytes']} fused ({m['bytes_reduction_x']:.1f}x less) -> "
          f"{micro['modeled_step_fused_int8_bytes']} fused-int8 "
          f"({micro['int8_vs_bf16_bytes_reduction_x']:.2f}x less than bf16, scales counted) -> "
          f"{micro['modeled_step_fused_int4_bytes']} fused-int4 "
          f"({micro['int4_vs_int8_bytes_reduction_x']:.2f}x less than int8, "
          f"{micro['int4_vs_bf16_bytes_reduction_x']:.2f}x less than bf16); "
          f"measured step {micro['gather_step_ms']:.1f} ms gather vs "
          f"{micro['fused_step_ms']:.1f} ms fused / {micro['fused_int8_step_ms']:.1f} ms "
          f"fused-int8 / {micro['fused_int4_step_ms']:.1f} ms fused-int4 "
          f"(CPU: fused runs interpret-mode Pallas — latency is directional)")
    assert m["bytes_reduction_x"] >= 2.0, (
        f"fused paged decode must cut modeled KV bytes >= 2x at 50% occupancy, "
        f"got {m['bytes_reduction_x']:.2f}x"
    )
    assert micro["int8_vs_bf16_bytes_reduction_x"] >= 1.8, (
        f"int8 pool must cut modeled fused KV bytes >= 1.8x vs bf16 at 50% occupancy, "
        f"got {micro['int8_vs_bf16_bytes_reduction_x']:.2f}x"
    )
    assert micro["int4_vs_int8_bytes_reduction_x"] >= 1.8, (
        f"packed int4 must cut modeled fused KV bytes >= 1.8x vs int8, "
        f"got {micro['int4_vs_int8_bytes_reduction_x']:.2f}x"
    )
    assert micro["int4_vs_bf16_bytes_reduction_x"] >= 3.5, (
        f"packed int4 must cut modeled fused KV bytes >= 3.5x vs bf16, "
        f"got {micro['int4_vs_bf16_bytes_reduction_x']:.2f}x"
    )
    report["paged_decode_micro"] = micro
    return micro


def bench_paged_prefill_micro(base, params, args, micro):
    """Part 3b: fused paged-prefill kernel vs window gather, one jitted chunk.

    Parity is covered by the tier-1 suite (tests/test_paged_prefill.py);
    here the claims are bandwidth and latency (DESIGN.md §7). The bytes
    model sums, over all chunks of one prompt, the HBM traffic of the
    per-layer window read: the gather path reads the window's live blocks,
    writes the dense rectangular copy, and attention reads it back — every
    chunk, so copy bytes grow with the square of the prompt — while the
    fused kernel touches live blocks only (K twice, V once). Asserted
    >= 2x with the prompt filling 50% of the padded window."""
    import time

    from repro.kernels.exaq_paged_prefill import paged_prefill_bytes_model
    from repro.models import build_model

    bs, MB = args.block_size, 8
    P = MB * bs // 2  # prompt fills 50% of the window, prefilled in >1 chunk
    C = min(args.prefill_chunk, P)  # clamp so the timed chunk stays inside the
    start = P - C                   # modeled prompt (last = widest-window chunk)
    rng = np.random.default_rng(args.seed)
    tokens = rng.integers(0, base.vocab_size, (1, C)).astype(np.int32)
    table = np.arange(1, MB + 1, dtype=np.int32)
    pos = start + np.arange(C)
    blk_t = table[np.minimum(pos // bs, MB - 1)].astype(np.int32)
    off_t = (pos % bs).astype(np.int32)

    pre = {"block_size": bs, "max_blocks": MB, "prefill_chunk": C, "prompt_len": P,
           "occupancy": P / (MB * bs)}
    for label, fused, dt in (("fused", True, jnp.bfloat16),
                             ("gather", False, jnp.bfloat16),
                             ("fused_int8", True, jnp.int8),
                             ("fused_int4", True, "int4")):
        cfg = base.with_quant(softmax_impl="exaq", bits=2, use_fused_kernel=fused)
        model = build_model(cfg)
        pool = model.init_block_pool(1 + MB, bs, dt)
        step = jax.jit(lambda pr, tk, pl_, tb, st, cl, bt, ot, m=model:
                       m.prefill_paged_chunk(pr, tk, pl_, tb, st, cl, bt, ot))
        a = (params, jnp.asarray(tokens), pool, jnp.asarray(table),
             jnp.asarray(start, jnp.int32), jnp.asarray(C, jnp.int32),
             jnp.asarray(blk_t), jnp.asarray(off_t))
        jax.block_until_ready(step(*a)[0])  # compile
        iters = 5
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(step(*a)[0])
        pre[f"{label}_chunk_ms"] = 1e3 * (time.perf_counter() - t0) / iters

    kw = dict(prompt_len=P, chunk=C, kv_heads=base.num_kv_heads, max_blocks=MB,
              block_size=bs, head_dim=base.resolved_head_dim)
    m = paged_prefill_bytes_model(kv_dtype="bf16", **kw)
    m_int8 = paged_prefill_bytes_model(kv_dtype="int8", **kw)
    m_int4 = paged_prefill_bytes_model(kv_dtype="int4", **kw)
    pre["modeled_per_layer"] = m
    pre["modeled_per_layer_int8"] = m_int8
    pre["modeled_per_layer_int4"] = m_int4
    pre["modeled_prefill_gather_bytes"] = m["gather_then_attend_bytes"] * base.num_layers
    pre["modeled_prefill_fused_bytes"] = m["fused_pool_read_bytes"] * base.num_layers
    pre["bytes_reduction_x"] = m["bytes_reduction_x"]
    pre["int8_vs_bf16_bytes_reduction_x"] = (
        m["fused_pool_read_bytes"] / m_int8["fused_pool_read_bytes"]
    )
    pre["int4_vs_int8_bytes_reduction_x"] = (
        m_int8["fused_pool_read_bytes"] / m_int4["fused_pool_read_bytes"]
    )
    pre["int4_vs_bf16_bytes_reduction_x"] = (
        m["fused_pool_read_bytes"] / m_int4["fused_pool_read_bytes"]
    )
    print(f"paged-prefill micro ({P}-token prompt in {m['chunks']} chunks of {C}, "
          f"{MB}x{bs}-token window, {100*pre['occupancy']:.0f}% occupancy): "
          f"modeled KV bytes/prefill {pre['modeled_prefill_gather_bytes']} gather -> "
          f"{pre['modeled_prefill_fused_bytes']} fused ({m['bytes_reduction_x']:.1f}x less); "
          f"measured chunk {pre['gather_chunk_ms']:.1f} ms gather vs "
          f"{pre['fused_chunk_ms']:.1f} ms fused / {pre['fused_int8_chunk_ms']:.1f} ms "
          f"fused-int8 / {pre['fused_int4_chunk_ms']:.1f} ms fused-int4 "
          f"(CPU: fused runs interpret-mode Pallas — latency is directional)")
    assert m["bytes_reduction_x"] >= 2.0, (
        f"fused paged prefill must cut modeled KV bytes >= 2x at 50% occupancy, "
        f"got {m['bytes_reduction_x']:.2f}x"
    )
    assert pre["int8_vs_bf16_bytes_reduction_x"] >= 1.8, (
        f"int8 pool must cut modeled fused prefill KV bytes >= 1.8x vs bf16, "
        f"got {pre['int8_vs_bf16_bytes_reduction_x']:.2f}x"
    )
    assert pre["int4_vs_int8_bytes_reduction_x"] >= 1.8, (
        f"packed int4 must cut modeled fused prefill KV bytes >= 1.8x vs int8, "
        f"got {pre['int4_vs_int8_bytes_reduction_x']:.2f}x"
    )
    assert pre["int4_vs_bf16_bytes_reduction_x"] >= 3.5, (
        f"packed int4 must cut modeled fused prefill KV bytes >= 3.5x vs bf16, "
        f"got {pre['int4_vs_bf16_bytes_reduction_x']:.2f}x"
    )
    micro["prefill"] = pre
    return pre


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5, help="arrivals per decode step")
    ap.add_argument("--chunk", type=int, default=4, help="decode steps per jitted chunk")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="system-prompt tokens shared by every request (paged part)")
    ap.add_argument("--paged-rate", type=float, default=0.25,
                    help="arrivals per decode step for the shared-prefix trace")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--json", default=None, help="write all metrics to this path")
    ap.add_argument("--micro-json", default=None,
                    help="write the paged-decode microbenchmark metrics alone to this path")
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    base, params, loss = make_smoke_model(args.arch)
    report = {"arch": base.name, "train_loss": loss, "requests": args.requests,
              "slots": args.slots, "gen": args.gen, "impls": {}, "paged": {}}

    print(f"arch={base.name} (2-layer smoke, train loss {loss:.4f}) "
          f"requests={args.requests} slots={args.slots} gen={args.gen} "
          f"Poisson rate={args.rate}/step")
    calib_stats = calibrate_smoke(base, params)
    bench_impl_agreement(base, params, calib_stats, args, rng, report)

    print(f"--- shared-prefix trace: {args.shared_prefix} system tokens, "
          f"rate={args.paged_rate}/step, block_size={args.block_size} ---")
    bench_paged(base, params, calib_stats, args, rng, report)

    print("--- paged-decode microbenchmark: fused kernel vs HBM gather ---")
    micro = bench_paged_decode_micro(base, params, args, report)

    print("--- paged-prefill microbenchmark: fused kernel vs window gather ---")
    bench_paged_prefill_micro(base, params, args, micro)

    print("--- int8/int4 KV pools: greedy parity + memory vs fp32 (DESIGN.md §6/§10) ---")
    bench_kv_dtype(base, params, calib_stats, args, rng, report)

    print("--- data-parallel fleet: 2 replicas vs single engine (DESIGN.md §9) ---")
    bench_dp(base, params, calib_stats, args, rng, report)

    print("--- bursty arrivals: tick-clocked TTFT/ITL + admission control (DESIGN.md §11) ---")
    bench_bursty(base, params, calib_stats, args, rng, report)

    print("--- speculative decoding: n-gram drafts + fused verify (DESIGN.md §12) ---")
    bench_spec(base, params, calib_stats, args, rng, report)

    print("--- StatePool architectures: mamba2 / moe / hybrid paged serving (DESIGN.md §13) ---")
    bench_state_archs(args, report)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote metrics to {args.json}")
    if args.micro_json:
        with open(args.micro_json, "w") as f:
            json.dump(micro, f, indent=2)
        print(f"wrote paged-decode micro metrics to {args.micro_json}")
    print("OK: >=2 concurrent ragged requests per jitted step; EXAQ-2bit greedy == exact; "
          ">=50% prefix-cache hits with slot-engine parity on the paged engine; "
          ">=2x modeled KV bytes cut by the fused paged-decode AND paged-prefill kernels; "
          ">=1.8x further cut and >=99% greedy agreement on the int8 pool; "
          ">=1.8x beyond int8 (>=3.5x vs bf16) and >=99% agreement on the packed-int4 pool; "
          "bit-exact dp=2 fleet parity with both replicas served; "
          "bursty trace served with every admission-control shed structured + retryable; "
          "bit-exact speculative decode with >=1.5x fewer target-model steps per token at k=4; "
          "greedy parity vs the unpaged reference for mamba2/moe/hybrid StatePool serving")


if __name__ == "__main__":
    main()
