"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract; the
"derived" column carries each experiment's headline quantity. Detailed
records land in EXPERIMENTS.md.
"""

from __future__ import annotations

import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, 1e6 * (time.perf_counter() - t0)


def main() -> None:
    import benchmarks.bench_accuracy as acc
    import benchmarks.bench_calibration as cal
    import benchmarks.bench_clipping as clp
    import benchmarks.bench_roofline as roof
    import benchmarks.bench_runtime as rt

    print("name,us_per_call,derived")

    rows, us = _timed(clp.run, fast=True)
    for r in rows:
        print(f"table1_fig3_clipping_M{r['bits']},{us/2:.0f},"
              f"analytic_fit={r['fit_analytic'][0]}s{r['fit_analytic'][1]:+}"
              f"|paper={r['paper_table1'][0]}s{r['paper_table1'][1]:+}")

    res, us = _timed(cal.run, train_steps=40)
    sig = res["trained_small_lm"]
    print(f"fig6_sigma_range,{us:.0f},trained_lm_sigma=[{min(sig):.2f}..{max(sig):.2f}]")

    res, us = _timed(acc.run, train_steps=120)
    print(f"table2_accuracy_proxy,{us:.0f},"
          f"ppl_exact={res['exact']:.2f}|exaq2={res['exaq_paper_int2']:.2f}"
          f"|naive2={res['naive_int2']:.2f}|exaq3={res['exaq_paper_int3']:.2f}"
          f"|naive3={res['naive_int3']:.2f}")

    t3, us = _timed(rt.table3)
    cal = [r for r in t3 if r["exp_cycles"] == 4][0]   # Gaudi-2-effective exp cost
    hi = [r for r in t3 if r["exp_cycles"] == 12][0]
    print(f"table3_softmax_cycles,{us:.0f},speedup={cal['speedup_pct']}%_paper=36.9%_(upper_bound_{hi['speedup_pct']}%_at_12cyc)")
    wc, us = _timed(rt.wallclock)
    print(f"table3_wallclock_cpu,{us:.0f},exact_us={wc['exact_us']:.0f}|exaq_us={wc['exaq_us']:.0f}")
    f1, us = _timed(rt.figure1)
    print(f"fig1_op_shares,{us:.0f},softmax_share={f1['softmax']}%")

    try:
        rows, us = _timed(roof.table)
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        print(f"roofline_cells,{us:.0f},cells={len(rows)}"
              f"|best={best['arch']}/{best['shape']}={best['roofline_fraction']}"
              f"|worst={worst['arch']}/{worst['shape']}={worst['roofline_fraction']}")
    except Exception as e:  # dry-run artifacts absent
        print(f"roofline_cells,0,unavailable({type(e).__name__})")


if __name__ == "__main__":
    main()
