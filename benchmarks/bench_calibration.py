"""Paper Figure 6: standard deviation of softmax inputs across layers.

The paper collects sigma in [0.9, 3.4] across LLaMA layers/iterations (the
range Table 1 is fitted over). We reproduce the *procedure* on in-repo
models: per-layer sigma from the calibration probe on (a) a briefly-trained
small LM and (b) random-init reduced configs of the assigned archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.optim.adamw import AdamW
from repro.runtime.train import init_train_state, make_train_step


def run(train_steps: int = 60, seed: int = 0):
    out = {}
    base = get_config("internlm2-1.8b").reduced(num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    cfg = base.with_quant(softmax_impl="exact")
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=seed)
    opt = AdamW(lr=3e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, opt))
    for _ in range(train_steps):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in data.next_batch().items()})
    model = build_model(cfg)
    st = model.calibrate(state["params"], {k: jnp.asarray(v) for k, v in data.next_batch().items()})
    out["trained_small_lm"] = [round(float(s), 3) for s in np.asarray(st["attn_sigma"])]

    for arch in ("yi-6b", "qwen3-32b", "deepseek-moe-16b", "internvl2-1b"):
        c = get_config(arch).reduced().with_quant(softmax_impl="exact")
        m = build_model(c)
        params = m.init(jax.random.PRNGKey(seed))
        rng = np.random.default_rng(seed)
        batch = {"tokens": jnp.asarray(rng.integers(0, c.vocab_size, (2, 64)), jnp.int32)}
        if c.frontend == "vlm":
            batch["vision_embeds"] = jnp.asarray(rng.normal(0, 1, (2, c.frontend_tokens, c.frontend_dim)), jnp.float32)
        st = m.calibrate(params, batch)
        out[arch] = [round(float(s), 3) for s in np.asarray(st["attn_sigma"])]
    return out


def main():
    res = run()
    for k, v in res.items():
        print(f"  {k}: sigma per layer = {v}")
    return res


if __name__ == "__main__":
    main()
