"""Paper Table 3 (36.9% softmax speedup) + Figure 1 (runtime shares).

No Gaudi-2 offline, so Table 3 is reproduced with the paper's own cycle
model (§4 + footnote 3):

  original per element : exp 5-12 cycles (we take 8) + 1 accumulate + 1 div
  EXAQ    per element  : quantize 3/N amortized? -> paper: quantize is a
                         3-cycle *vector* op on the whole tensor; LUT_exp
                         1 cycle; accumulation N/4 (LUT_sum packs 4 codes).

We report the cycle-model speedup for a LLaMA-2-7B decode-attention softmax
and a sweep over exp-cost assumptions, showing the paper's 36.9% sits inside
the model's range. A wall-clock XLA-CPU microbenchmark of exact vs Algo.-2
softmax is included as directional evidence (CPU backend; documented caveat).

Figure 1 is reproduced analytically: per-op time shares for LLaMA-2-7B-class
decode under a v5e bandwidth/compute model, with GEMMs in BF16 — showing
softmax as a major non-GEMM cost once attention GEMMs are fast.

The paged-decode section extends the same analytic treatment to the serving
runtime (DESIGN.md §3, fused paged decode): per-step HBM KV bytes for a
LLaMA-7B-class paged decode batch, gather-then-read vs the fused Pallas
kernel's direct pool reads, swept over occupancy — the bandwidth the fused
kernel deletes is the term that dwarfed the softmax win the rest of this
file measures.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exaq_params
from repro.core.softmax import exact_softmax, quantized_softmax


def cycle_model(n: int, exp_cycles: int = 4, bits: int = 2):
    """Per-row softmax cycles, original (Algo. 1) vs EXAQ (Algo. 2).

    Both include the phases EXAQ does NOT accelerate (max-subtract pass and
    per-element normalization divide), as the paper's Table 3 measures the
    whole softmax op. exp_cycles=4 is the Gaudi-2-effective exponent cost
    that reproduces the measured 36.9%; the 5-12 range is the paper's
    footnote-3 hardware spread (upper bounds).
    """
    # Algo 1: max pass + N exps (multi-cycle) + N accumulates + N divides
    orig = n * 1 + n * exp_cycles + n * 1 + n * 1
    # Algo 2: max pass + quantize pass + N LUT (1 cycle)
    #         + N/4 accumulates (LUT_sum) + N divides
    pack = 8 // bits  # codes per byte
    ours = n * 1 + n * 1 + n * 1 + (n // pack) * 1 + n * 1
    return orig, ours


def table3(n: int = 4096):
    rows = []
    for exp_c in (4, 8, 12):
        o, q = cycle_model(n, exp_c)
        rows.append({"exp_cycles": exp_c, "orig": o, "exaq": q, "speedup_pct": round(100 * (1 - q / o), 1)})
    return rows


def wallclock(n: int = 4096, rows: int = 256, iters: int = 30):
    p = exaq_params(2.0, 2)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 2, (rows, n)), jnp.float32)
    f_exact = jax.jit(lambda t: exact_softmax(t))
    f_exaq = jax.jit(lambda t: quantized_softmax(t, p))
    f_exact(x).block_until_ready()
    f_exaq(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f_exact(x).block_until_ready()
    t1 = time.perf_counter()
    for _ in range(iters):
        f_exaq(x).block_until_ready()
    t2 = time.perf_counter()
    return {"exact_us": 1e6 * (t1 - t0) / iters, "exaq_us": 1e6 * (t2 - t1) / iters}


def figure1(seq: int = 4096, d_model: int = 4096, n_heads: int = 32, d_ff: int = 11008,
            exp_ops: float = 10.0, vpu_tops: float = 2e12):
    """Analytic op-level time shares for LLaMA-7B-class PREFILL (the regime of
    the paper's Fig. 1): GEMMs run on the MXU at BF16 peak, softmax runs on
    the VPU over the H x S^2 score matrix with a multi-op exp chain, plus the
    score-matrix HBM round-trips of an unfused attention."""
    PEAK, BW = 197e12, 819e9
    t = {}
    # per-layer GEMM flops: qkvo projections + mlp + attention dots
    proj_flops = 2 * seq * (4 * d_model * d_model + 3 * d_model * d_ff)
    attn_dots = 4 * seq * seq * d_model  # QK^T + PV (causal halves it; keep upper bound)
    t["gemm_mxu"] = (proj_flops + attn_dots) / PEAK
    # softmax: H*S^2 elements, ~exp_ops VPU ops each + 3 HBM round-trips unfused
    elems = n_heads * seq * seq
    t["softmax"] = elems * exp_ops / vpu_tops + 3 * elems * 4 / BW
    t["norm_misc"] = (8 * seq * d_model * 4) / BW
    tot = sum(t.values())
    return {k: round(100 * v / tot, 1) for k, v in t.items()}


def paged_decode_bytes(slots: int = 32, max_seq: int = 4096, block_size: int = 16,
                       kv_heads: int = 32, head_dim: int = 128, layers: int = 32,
                       occupancies=(0.25, 0.5, 1.0),
                       kv_dtypes=("fp32", "bf16", "int8")):
    """Per-decode-step HBM KV bytes for a LLaMA-7B-class paged batch: the
    gather path's 3 rectangular passes vs the fused kernel's live-block
    reads, swept over mean occupancy AND pool storage dtype (DESIGN.md
    §3/§6) — the element size is taken from ``kv_dtype`` (int8 includes the
    per-block scale reads), not hardcoded."""
    from repro.kernels.exaq_paged_attention import paged_decode_bytes_model

    mb = max_seq // block_size
    rows = []
    for dt in kv_dtypes:
        for occ in occupancies:
            lens = np.full((slots,), int(occ * max_seq), np.int64)
            m = paged_decode_bytes_model(slots=slots, kv_heads=kv_heads, max_blocks=mb,
                                         block_size=block_size, head_dim=head_dim,
                                         kv_lens=lens, kv_dtype=dt)
            rows.append({
                "kv_dtype": dt,
                "occupancy": occ,
                "gather_gb_per_step": round(layers * m["gather_then_read_bytes"] / 1e9, 2),
                "fused_gb_per_step": round(layers * m["fused_pool_read_bytes"] / 1e9, 2),
                "reduction_x": round(m["bytes_reduction_x"], 2),
            })
    return rows


def main():
    print("Table 3 (cycle model, N=4096):")
    for r in table3():
        print(f"  exp={r['exp_cycles']}cyc: orig={r['orig']} exaq={r['exaq']} speedup={r['speedup_pct']}% (paper: 36.9%)")
    wc = wallclock()
    print(f"wall-clock (XLA-CPU, informational): exact={wc['exact_us']:.0f}us exaq={wc['exaq_us']:.0f}us")
    print("Figure 1 (analytic decode op shares, %):", figure1())
    pdb_rows = paged_decode_bytes()
    print("paged decode KV bytes/step (LLaMA-7B-class, 32 slots x 4k seq):")
    for r in pdb_rows:
        print(f"  {r['kv_dtype']:5s} occupancy {int(100*r['occupancy'])}%: "
              f"gather {r['gather_gb_per_step']} GB "
              f"-> fused {r['fused_gb_per_step']} GB ({r['reduction_x']}x less)")
    return {"table3": table3(), "wallclock": wc, "figure1": figure1(),
            "paged_decode_bytes": pdb_rows}


if __name__ == "__main__":
    main()
