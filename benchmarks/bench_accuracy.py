"""Paper Table 2 (accuracy, EXAQ vs NAIVE) — offline-reproducible proxy.

LLaMA checkpoints / lm-eval-harness are unavailable offline, so the claim
is reproduced at reachable scale, preserving the protocol:

  1. Train a small LM in-repo (exact softmax — PTQ setting).
  2. Calibrate per-layer sigma/min on a held-out calibration set
     (paper: 100 samples).
  3. Evaluate held-out perplexity with the softmax swapped for:
     exact | EXAQ(paper rule) | EXAQ(analytic rule) | NAIVE, at INT2/INT3.

Expected ordering (paper Table 2): EXAQ ~= exact, NAIVE degraded,
degradation worse at INT2 than INT3.

Also: a zero-training probe across all 10 assigned archs — random-init logit
MSE vs exact softmax for each method (fast sanity sweep).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.optim.adamw import AdamW
from repro.runtime.train import init_train_state, make_loss_fn, make_train_step


def _eval_ppl(cfg, params, batches, qstate=None):
    loss_fn = make_loss_fn(cfg, qstate, compute_dtype=jnp.float32)
    f = jax.jit(loss_fn)
    tot = 0.0
    for b in batches:
        loss, _ = f(params, b)
        tot += float(loss)
    return math.exp(tot / len(batches))


def run(train_steps: int = 150, seed: int = 0):
    base = get_config("internlm2-1.8b").reduced(num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    cfg_train = base.with_quant(softmax_impl="exact")
    B, S = 8, 64
    data = SyntheticLMData(base.vocab_size, S, B, seed=seed)
    opt = AdamW(lr=3e-3, weight_decay=0.01)
    state = init_train_state(cfg_train, opt, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg_train, opt))
    for _ in range(train_steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data.next_batch().items()})
    params = state["params"]

    # calibration set (paper: ~100 samples)
    model = build_model(cfg_train)
    calib_batches = [{k: jnp.asarray(v) for k, v in data.next_batch().items()} for _ in range(4)]
    stats_acc = None
    for cb in calib_batches:
        st = model.calibrate(params, cb)
        st = {k: np.asarray(v, np.float64) for k, v in st.items()}
        if stats_acc is None:
            stats_acc = {k: [v] for k, v in st.items()}
        else:
            for k, v in st.items():
                stats_acc[k].append(v)
    stats = {
        "attn_sigma": jnp.asarray(np.mean(stats_acc["attn_sigma"], axis=0), jnp.float32),
        "attn_min": jnp.asarray(np.min(stats_acc["attn_min"], axis=0), jnp.float32),
    }

    eval_batches = [{k: jnp.asarray(v) for k, v in data.next_batch().items()} for _ in range(8)]
    results = {"sigma_range": (float(stats["attn_sigma"].min()), float(stats["attn_sigma"].max()))}
    results["exact"] = _eval_ppl(cfg_train, params, eval_batches)
    for bits in (2, 3):
        for method, impl, rule in (
            ("exaq_paper", "exaq", "paper"),
            ("exaq_analytic", "exaq", "analytic"),
            ("naive", "naive", "paper"),
        ):
            cfg_q = base.with_quant(softmax_impl=impl, bits=bits, clip_rule=rule)
            qs = build_model(cfg_q).qstate_from_stats(stats)
            results[f"{method}_int{bits}"] = _eval_ppl(cfg_q, params, eval_batches, qstate=qs)
    return results


def logit_mse_sweep(seed: int = 0):
    """Random-init logit-MSE probe across all 10 assigned archs."""
    out = {}
    for arch in [a for a in list_configs() if a != "llama1-7b"]:
        base = get_config(arch).reduced()
        if base.family == "ssm":
            out[arch] = {"note": "attention-free; EXAQ n/a (DESIGN.md §4)"}
            continue
        m_exact = build_model(base.with_quant(softmax_impl="exact"))
        params = m_exact.init(jax.random.PRNGKey(seed))
        rng = np.random.default_rng(seed)
        batch = {"tokens": jnp.asarray(rng.integers(0, base.vocab_size, (2, 32)), jnp.int32)}
        if base.frontend == "vlm":
            batch["vision_embeds"] = jnp.asarray(rng.normal(0, 1, (2, base.frontend_tokens, base.frontend_dim)), jnp.float32)
        if base.family == "audio":
            batch["audio_embeds"] = jnp.asarray(rng.normal(0, 1, (2, base.enc_seq, base.frontend_dim)), jnp.float32)
        ref, _ = m_exact.forward_train(params, batch)
        row = {}
        for method, impl in (("exaq", "exaq"), ("naive", "naive")):
            lq, _ = build_model(base.with_quant(softmax_impl=impl, bits=2)).forward_train(params, batch)
            row[method + "_int2_mse"] = float(((lq - ref) ** 2).mean())
        out[arch] = row
    return out


def main():
    res = run()
    print("accuracy proxy (perplexity; lower=better):")
    for k, v in res.items():
        print(f"  {k}: {v}")
    return res


if __name__ == "__main__":
    main()
