"""Roofline analysis (required deliverable g): three terms per (arch x shape),
derived from the dry-run's compiled artifact.

    compute term    = per-device HLO FLOPs / peak FLOP/s        (197 TF bf16)
    memory term     = per-device HLO bytes / HBM bandwidth      (819 GB/s)
    collective term = per-device collective bytes / ICI link bw (50 GB/s)

cost_analysis() on the partitioned executable reports *per-device* numbers
with loop trip counts included (verified analytically against 2*N*B for the
internlm2 decode cell); collective bytes come from parsing the post-SPMD HLO
with a ring-algorithm cost model (launch/dryrun.py).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12   # TPU v5e bf16 per chip
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(tag: str = "singlepod", dryrun_dir: str | None = None) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir or DRYRUN_DIR, f"*__{tag}.json"))):
        r = json.load(open(f))
        if "error" not in r and "skipped" not in r:
            out.append(r)
    return out


def ideal_bytes_per_dev(cfg, shape, mesh: dict) -> float:
    """Unavoidable per-device HBM traffic (the memory-roofline floor):
    params/opt streams + one residual-stream pass per layer + cache traffic.
    Attention scores are assumed VMEM-resident (perfect fusion).

    Activations (B,S,D) are sharded over the data axes and replicated over
    'model' under TP, so per-device token count divides by dp only."""
    from repro.utils.params import count_active_params, count_params

    devices = 1
    for v in mesh.values():
        devices *= v
    dp = devices // mesh.get("model", 1)
    n = count_params(cfg)
    n_act = count_active_params(cfg)
    L = cfg.num_layers + cfg.enc_layers
    d = cfg.d_model
    tokens_dp = shape.global_batch * shape.seq_len / dp
    if shape.kind == "train":
        # fp32 params r/w + grads r/w + adam m,v r/w (~12 streams of 4B each)
        p = 12.0 * 4.0 * n / devices
        act = 8.0 * 2.0 * d * tokens_dp * L  # fwd+bwd residual stream, bf16
        return p + act
    kv_bytes = 0.0
    if cfg.num_kv_heads:
        n_caches = (cfg.num_layers // cfg.hybrid_period) if cfg.hybrid_period else cfg.num_layers
        kv_bytes = 2.0 * n_caches * shape.global_batch * cfg.num_kv_heads * cfg.resolved_head_dim * shape.seq_len * 2.0
    if shape.kind == "prefill":
        p = 2.0 * n_act / devices
        act = 4.0 * 2.0 * d * tokens_dp * L
        return p + act + kv_bytes / devices  # write the cache once
    # decode: stream active params + read the live cache once
    p = 2.0 * n_act / devices
    return p + kv_bytes / devices


def roofline_terms(rec: dict) -> dict:
    from repro.configs import SHAPES, get_config
    from repro.utils.params import count_active_params, count_params, model_flops

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    dev = rec["devices"]
    # trip-counted HLO costs (utils/hlo_cost); fall back to XLA's once-counted
    flops = rec.get("tc_flops", rec["flops"])
    byts = rec.get("tc_bytes", rec["bytes_accessed"])
    coll = rec.get("tc_collectives", rec["collectives"])["total"]
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / dev
    hlo_flops = max(flops, 1.0)
    bound = max(terms.values())
    # roofline fraction = ideal step time / achieved (dominant-term) time,
    # where ideal = max(compute floor, unavoidable-HBM floor)
    ideal = max(mf_dev / PEAK_FLOPS, ideal_bytes_per_dev(cfg, shape, rec["mesh"]) / HBM_BW)
    frac = ideal / bound if bound > 0 else 0.0
    note = {
        "compute_s": "compute-bound: reduce non-model FLOPs (remat policy, fused attention) or shrink redundant compute",
        "memory_s": "HBM-bound: fuse attention/softmax (keep scores in VMEM), cut activation round-trips, bf16/int8 the cache",
        "collective_s": "collective-bound: re-align cache/param shardings to kill gathers; seq-parallel EXAQ combine (counts all-reduce)",
    }[dom]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"], "devices": dev,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dom.replace("_s", ""),
        "model_flops_global": mf,
        "model_flops_per_dev": mf_dev,
        "hlo_flops_per_dev": flops,
        "useful_flops_ratio": round(mf_dev / hlo_flops, 3),
        "roofline_fraction": round(frac, 4),
        "params_total": count_params(cfg),
        "params_active": count_active_params(cfg),
        "note": note,
    }


def table(tag: str = "singlepod", dryrun_dir: str | None = None) -> list[dict]:
    return [roofline_terms(r) for r in load_cells(tag, dryrun_dir)]


def main(out_csv: str | None = None):
    rows = table()
    cols = ["arch", "shape", "compute_s", "memory_s", "collective_s", "dominant",
            "useful_flops_ratio", "roofline_fraction"]
    print(",".join(cols))
    lines = []
    for r in rows:
        line = ",".join(str(r[c]) for c in cols)
        print(line)
        lines.append(line)
    if out_csv:
        with open(out_csv, "w") as f:
            f.write(",".join(cols) + "\n" + "\n".join(lines) + "\n")
    return rows


if __name__ == "__main__":
    main()
