"""Trip-counted HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
a lax.scan of 8 matmuls reports 1 matmul of FLOPs), silently undercounting
every scan-over-layers model. This analyzer parses the post-SPMD HLO text,
recovers the call graph (while/fusion/call/conditional), reads loop trip
counts from XLA's ``known_trip_count`` backend config (fallback: the loop
condition's compare constant), and accumulates:

  * dot FLOPs            2 * prod(output dims) * prod(contracting dims)
  * HBM traffic bytes    output + operand bytes of executed top-level
                         instructions (fusion internals stay in VMEM;
                         fusion boundaries hit HBM)
  * collective bytes     ring cost per kind: all-reduce 2(n-1)/n, all-gather
                         /all-to-all (n-1)/n, reduce-scatter (n-1) x shard,
                         permute 1x — per device

All quantities are per-device (the HLO module is the partitioned program).
Elementwise FLOPs are ignored (dots dominate — standard MFU practice).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "iota",
    "after-all", "partition-id", "replica-id", "reshape", "while", "conditional", "call",
}


def xla_cost_analysis(compiled) -> dict:
    """Compat shim over ``Compiled.cost_analysis()``.

    JAX <= 0.4.x returns a list with one per-device dict; newer releases
    return the dict directly. Always returns a (possibly empty) dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(type_str: str) -> float:
    n = 0.0
    for _, dims in _SHAPE_RE.findall(type_str):
        e = 1.0
        for d in dims.split(","):
            if d:
                e *= int(d)
        n += e
    return n


@dataclass
class Instr:
    name: str
    op: str
    result_type: str
    operands: list[str]
    line: str


@dataclass
class Computation:
    name: str
    types: dict[str, str] = field(default_factory=dict)  # value name -> type
    instrs: list[Instr] = field(default_factory=list)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        mh = _COMP_HDR.match(line)
        if mh and line.endswith("{"):
            cur = Computation(mh.group(2))
            comps[cur.name] = cur
            if mh.group(1):
                entry = cur.name
            # header params: "name: type, name: type"
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)", mh.group(3)):
                cur.types[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rtype, op, rest = mi.groups()
        # operand region: up to the first top-level ')'
        depth = 1
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = rest[:end]
        operands = _NAME_RE.findall(args)
        cur.types[name] = rtype
        cur.instrs.append(Instr(name, op, rtype, operands, line))
    return comps, entry


def _trip_count(ins: Instr, comps: dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count.{0,6}?"n":"(\d+)"', ins.line)
    if m:
        return max(int(m.group(1)), 1)
    mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        consts = []
        for i2 in cond.instrs:
            m2 = re.search(r"constant\((\d+)\)", i2.line)
            if m2:
                consts.append(int(m2.group(1)))
        if consts:
            return max(max(consts), 1)
    return 1


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    out_elems = _elems(ins.result_type)
    lhs_type = types.get(ins.operands[0], "") if ins.operands else ""
    lhs_dims = _first_shape_dims(lhs_type)
    k = 1.0
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if mc and lhs_dims:
        for idx in mc.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return total_devices


def _collective_moved(ins: Instr, n_dev: int) -> tuple[str, float]:
    op = ins.op[: -len("-start")] if ins.op.endswith("-start") else ins.op
    if op not in _COLLECTIVES:
        return "", 0.0
    n = _group_size(ins.line, n_dev)
    payload = _type_bytes(ins.result_type)
    if op == "all-reduce":
        moved = 2.0 * (n - 1) / max(n, 1) * payload
    elif op == "all-gather":
        moved = (n - 1) / max(n, 1) * payload
    elif op == "reduce-scatter":
        moved = (n - 1) * payload
    elif op == "all-to-all":
        moved = (n - 1) / max(n, 1) * payload
    else:
        moved = payload
    return op, moved


@dataclass
class CostSummary:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: dict = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())

    def scaled(self, m: float) -> "CostSummary":
        return CostSummary(
            self.flops * m, self.bytes * m,
            {k: v * m for k, v in self.collectives.items()},
            {k: v * m for k, v in self.collective_counts.items()},
        )

    def add(self, other: "CostSummary") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for k in _COLLECTIVES:
            self.collectives[k] += other.collectives[k]
            self.collective_counts[k] += other.collective_counts[k]


def _instr_bytes(ins: Instr, types: dict[str, str]) -> float:
    """Op-aware HBM traffic model.

    Slicing ops touch only the slice (XLA implements them as offset reads /
    in-place updates), NOT the full buffer — charging the whole operand would
    overcount a scan body by the full stacked-parameter size per iteration.
    """
    out_b = _type_bytes(ins.result_type)

    def opnd(i: int) -> float:
        if i < len(ins.operands):
            return _type_bytes(types.get(ins.operands[i], ""))
        return 0.0

    op = ins.op
    if op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b  # read slice + write result
    if op == "dynamic-update-slice":
        return 2.0 * opnd(1)  # read update + write into buffer (in place)
    if op == "scatter":
        upd = opnd(2) or out_b
        return 2.0 * upd
    if op in ("broadcast", "pad"):
        return out_b  # write-only (operand is small / reread from cache)
    if op == "concatenate":
        return 2.0 * out_b  # read all pieces + write result
    in_b = sum(_type_bytes(types.get(o, "")) for o in ins.operands)
    return out_b + in_b


def _fusion_operand_bytes(ins: Instr, types: dict[str, str], comps: dict[str, "Computation"]) -> float:
    """Slice-aware fusion input traffic: an operand whose in-fusion parameter
    is consumed ONLY by (dynamic-)slice/gather is read at slice granularity —
    charging the full stacked-parameter array per scan iteration would
    overcount by the layer count."""
    called = _called_comps(ins)
    fused = comps.get(called[0]) if called else None
    total = 0.0
    param_uses: dict[int, list[Instr]] = {}
    param_names: dict[int, str] = {}
    if fused is not None:
        for fi in fused.instrs:
            if fi.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", fi.line)
                if m:
                    param_names[int(m.group(1))] = fi.name
        name_to_idx = {v: k for k, v in param_names.items()}
        for fi in fused.instrs:
            for o in fi.operands:
                if o in name_to_idx:
                    param_uses.setdefault(name_to_idx[o], []).append(fi)
    for i, o in enumerate(ins.operands):
        full = _type_bytes(types.get(o, ""))
        uses = param_uses.get(i)
        if uses and all(u.op in ("dynamic-slice", "slice", "gather") for u in uses):
            sliced = sum(_type_bytes(u.result_type) for u in uses)
            total += min(full, sliced)
        else:
            total += full
    return total


def _called_comps(ins: Instr) -> list[str]:
    out = []
    for key in ("calls", "to_apply", "body", "branch_computations"):
        m = re.search(key + r"=\{?%?([\w.\-]+(?:\s*,\s*%?[\w.\-]+)*)\}?", ins.line)
        if m:
            out += [c.strip().lstrip("%") for c in m.group(1).split(",")]
    return out


def analyze(text: str, n_devices: int) -> CostSummary:
    comps, entry = parse_hlo(text)
    if not entry:
        return CostSummary()
    memo: dict[tuple[str, bool], CostSummary] = {}

    def cost_of(name: str, top_level: bool, stack: frozenset) -> CostSummary:
        key = (name, top_level)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        cs = CostSummary()
        if comp is None or name in stack:
            return cs
        stk = stack | {name}
        for ins in comp.instrs:
            if ins.op == "dot":
                cs.flops += _dot_flops(ins, comp.types)
            ckind, moved = _collective_moved(ins, n_devices)
            if ckind:
                cs.collectives[ckind] += moved
                cs.collective_counts[ckind] += 1
            if ins.op == "while":
                trips = _trip_count(ins, comps)
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                if mb:
                    cs.add(cost_of(mb.group(1), True, stk).scaled(trips))
            elif ins.op == "call":
                # a call body executes at the caller's level: its instructions
                # materialize to HBM exactly as if inlined (XLA:CPU wraps
                # parallelized elementwise ops in %parallel_* calls), so bytes
                # count — unlike fusion internals, which stay in VMEM/registers
                for cn in _called_comps(ins):
                    if cn in comps:
                        cs.add(cost_of(cn, top_level, stk))
            elif ins.op in ("fusion", "conditional") or _called_comps(ins):
                for cn in _called_comps(ins):
                    if cn in comps:
                        sub = cost_of(cn, False, stk)
                        # fusion internals: flops + collectives count; bytes don't
                        cs.flops += sub.flops
                        for k in _COLLECTIVES:
                            cs.collectives[k] += sub.collectives[k]
                            cs.collective_counts[k] += sub.collective_counts[k]
            # HBM bytes: only executed, materializing instructions
            if top_level and ins.op not in _FREE_OPS:
                if ins.op == "fusion":
                    cs.bytes += _type_bytes(ins.result_type) + _fusion_operand_bytes(ins, comp.types, comps)
                else:
                    cs.bytes += _instr_bytes(ins, comp.types)
        memo[key] = cs
        return cs

    return cost_of(entry, True, frozenset())


def per_collective_sites(text: str, n_devices: int, top: int = 12) -> list[tuple[str, float, float]]:
    """(kind + payload type + metadata hint, trip-weighted bytes, executions)."""
    comps, entry = parse_hlo(text)
    sites: dict[str, list[float]] = {}

    def walk(name: str, mult: float, stack: frozenset):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stk = stack | {name}
        for ins in comp.instrs:
            ckind, moved = _collective_moved(ins, n_devices)
            if ckind:
                mo = re.search(r'op_name="([^"]*)"', ins.line)
                hint = mo.group(1)[-60:] if mo else ""
                key = f"{ckind} {ins.result_type.split('{')[0]} {hint}"
                sites.setdefault(key, [0.0, 0.0])
                sites[key][0] += moved * mult
                sites[key][1] += mult
            if ins.op == "while":
                trips = _trip_count(ins, comps)
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                if mb:
                    walk(mb.group(1), mult * trips, stk)
            else:
                for cn in _called_comps(ins):
                    walk(cn, mult, stk)

    if entry:
        walk(entry, 1.0, frozenset())
    rows = [(k, v[0], v[1]) for k, v in sites.items()]
    rows.sort(key=lambda r: -r[1])
    return rows[:top]


def per_bytes_sites(text: str, top: int = 14) -> list[tuple[str, float, float]]:
    """Top HBM-traffic sites: (op + result type + op_name hint,
    trip-weighted bytes, executions). The §Perf profiling instrument."""
    comps, entry = parse_hlo(text)
    sites: dict[str, list[float]] = {}

    def walk(name: str, mult: float, stack: frozenset):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stk = stack | {name}
        for ins in comp.instrs:
            if ins.op not in _FREE_OPS:
                if ins.op == "fusion":
                    b = _type_bytes(ins.result_type) + _fusion_operand_bytes(ins, comp.types, comps)
                else:
                    b = _instr_bytes(ins, comp.types)
                if b * mult > 0:
                    mo = re.search(r'op_name="([^"]*)"', ins.line)
                    hint = mo.group(1)[-70:] if mo else ""
                    key = f"{ins.op} {ins.result_type.split('{')[0][:46]} {hint}"
                    sites.setdefault(key, [0.0, 0.0])
                    sites[key][0] += b * mult
                    sites[key][1] += mult
            if ins.op == "while":
                trips = _trip_count(ins, comps)
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                if mb:
                    walk(mb.group(1), mult * trips, stk)
            elif ins.op == "call":  # call bodies materialize (see analyze())
                for cn in _called_comps(ins):
                    walk(cn, mult, stk)

    if entry:
        walk(entry, 1.0, frozenset())
    rows = [(k, v[0], v[1]) for k, v in sites.items()]
    rows.sort(key=lambda r: -r[1])
    return rows[:top]
