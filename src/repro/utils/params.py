"""Exact parameter counting (from the abstract init tree — no formula drift)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def count_params(cfg) -> int:
    from repro.models import build_model

    tree = jax.eval_shape(lambda k: build_model(cfg).init(k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    return int(sum(leaf.size for leaf in jax.tree.leaves(tree)))


def count_embedding_params(cfg) -> int:
    return int(cfg.vocab_size) * int(cfg.d_model)


def per_expert_params(cfg) -> int:
    if cfg.moe is None:
        return 0
    fe = cfg.moe.d_expert or cfg.d_ff
    return cfg.d_model * 2 * fe + fe * cfg.d_model  # gated wi + wo


def count_active_params(cfg) -> int:
    """Params touched per token (MoE: only top-k routed experts active)."""
    n = count_params(cfg)
    if cfg.moe is not None:
        inactive = (cfg.moe.num_experts - cfg.moe.top_k) * per_expert_params(cfg) * cfg.num_layers
        n -= inactive
    return int(n)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6*N*D (train) / 2*N_active*D (inference),
    N excluding the embedding gather (the unembed matmul counts)."""
    n_act = count_active_params(cfg) - count_embedding_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch
