"""Pallas TPU kernel: fused chunked-prefill attention over a paged KV pool.

The prefill hot path this kernel deletes (DESIGN.md §7): chunked prefill
(`attention_prefill_chunk`) scatters a chunk's C projected K/V rows into the
pool — O(C·Dh) bytes, cheap — and then `gather_block_kv` assembles the
request's *entire* window `(1, KV, MB·bs, Dh)` as a dense HBM copy so
attention can read it back. Per chunk that is ~3 rectangular passes over a
window that grows with every chunk, so prefilling a P-token prompt moves
O(P²) bytes in copies alone: exactly the term that dominates time-to-first-
token for the long-prompt / shared-prefix traffic the paged engine targets.

Here the block table drives the DMA directly, mirroring the decode kernel
(`exaq_paged_attention`): the grid is ``(kv_head, chunk)`` over one request's
table, the table and the chunk's window length ride the *scalar-prefetch*
channel, and the K/V BlockSpec index maps pull one pool block per grid step
straight into VMEM. The dense window copy never exists; the scatter that
precedes the attend (quantize-on-scatter with §6 scale seeding for int8
pools) is unchanged and shared with the gather path.

Chunk-combine semantics are the two-pass global grid of
``exaq_softmax_chunked`` (exact Algo. 2, DESIGN.md §2): pass 1 reduces each
query row's max over every block it may attend to, pass 2 re-reads K,
quantizes all scores on the grid anchored at that max, and accumulates the
PV numerator plus the 2^M-bin histogram denominator. Counts on a shared grid
add exactly across blocks AND across prefill chunks (each chunk anchors at
its own rows' true global maxes), so a prompt prefilled in chunks through
this kernel is bit-identical to a one-shot prefill and matches the gather
oracle (``kernels.ops.paged_prefill_attention`` with ``use_kernel=False``)
to fp32 roundoff.

Causality is by *global position*: chunk row ``i`` sits at position
``start + i`` and attends to window columns ``<= start + i``. Table entries
at or past ``ceil((start + C) / bs)`` can never be attended (the newest row
caps the window), so their index maps pin to the null block — consecutive
identical indices collapse to one DMA and bytes moved track ``start + C``,
not the padded table width. V's pass-1 index map is pinned the same way, so
V crosses HBM once: ~2×K + 1×V of live-window bytes per chunk, vs the
gather's live pool read plus two rectangular passes over the dense copy
(see ``paged_prefill_bytes_model``).

GQA is native: q is laid out ``(KV, group·C, Dh)`` so one kv head's query
group forms the q-block rows — K/V are never repeated ``group`` times.

Int8 pools (DESIGN.md §6): the per-(block, kv-head) dequant scales ride the
scalar-prefetch channel beside the table and each K/V block is dequantized
in VMEM right after its 8-bit DMA lands, before the EXAQ clip/LUT stages —
identical to the dequantizing gather oracle, so parity holds at int8 too.

Packed int4 pools (DESIGN.md §10) mirror the decode kernel: the pool's last
dim is ``Dh/2`` packed uint8 nibbles, the (N, KV, n_sub) sub-block scale
codes join the block scales on the scalar-prefetch channel, and each block
is nibble-split and scaled ``block_scale * sub_code / 15`` per sub-block
row group in VMEM right after its half-width DMA — no unpacked or
dequantized copy ever exists in HBM. q/out/acc live at the unpacked width
``2 * lane_pad(Dh/2)``: q's zero lane-padding nulls the K-side garbage
padded nibbles decode to, and V-side garbage lands in output lanes >= Dh
that the final slice drops.

Layouts: q ``(1, H, C, Dh)``; pool_k/pool_v ``(N, KV, bs, Dh)`` (int4:
``(N, KV, bs, Dh/2)`` uint8); block_table ``(MB,)`` int32; start scalar
int32 (tokens already cached); optional k_scale/v_scale ``(N, KV)`` fp32
and int4-only k_sub/v_sub ``(N, KV, n_sub)`` uint8. Compiled-mode tiling
wants ``bs`` a multiple of 8 and the pool's last dim lane-padded
(production shapes satisfy both; tests run interpret mode where any shape
goes).

Tensor-parallel contract (DESIGN.md §9): under a mesh whose 'model' axis
divides KV, ``kernels.ops.paged_prefill_attention`` wraps this kernel in a
shard_map that splits q's H axis and the pool's KV axis by the same factor
and replicates table/start/scalars. Inside the shard_map the kernel sees
the *local* head partition, its grid's kv_head axis runs over local heads
only, and GQA group alignment is preserved (H and KV shard by the same
factor) — no global-head offsets in the index maps, and per-(row, head)
outputs are computed whole on one shard, so the sharded kernel is bit-exact
vs the single-shard dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# constants and the accumulate stage are shared with the decode kernel: the
# two paged kernels must mask, pad, and quantize identically for the
# decode-vs-prefill parity contract to hold
from repro.kernels.exaq_paged_attention import _LANES, _NEG_BIG, _round_up, exaq_accumulate_stage
from repro.kernels.kv_codec import INT4_BIAS, INV_SUB_LEVELS, kv4_num_sub


def _paged_prefill_kernel(
    table_ref,
    info_ref,
    *refs,
    bs: int,
    mb: int,
    block_q: int,
    chunk: int,
    group: int,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    scale: float,
    kv_quant: bool,
    kv_int4: bool = False,
    n_sub: int = 0,
    sub_bs: int = 0,
):
    """Grid (KV, 2*MB): table entries 0..MB-1 are the max pass, MB..2*MB-1
    the quantize+accumulate pass. Scratch (m, l, acc) carries across the
    chunk axis; the BlockSpec index maps (not this body) steer the pool DMA.
    ``info_ref`` is (2,): [start, start + C] — row positions and the live
    window length. ``kv_quant`` pools carry two extra scalar-prefetch refs,
    the per-(block, kv-head) dequant scales (DESIGN.md §6); ``kv_int4``
    pools carry two more — the (N, KV, n_sub) sub-block scale codes — and
    their K/V refs hold *packed* nibbles at half width (DESIGN.md §10)."""
    if kv_int4:
        (ksc_ref, vsc_ref, ksub_ref, vsub_ref,
         q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    elif kv_quant:
        ksub_ref = vsub_ref = None
        ksc_ref, vsc_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        ksc_ref = vsc_ref = ksub_ref = vsub_ref = None
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    head = pl.program_id(0)
    j = pl.program_id(1)
    t = j % mb  # table entry this step touches (same in both passes)
    start = info_ref[0]
    win = info_ref[1]  # start + C: the newest row caps the window
    live = t * bs < win
    blk = jnp.where(live, table_ref[t], 0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # q rows are (group, C) flattened as r = g*C + i: row r's global query
    # position is start + (r % C); rows past group*C are lane padding
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_q, bs), 0)
    col = t * bs + jax.lax.broadcasted_iota(jnp.int32, (block_q, bs), 1)
    valid = (rows < group * chunk) & (col <= start + rows % chunk)

    def _load_kv(ref, sc_ref, sub_ref):
        """One pool block from its VMEM ref to fp32 rows, dequantized —
        kept arithmetic-identical to the decode kernel's ``_load_kv`` and
        ``kv_codec.kv4_effective_scale`` (same multiply order) so fused
        prefill matches the gather oracle to fp32 roundoff."""
        x = ref[0, 0]
        if kv_int4:
            lo = (x & 0xF).astype(jnp.int32) - INT4_BIAS
            hi = (x >> 4).astype(jnp.int32) - INT4_BIAS
            codes = jnp.stack([lo, hi], axis=-1).reshape(bs, 2 * x.shape[-1])
            parts = []
            for sg in range(n_sub):
                s_eff = sc_ref[blk, head] * sub_ref[blk, head, sg].astype(jnp.float32) \
                    * INV_SUB_LEVELS
                parts.append(s_eff * jnp.ones((sub_bs, 1), jnp.float32))
            row_scale = jnp.concatenate(parts, axis=0) if n_sub > 1 else parts[0]
            return codes.astype(jnp.float32) * row_scale
        x = x.astype(jnp.float32)
        if kv_quant:
            x = x * sc_ref[blk, head]  # dequant in VMEM: HBM moved 1 byte/elt
        return x

    def _scores():
        q = q_ref[0].astype(jnp.float32)
        k = _load_kv(k_ref, ksc_ref, ksub_ref)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        return jnp.where(valid, s, _NEG_BIG)

    @pl.when((j < mb) & live)
    def _max_pass():
        s = _scores()
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))

    @pl.when((j >= mb) & live)
    def _acc_pass():
        s = _scores()
        m = m_ref[:, :1]  # global row max from pass 1 — shared quantization grid
        e, dden = exaq_accumulate_stage(s, m, valid, levels=levels, clip=clip, lut=lut)
        l_ref[...] = l_ref[...] + dden
        v = _load_kv(v_ref, vsc_ref, vsub_ref)
        acc_ref[...] += jax.lax.dot_general(
            e, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == 2 * mb - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30))[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "scale", "interpret"),
)
def exaq_paged_prefill_attention(
    q: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    block_table: jnp.ndarray,
    start,
    params,
    scale: float,
    *,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    k_sub: jnp.ndarray | None = None,
    v_sub: jnp.ndarray | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused chunked-prefill EXAQ attention for one request over a block pool.

    q: (1, H, C, D) the chunk's projected queries (rows at global positions
    ``start + i``); pool_k/pool_v: (N, KV, bs, D) with this chunk's K/V
    already scattered in; block_table: (MB,) int32 block ids (null-block
    padded); start: scalar int32 tokens cached before this chunk. An int8
    pool additionally takes k_scale/v_scale (N, KV) fp32 dequant scales
    (DESIGN.md §6), scalar-prefetched beside the table. A packed int4 pool
    (uint8 payload at last dim D/2, DESIGN.md §10) also takes k_sub/v_sub
    (N, KV, n_sub) uint8 sub-block scale codes; nibbles unpack in VMEM
    after each half-width block DMA. Returns
    (1, H, C, D) fp32. Global-grid (exact Algo. 2) semantics — bit-identical
    to a one-shot prefill of the same window.
    """
    _, H, C, D = q.shape
    N, KV, bs, _ = pool_k.shape
    MB = block_table.shape[0]
    group = H // KV
    kv_quant = pool_k.dtype == jnp.int8
    kv_int4 = pool_k.dtype == jnp.uint8
    want_scales = kv_quant or kv_int4
    if (k_scale is not None) != want_scales or (v_scale is not None) != want_scales:
        raise ValueError(
            "quantized (int8/int4) pools require both k_scale and v_scale; fp pools forbid them"
        )
    if (k_sub is not None) != kv_int4 or (v_sub is not None) != kv_int4:
        raise ValueError(
            "packed int4 pools require both k_sub and v_sub sub-scale planes; "
            "other pools forbid them"
        )
    q = q[0].reshape(KV, group, C, D).reshape(KV, group * C, D)
    block_q = _round_up(max(group * C, 8), 8)
    if block_q != group * C:
        q = jnp.pad(q, ((0, 0), (0, block_q - group * C), (0, 0)))
    if kv_int4:
        if D % 2 or pool_k.shape[3] != D // 2:
            raise ValueError(
                f"packed int4 pool last dim must be head_dim/2 "
                f"(got pool {pool_k.shape[3]}, head_dim {D})"
            )
        n_sub = k_sub.shape[-1]
        sub_bs = bs // n_sub
        # packed payload lane-pads at its own (half) width; q/out/acc live at
        # the unpacked width 2*Pp (zero q padding nulls K-side garbage, the
        # V-side garbage lands in output lanes >= D sliced off below)
        p_pad = _round_up(max(D // 2, _LANES), _LANES)
        kv_width = p_pad
        d_pad = 2 * p_pad
        if p_pad != D // 2:
            ppad = ((0, 0), (0, 0), (0, 0), (0, p_pad - D // 2))
            pool_k = jnp.pad(pool_k, ppad)
            pool_v = jnp.pad(pool_v, ppad)
        if d_pad != D:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, d_pad - D)))
    else:
        n_sub = sub_bs = 0
        d_pad = _round_up(max(D, _LANES), _LANES)
        kv_width = d_pad
        if d_pad != D:
            # production head dims are lane-aligned; the pad only fires on the
            # small shapes tests use (interpret mode), never on the serving path
            pad = ((0, 0), (0, 0), (0, d_pad - D))
            q = jnp.pad(q, pad)
            pad4 = ((0, 0), (0, 0), (0, 0), (0, d_pad - D))
            pool_k = jnp.pad(pool_k, pad4)
            pool_v = jnp.pad(pool_v, pad4)

    table = block_table.astype(jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    info = jnp.stack([start, start + C])
    lut = tuple(float(x) for x in params.lut_np())

    def _k_index(h, j, tbl, inf, *sc):
        # future/dead-tail entries -> null block; consecutive identical
        # indices are a single DMA, so bytes track start + C, not MB*bs
        t = j % MB
        return (jnp.where(t * bs < inf[1], tbl[t], 0), h, 0, 0)

    def _v_index(h, j, tbl, inf, *sc):
        # V is only consumed by the accumulate pass; pin the max pass (and
        # future blocks) to the null block so V moves over HBM exactly once
        t = j % MB
        return (jnp.where((j >= MB) & (t * bs < inf[1]), tbl[t], 0), h, 0, 0)

    def _q_index(h, j, tbl, inf, *sc):
        return (h, 0, 0)

    prefetch = (table, info)
    if want_scales:
        prefetch += (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
    if kv_int4:
        prefetch += (k_sub.astype(jnp.int32), v_sub.astype(jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(KV, 2 * MB),
        in_specs=[
            pl.BlockSpec((1, block_q, d_pad), _q_index),
            pl.BlockSpec((1, 1, bs, kv_width), _k_index),
            pl.BlockSpec((1, 1, bs, kv_width), _v_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d_pad), _q_index),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d_pad), jnp.float32),
        ],
    )
    kern = functools.partial(
        _paged_prefill_kernel,
        bs=bs, mb=MB, block_q=block_q, chunk=C, group=group,
        levels=params.levels, clip=float(params.clip), lut=lut, scale=float(scale),
        kv_quant=kv_quant, kv_int4=kv_int4, n_sub=n_sub, sub_bs=sub_bs,
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((KV, block_q, d_pad), jnp.float32),
        # only the chunk axis carries scratch state; kv-head programs are
        # independent and may partition across cores
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*prefetch, q, pool_k, pool_v)
    return out[:, : group * C, :D].reshape(KV * group, C, D)[None]


def paged_prefill_bytes_model(
    *,
    prompt_len: int,
    chunk: int,
    kv_heads: int,
    max_blocks: int,
    block_size: int,
    head_dim: int,
    start_cached: int = 0,
    dtype_bytes: int = 2,
    kv_dtype: str | None = None,
    tp: int = 1,
) -> dict:
    """Modeled HBM KV bytes per layer to prefill one prompt, gather vs fused.

    Chunked prefill runs ``ceil((prompt_len - start_cached) / chunk)`` chunks;
    at each, the window is ``start + C`` tokens. gather_then_attend:
    ``gather_block_kv`` reads the window's *live* blocks from the pool,
    writes the dense rectangular ``max_blocks``-wide copy, and attention
    reads the copy back — (live + 2 × rect) passes over each of K and V,
    every chunk, so copy bytes grow O(prompt²). fused_pool_read: the kernel
    touches live blocks only — K twice (max + accumulate pass), V once. The
    O(C·Dh) scatter is identical on both paths and excluded. Pure arithmetic
    so benchmarks and tests can assert the ≥2x bandwidth win without
    hardware counters.

    ``kv_dtype`` ("fp32" | "bf16" | "int8" | "int4") sizes the pool element
    instead of the raw ``dtype_bytes`` knob; int8 (DESIGN.md §6) adds the
    4-byte per-(block, kv-head) scale to every pool-block read and prices
    the gather path's dense dequantized copy at fp32 width. int4
    (DESIGN.md §10) halves the payload to packed nibbles and adds one uint8
    sub-block scale code per ``KV_SUB_BLOCK`` tokens on top of the fp32
    block scale; its dense copy is fp32-priced too.

    ``tp`` models the tensor-parallel pool split (DESIGN.md §9): each shard
    reads ``kv_heads / tp`` heads of every block, so the figures are
    per-shard bytes. ``tp`` must divide ``kv_heads`` (non-divisible counts
    serve a replicated pool; model that as tp=1).
    """
    from repro.kernels.exaq_paged_attention import KV_DTYPE_BYTES

    if kv_heads % tp:
        raise ValueError(f"tp={tp} must divide kv_heads={kv_heads} (replicated fallback is tp=1)")
    kv_heads //= tp
    if kv_dtype is not None:
        dtype_bytes = KV_DTYPE_BYTES[kv_dtype]
    if kv_dtype == "int4":
        payload_bytes = kv_heads * block_size * head_dim // 2  # packed nibbles
        scale_bytes = kv_heads * (4 + kv4_num_sub(block_size))
        dense_bytes_elt = 4
    elif kv_dtype == "int8":
        payload_bytes = kv_heads * block_size * head_dim
        scale_bytes = kv_heads * 4
        dense_bytes_elt = 4
    else:
        payload_bytes = kv_heads * block_size * head_dim * dtype_bytes
        scale_bytes = 0
        dense_bytes_elt = dtype_bytes
    block_bytes = payload_bytes + scale_bytes
    dense_block_bytes = kv_heads * block_size * head_dim * dense_bytes_elt

    gather = fused = live_sum = chunks = 0
    start = start_cached
    while start < prompt_len:
        c = min(chunk, prompt_len - start)
        live = -(-(start + c) // block_size)
        gather += (live * block_bytes + 2 * max_blocks * dense_block_bytes) * 2
        fused += live * (2 + 1) * block_bytes  # 2x K + 1x V, live blocks only
        live_sum += live
        start += c
        chunks += 1
    return {
        "kv_dtype": kv_dtype,
        "tp": tp,
        "prompt_len": prompt_len,
        "chunk": chunk,
        "chunks": chunks,
        "gather_then_attend_bytes": int(gather),
        "fused_pool_read_bytes": int(fused),
        "bytes_reduction_x": gather / max(fused, 1),
        "live_block_reads": int(live_sum),
        "rect_blocks_per_chunk": int(max_blocks),
        "block_bytes": int(block_bytes),
    }
