"""Pallas TPU kernels for EXAQ hot spots + jnp oracles and jit wrappers."""

from repro.kernels.ops import (
    decode_attention,
    exaq_attention,
    exaq_softmax,
    gather_block_kv,
    kv_quantize,
    kv_write_scales,
    paged_decode_attention,
    paged_prefill_attention,
    repeat_kv,
    window_valid_mask,
)

__all__ = [
    "decode_attention",
    "exaq_attention",
    "exaq_softmax",
    "gather_block_kv",
    "kv_quantize",
    "kv_write_scales",
    "paged_decode_attention",
    "paged_prefill_attention",
    "repeat_kv",
    "window_valid_mask",
]
