"""Pallas TPU kernels for EXAQ hot spots + jnp oracles and jit wrappers."""

from repro.kernels.ops import decode_attention, exaq_attention, exaq_softmax

__all__ = ["decode_attention", "exaq_attention", "exaq_softmax"]
