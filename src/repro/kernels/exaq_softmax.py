"""Pallas TPU kernel: fused EXAQ softmax (paper Algo. 2, TPU-native form).

One pass over a (block_rows, n) VMEM tile:
  max-subtract -> quantize (1 FMA + floor + clamp) -> LUT exp (select chain over
  2^M constants, no transcendental) -> histogram denominator (integer counts x
  2^M FMAs; the TPU analogue of the byte-packed LUT_sum) -> normalize.

The LUT values and the clip C are compile-time constants (calibrated sigma),
so the quantizer folds into immediate operands.

Block sizing: rows are tiled by ``block_rows``; the full row (padded to a lane
multiple) lives in VMEM — fp32 rows up to 32k cost 8*32k*4B = 1 MiB per tile.
Longer rows go through ops.exaq_softmax_chunked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantizer import QuantParams

_NEG_BIG = -1e30
_LANES = 128


def _kernel(
    x_ref,
    o_ref,
    *,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    valid_cols: int,
):
    x = x_ref[...].astype(jnp.float32)
    bm, bn = x.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    valid = col < valid_cols
    x = jnp.where(valid, x, _NEG_BIG)
    m = jnp.max(x, axis=-1, keepdims=True)
    xs = x - m
    inv_delta = levels / (-clip)
    codes = jnp.clip(jnp.floor((xs - clip) * inv_delta), 0, levels - 1).astype(jnp.int32)
    # LUT_exp: select chain over 2^M immediates (1-cycle-class VPU ops)
    e = jnp.full((bm, bn), lut[0], jnp.float32)
    for k in range(1, levels):
        e = jnp.where(codes == k, lut[k], e)
    e = jnp.where(valid, e, 0.0)
    # LUT_sum analogue: integer histogram, then 2^M FMAs per row
    denom = jnp.zeros((bm, 1), jnp.float32)
    for k in range(levels):
        cnt = jnp.sum(jnp.where(valid & (codes == k), 1, 0).astype(jnp.int32), axis=-1, keepdims=True)
        denom = denom + cnt.astype(jnp.float32) * lut[k]
    o_ref[...] = (e / denom).astype(o_ref.dtype)


def _masked_kernel(
    x_ref,
    lens_ref,
    o_ref,
    *,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    valid_cols: int,
):
    """Variant with per-row valid lengths (e.g. ragged attention rows)."""
    x = x_ref[...].astype(jnp.float32)
    bm, bn = x.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    lens = lens_ref[...].reshape(bm, 1)
    valid = (col < valid_cols) & (col < lens)
    x = jnp.where(valid, x, _NEG_BIG)
    m = jnp.max(x, axis=-1, keepdims=True)
    xs = x - m
    inv_delta = levels / (-clip)
    codes = jnp.clip(jnp.floor((xs - clip) * inv_delta), 0, levels - 1).astype(jnp.int32)
    e = jnp.full((bm, bn), lut[0], jnp.float32)
    for k in range(1, levels):
        e = jnp.where(codes == k, lut[k], e)
    e = jnp.where(valid, e, 0.0)
    denom = jnp.zeros((bm, 1), jnp.float32)
    for k in range(levels):
        cnt = jnp.sum(jnp.where(valid & (codes == k), 1, 0).astype(jnp.int32), axis=-1, keepdims=True)
        denom = denom + cnt.astype(jnp.float32) * lut[k]
    denom = jnp.maximum(denom, 1e-30)  # fully-masked rows
    o_ref[...] = (e / denom).astype(o_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit, static_argnames=("params", "block_rows", "interpret")
)
def exaq_softmax_pallas(
    x: jnp.ndarray,
    params: QuantParams,
    lens: jnp.ndarray | None = None,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """EXAQ softmax over the last axis. x: (..., n); lens: (...,) optional."""
    orig_shape = x.shape
    n = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, n)
    n_pad = _round_up(max(n, _LANES), _LANES)
    rows_pad = _round_up(max(rows, block_rows), block_rows)
    if n_pad != n or rows_pad != rows:
        x2 = jnp.pad(x2, ((0, rows_pad - rows), (0, n_pad - n)))
    lut = tuple(float(v) for v in params.lut_np())
    grid = (rows_pad // block_rows,)
    kwargs = dict(levels=params.levels, clip=float(params.clip), lut=lut, valid_cols=n)
    if lens is None:
        out = pl.pallas_call(
            functools.partial(_kernel, **kwargs),
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, n_pad), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, n_pad), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows_pad, n_pad), x.dtype),
            interpret=interpret,
        )(x2)
    else:
        l2 = lens.reshape(rows).astype(jnp.int32)
        if rows_pad != rows:
            l2 = jnp.pad(l2, (0, rows_pad - rows))
        out = pl.pallas_call(
            functools.partial(_masked_kernel, **kwargs),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, n_pad), lambda i: (i, 0)),
                pl.BlockSpec((block_rows,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((block_rows, n_pad), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows_pad, n_pad), x.dtype),
            interpret=interpret,
        )(x2, l2)
    return out[:rows, :n].reshape(orig_shape)
