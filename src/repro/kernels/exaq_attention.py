"""Pallas TPU kernel: fused flash attention with EXAQ softmax (beyond-paper).

Motivation (roofline): unfused attention materializes the (Sq, Skv) score
matrix in HBM three times (write scores, read for softmax, read probs for PV)
— at 32k prefill that is the dominant memory term. Fusing QK^T -> EXAQ
softmax -> PV keeps scores in VMEM; EXAQ then removes the per-element
transcendental: inside each block, exp() is replaced by quantize + a 2^M-way
select, and the block denominator by an integer histogram dotted with the LUT
(paper §4.1/§4.2 adapted to the VPU — see DESIGN.md §2).

Online semantics: scores in each kv block are quantized on the grid anchored
at the *running* row max, and accumulators are rescaled by exp(m_old - m_new)
(one scalar exp per row per block — the per-element exps are gone). The
matching oracle is ``ref.flash_exaq_attention_ref``; the global-grid (exact
Algo. 2) semantics are provided by ``ref.exaq_attention_global_ref`` and used
on the distributed seq-parallel path.

Layouts: q (B, H, Sq, D); k, v (B, Hkv, Skv, D); GQA is handled by the kv
index map (h // group). grid = (B, H, num_q_blocks, num_kv_blocks); the kv
axis is innermost so the (m, l, acc) VMEM scratch carries across kv steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_BIG = -1e30
_LANES = 128


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _flash_body(
    q,
    k,
    v,
    m_ref,
    l_ref,
    acc_ref,
    *,
    valid,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    scale: float,
):
    """Shared inner step: one (q_block, kv_block) EXAQ-flash update."""
    bq = q.shape[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bkv); scale applied in fp32 (bit-exact vs the oracle)
    s = jnp.where(valid, s, _NEG_BIG)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    inv_delta = levels / (-clip)
    codes = jnp.clip(jnp.floor((s - m_new - clip) * inv_delta), 0, levels - 1).astype(jnp.int32)
    e = jnp.full(s.shape, lut[0], jnp.float32)
    for kk in range(1, levels):
        e = jnp.where(codes == kk, lut[kk], e)
    e = jnp.where(valid, e, 0.0)
    # block denominator via integer histogram (LUT_sum analogue)
    dden = jnp.zeros((bq, 1), jnp.float32)
    for kk in range(levels):
        cnt = jnp.sum(jnp.where(valid & (codes == kk), 1, 0).astype(jnp.int32), axis=-1, keepdims=True)
        dden = dden + cnt.astype(jnp.float32) * lut[kk]
    alpha = jnp.exp(m_prev - m_new)  # one scalar exp per row per block
    l_new = alpha * l_ref[:, :1] + dden
    pv = jax.lax.dot_general(
        e, v.astype(jnp.float32), (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)


def _causal_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    block_q: int,
    block_kv: int,
    nkv: int,
    sq: int,
    skv: int,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    scale: float,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0]
    offset = skv - sq  # align sequence ends (standard decoder convention)
    row_ids = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) + offset
    col_ids = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    valid = (col_ids <= row_ids) & (col_ids < skv)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip fully-masked blocks above the causal diagonal
    q_end = iq * block_q + block_q - 1 + offset
    @pl.when(ikv * block_kv <= q_end)
    def _compute():
        _flash_body(q, k, v, m_ref, l_ref, acc_ref, valid=valid, levels=levels, clip=clip, lut=lut, scale=scale)

    @pl.when(ikv == nkv - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30))[None, None].astype(o_ref.dtype)


def _decode_kernel(
    q_ref,
    k_ref,
    v_ref,
    lens_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    block_q: int,
    block_kv: int,
    nkv: int,
    skv: int,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    scale: float,
):
    ikv = pl.program_id(3)
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0]
    kv_len = lens_ref[0, 0]
    col_ids = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    valid = (col_ids < kv_len) & (col_ids < skv)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip kv blocks entirely beyond the live cache length
    @pl.when(ikv * block_kv < kv_len)
    def _compute():
        _flash_body(q, k, v, m_ref, l_ref, acc_ref, valid=valid, levels=levels, clip=clip, lut=lut, scale=scale)

    @pl.when(ikv == nkv - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30))[None, None].astype(o_ref.dtype)


def _common_prep(q, k, v, scale):
    """Pad head_dim to a lane multiple (scale is applied in-kernel, fp32)."""
    del scale
    D = q.shape[-1]
    d_pad = _round_up(max(D, _LANES), _LANES)
    if d_pad != D:
        pad = [(0, 0)] * (q.ndim - 1) + [(0, d_pad - D)]
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    return q, k, v, D, d_pad


@functools.partial(
    jax.jit,
    static_argnames=("params", "scale", "causal", "block_q", "block_kv", "interpret"),
)
def flash_exaq_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    params,
    scale: float,
    causal: bool = True,
    *,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused EXAQ flash attention forward. q:(B,H,Sq,D) k,v:(B,Hkv,Skv,D)."""
    assert causal, "use exaq_decode_attention for the non-causal decode path"
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = H // Hkv
    q, k, v, D, d_pad = _common_prep(q, k, v, scale)
    sq_pad = _round_up(Sq, block_q)
    skv_pad = _round_up(Skv, block_kv)
    if sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - Sq), (0, 0)))
    if skv_pad != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - Skv), (0, 0)))
    nq, nkv = sq_pad // block_q, skv_pad // block_kv
    lut = tuple(float(x) for x in params.lut_np())
    kern = functools.partial(
        _causal_kernel,
        block_q=block_q, block_kv=block_kv, nkv=nkv, sq=Sq, skv=Skv,
        levels=params.levels, clip=float(params.clip), lut=lut, scale=float(scale),
    )
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_pad), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d_pad), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d_pad), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_pad), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_pad, d_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :D]


@functools.partial(
    jax.jit,
    static_argnames=("params", "scale", "block_kv", "interpret"),
)
def exaq_decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_lens: jnp.ndarray,
    params,
    scale: float,
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-token decode attention with EXAQ softmax over the KV cache.

    q: (B, H, 1, D); k, v: (B, Hkv, S, D) cache; kv_lens: (B,) live lengths.
    The GQA query group for one kv head becomes the q-block rows.
    """
    B, H, one, D = q.shape
    assert one == 1
    _, Hkv, Skv, _ = k.shape
    group = H // Hkv
    q = q.reshape(B, Hkv, group, D)
    q, k, v, D, d_pad = _common_prep(q, k, v, scale)
    block_q = _round_up(max(group, 8), 8)
    if block_q != group:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, block_q - group), (0, 0)))
    skv_pad = _round_up(Skv, block_kv)
    if skv_pad != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - Skv), (0, 0)))
    nkv = skv_pad // block_kv
    lut = tuple(float(x) for x in params.lut_np())
    lens2 = kv_lens.reshape(B, 1).astype(jnp.int32)
    kern = functools.partial(
        _decode_kernel,
        block_q=block_q, block_kv=block_kv, nkv=nkv, skv=Skv,
        levels=params.levels, clip=float(params.clip), lut=lut, scale=float(scale),
    )
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, 1, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_pad), lambda b, h, i, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d_pad), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d_pad), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_pad), lambda b, h, i, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, block_q, d_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lens2)
    out = out[:, :, :group, :D].reshape(B, H, 1, D)
    return out
