"""Public kernel entry points.

Auto-selects Pallas (TPU) vs interpret mode (CPU validation) vs pure-jnp
reference, and provides the chunked two-pass path for rows too long for one
VMEM tile. All functions are shape-polymorphic over leading dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantParams
from repro.kernels import ref
from repro.kernels.exaq_attention import exaq_decode_attention, flash_exaq_attention
from repro.kernels.exaq_paged_attention import exaq_paged_decode_attention
from repro.kernels.exaq_paged_prefill import exaq_paged_prefill_attention
from repro.kernels.exaq_softmax import exaq_softmax_pallas

# Rows longer than this take the chunked path (fp32 row bytes vs ~16 MiB VMEM).
MAX_FUSED_COLS = 32768


@functools.lru_cache(maxsize=1)
def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def exaq_softmax(
    x: jnp.ndarray,
    params: QuantParams,
    lens: jnp.ndarray | None = None,
    *,
    block_rows: int = 8,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """EXAQ softmax over the last axis (paper Algo. 2)."""
    n = x.shape[-1]
    if not use_kernel:
        return ref.exaq_softmax_ref(x, params, lens=lens)
    if n > MAX_FUSED_COLS:
        return exaq_softmax_chunked(x, params, lens=lens)
    return exaq_softmax_pallas(x, params, lens, block_rows=block_rows, interpret=on_cpu())


def exaq_softmax_chunked(
    x: jnp.ndarray,
    params: QuantParams,
    lens: jnp.ndarray | None = None,
    chunk: int = 16384,
) -> jnp.ndarray:
    """Two-pass EXAQ softmax for very long rows (e.g. 512k decode scores).

    Pass 1 scans ``chunk``-sized slices for the global row max. Pass 2
    re-scans, quantizing each slice on the grid anchored at that max and
    accumulating the 2^M *integer* histogram partials — counts compose exactly
    across chunks because the grid is global, the same property the
    distributed seq-parallel combine exploits (counts all-reduce). The
    savings vs the one-shot path is in the *intermediates*: no fp32
    LUT-select tensor or int32 code tensor is ever materialized row-wide —
    each scan step touches one chunk and the row-wide residue is the narrow
    integer codes (int8 up to 7-bit quantizers), which the final LUT + divide
    replays chunk-by-chunk. (The fp32 input itself stays live as the scan
    operand; XLA may alias it, but don't budget on that.)
    """
    xf = x.astype(jnp.float32)
    orig_shape = xf.shape
    n = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    xf = xf.reshape(rows, n)
    eff = (lens.reshape(rows).astype(jnp.int32) if lens is not None
           else jnp.full((rows,), n, jnp.int32))
    nc = -(-n // chunk)
    if nc * chunk != n:
        xf = jnp.pad(xf, ((0, 0), (0, nc * chunk - n)))
    # chunk axis leads so lax.scan slices one (rows, chunk) tile per step
    xc = jnp.moveaxis(xf.reshape(rows, nc, chunk), 1, 0)
    cols = jnp.arange(chunk, dtype=jnp.int32)
    levels = params.levels
    inv_delta = levels / (-params.clip)
    lutv = tuple(float(v) for v in params.lut_np())
    # int8 halves the stored-codes footprint but only holds codes <= 127
    code_dtype = jnp.int8 if levels <= 128 else jnp.int32

    def chunk_valid(j):
        return (j * chunk + cols)[None, :] < eff[:, None]  # (rows, chunk)

    # ---- pass 1: global row max over chunks
    def max_body(m, xs):
        sl, j = xs
        m_j = jnp.max(jnp.where(chunk_valid(j), sl, -1e30), axis=-1)
        return jnp.maximum(m, m_j), None

    m, _ = jax.lax.scan(max_body, jnp.full((rows,), -1e30, jnp.float32),
                        (xc, jnp.arange(nc)))
    m = m[:, None]

    # ---- pass 2: per-chunk quantize + histogram partials (int accumulators)
    def quant_body(counts, xs):
        sl, j = xs
        valid = chunk_valid(j)
        codes = jnp.clip(
            jnp.floor((sl - m - params.clip) * inv_delta), 0, levels - 1
        ).astype(code_dtype)
        onehot = (codes[..., None] == jnp.arange(levels, dtype=code_dtype)) & valid[..., None]
        counts = counts + jnp.sum(onehot, axis=1, dtype=jnp.int32)  # (rows, levels)
        return counts, codes

    counts, codes = jax.lax.scan(
        quant_body, jnp.zeros((rows, levels), jnp.int32), (xc, jnp.arange(nc))
    )
    denom = counts.astype(jnp.float32) @ jnp.asarray(lutv, jnp.float32)  # (rows,)
    denom = jnp.maximum(denom, 1e-30)[:, None]

    # ---- emit: LUT + normalize, replayed from the stored int8 codes
    def emit_body(_, xs):
        cj, j = xs
        e = jnp.where(chunk_valid(j), ref._lut_select(cj, lutv), 0.0)
        return None, e / denom

    _, out = jax.lax.scan(emit_body, None, (codes, jnp.arange(nc)))
    out = jnp.moveaxis(out, 0, 1).reshape(rows, nc * chunk)[:, :n]
    return out.reshape(orig_shape).astype(x.dtype)


def exaq_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    params: QuantParams,
    scale: float,
    causal: bool = True,
    *,
    block_q: int = 128,
    block_kv: int = 128,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Fused flash attention with EXAQ softmax. q:(B,H,Sq,D) k,v:(B,Hkv,Skv,D)."""
    if not use_kernel:
        kr, vr = _repeat_kv(q, k, v)
        return ref.flash_exaq_attention_ref(q, kr, vr, params, scale, causal=causal, block_kv=block_kv)
    return flash_exaq_attention(
        q, k, v, params, scale, causal, block_q=block_q, block_kv=block_kv, interpret=on_cpu()
    )


def window_valid_mask(width: int, upto: jnp.ndarray) -> jnp.ndarray:
    """Per-row live-window mask for paged attention windows.

    ``upto``: (S, Q) int32 *exclusive* upper bounds — decode passes
    ``kv_lens[:, None]`` (each slot's single query row sees [0, len)),
    chunked prefill passes ``start + row + 1`` per chunk row (causal by
    global position). Returns (S, 1, Q, width) bool, broadcast over heads —
    the ONE construction of the window-validity mask, shared by
    ``attention_decode_paged``, ``attention_prefill_chunk``, and the fused
    kernels' gather oracles (they must mask identically for parity to hold).
    """
    cols = jnp.arange(width, dtype=jnp.int32)
    return cols[None, None, None, :] < upto[:, None, :, None]


def exaq_weights_ref(s: jnp.ndarray, valid: jnp.ndarray, params: QuantParams) -> jnp.ndarray:
    """Global-grid Algo. 2 weights from raw scores (..., Q, W): anchor at the
    masked row max, quantize, LUT, zero masked lanes, normalize (guarded).
    The jnp oracle both paged kernels are tested against."""
    m = jnp.max(jnp.where(valid, s, -1e30), axis=-1, keepdims=True)
    inv_delta = params.levels / (-params.clip)
    codes = jnp.clip(jnp.floor((s - m - params.clip) * inv_delta), 0, params.levels - 1)
    lutv = tuple(float(v) for v in params.lut_np())
    e = jnp.where(valid, ref._lut_select(codes, lutv), 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_lens: jnp.ndarray,
    params: QuantParams,
    scale: float,
    *,
    block_kv: int = 512,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Single-step decode attention over a KV cache with EXAQ softmax."""
    if not use_kernel:
        kr, vr = _repeat_kv(q, k, v)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32) * scale
        valid = window_valid_mask(kr.shape[2], kv_lens.astype(jnp.int32)[:, None])
        p = exaq_weights_ref(s, valid, params)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return exaq_decode_attention(q, k, v, kv_lens, params, scale, block_kv=block_kv, interpret=on_cpu())


def repeat_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """Broadcast kv heads to the query-head count for GQA: (…, KV, S, Dh) ->
    (…, KV*group, S, Dh). The ONE shared implementation — model paths and
    kernel references both route here; fused kernels avoid the repeat
    entirely via kv-index maps / grouped-q layouts, so any call to this is
    a materialized group-factor copy worth engineering away."""
    if group == 1:
        return x
    return jnp.repeat(x, group, axis=1)


def _repeat_kv(q, k, v):
    group = q.shape[1] // k.shape[1]
    return repeat_kv(k, group), repeat_kv(v, group)


# ------------------------------------------------- quantized KV-pool codec

# The int8 per-block-scale and packed-int4 sub-block-scale codecs live in
# kernels/kv_codec.py (the fused kernels import from there directly — an
# import of this module back would be circular) and are re-exported here as
# the public API the engine, scatter paths, and tests use.
from repro.kernels.kv_codec import (  # noqa: E402, F401
    INT4_BIAS,
    INT4_QMAX,
    INT4_SUB_LEVELS,
    INV_SUB_LEVELS,
    KV_QMAX,
    KV_SCALE_MARGIN,
    KV_SUB_BLOCK,
    kv4_dequantize_block,
    kv4_effective_scale,
    kv4_num_sub,
    kv4_quantize,
    kv4_sub_block,
    kv4_write_block_scales,
    kv4_write_sub_scales,
    kv_cache_is_int4,
    kv_cache_is_quantized,
    kv_pack_int4,
    kv_quantize,
    kv_unpack_int4,
    kv_write_scales,
)


def gather_block_kv(pool_k: jnp.ndarray, pool_v: jnp.ndarray, block_tables: jnp.ndarray,
                    kv_lens: jnp.ndarray | None = None,
                    k_scale: jnp.ndarray | None = None,
                    v_scale: jnp.ndarray | None = None,
                    k_sub: jnp.ndarray | None = None,
                    v_sub: jnp.ndarray | None = None):
    """Assemble per-slot contiguous KV from a paged block pool (DESIGN.md §3).

    pool_{k,v}: (N, KV, bs, Dh) global block pool; block_tables: (S, MB) int32
    block ids per slot -> (S, KV, MB*bs, Dh) laid out in block-table order, so
    flat position ``p`` of slot ``s`` is block ``block_tables[s, p // bs]``
    offset ``p % bs`` — the invariant every paged caller masks against via
    ``kv_lens``. Table padding (the null block, id 0) gathers garbage that the
    length mask excludes.

    ``kv_lens`` — (S,) live tokens per slot, or a scalar broadcast to every
    slot (the chunked-prefill call site passes its scalar window length
    ``start + C`` directly) — when given, clamps each slot's table to its
    live block count (ceil(len/bs)): dead-tail entries are redirected to the
    null block before the gather, so the reference path reads each slot's
    live blocks plus one shared null block instead of the full rectangular
    table (shapes stay static — the clamp is a ``where``, not a slice, so it
    works under jit with traced lengths). Results are unchanged: dead-tail
    lanes were always masked out by the caller.

    ``k_scale``/``v_scale`` (N, KV) fp32, required for an int8 pool
    (DESIGN.md §6): each gathered block is dequantized ``codes * scale``
    before assembly, so callers always see fp values — this is the
    *dequantizing oracle* the fused int8 kernel is tested against. A packed
    int4 pool (uint8 payload, DESIGN.md §10) additionally requires the
    ``k_sub``/``v_sub`` (N, KV, n_sub) uint8 sub-block scale codes; blocks
    are nibble-unpacked and dequantized at
    ``block_scale * sub_code / 15`` per sub-block during assembly — the
    dequantizing oracle of the fused int4 kernels.

    The gather still materializes each slot's window once per layer; the
    fused kernel (``kernels/exaq_paged_attention.py``) streams blocks
    through VMEM instead and is the serving hot path. This stays as the
    interpret-mode / oracle reference.
    """
    int8_pool = pool_k.dtype == jnp.int8
    int4_pool = pool_k.dtype == jnp.uint8
    want_scales = int8_pool or int4_pool
    if (k_scale is not None) != want_scales or (v_scale is not None) != want_scales:
        raise ValueError(
            "quantized (int8/int4) pools require both k_scale and v_scale; fp pools forbid them"
        )
    if (k_sub is not None) != int4_pool or (v_sub is not None) != int4_pool:
        raise ValueError(
            "packed int4 pools require both k_sub and v_sub sub-scale planes; "
            "other pools forbid them"
        )
    if kv_lens is not None:
        MB = block_tables.shape[1]
        bs = pool_k.shape[2]
        kv_lens = jnp.broadcast_to(jnp.asarray(kv_lens, jnp.int32).reshape(-1),
                                   (block_tables.shape[0],))
        live = jnp.arange(MB, dtype=jnp.int32)[None, :] * bs < kv_lens[:, None]
        block_tables = jnp.where(live, block_tables, 0)  # 0 == kv_pool.NULL_BLOCK

    def g(pool, scale, sub):
        b = pool[block_tables]  # (S, MB, KV, bs, Dh) — or (…, bs, Dh//2) packed
        if sub is not None:
            b = kv4_dequantize_block(b, scale[block_tables], sub[block_tables])
        elif scale is not None:
            b = b.astype(jnp.float32) * scale[block_tables][..., None, None]
        b = jnp.swapaxes(b, 1, 2)  # (S, KV, MB, bs, Dh)
        S, KV, MB, bs, Dh = b.shape
        return b.reshape(S, KV, MB * bs, Dh)

    return g(pool_k, k_scale, k_sub), g(pool_v, v_scale, v_sub)


# ------------------------------------------------ tensor-parallel dispatch

def _tp_mesh(num_kv_heads: int):
    """The ambient mesh, when its 'model' axis can split the kv heads.

    Trace-time discovery: the serving device layer (runtime/device_step.py)
    activates its mesh via ``sharding.use_mesh`` around every jitted call, so
    this sees it while the engine functions are being traced. No mesh, a
    1-sized 'model' axis, or a head count the axis does not divide all
    return None — the caller stays on the single-shard path, mirroring
    ``sharding.block_pool_spec``'s replicated fallback (DESIGN.md §9).
    """
    from repro.runtime import sharding as shd

    mesh = shd.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    tp = mesh.shape["model"]
    if tp <= 1 or num_kv_heads % tp != 0:
        return None
    return mesh


def _tp_paged_attention(mesh, local_fn, head_args, table_args, scales):
    """shard_map a fused paged kernel over the 'model' axis (DESIGN.md §9).

    ``head_args`` (q and the two pool planes) shard their head axis — axis 1
    on every one of them — so each shard DMAs only its local heads from a
    local pool partition; ``table_args`` (block tables, lens, start) stay
    replicated scalar-prefetch inputs; quantized-pool ``scales`` follow the
    pool's head split on *their* axis 1, whatever their rank — int8's
    (N, KV) block-scale planes and int4's (N, KV, n_sub) sub-code planes
    both shard kv-heads. Because q heads and kv heads shard by the same
    factor, a shard's query group h // group lands exactly on its local kv
    heads — the kernels' index maps need no global-head offsets, and each
    (slot, head) row is computed whole on exactly one shard, so the sharded
    kernel is *bit-exact* against the single-shard one. The output is
    re-replicated before returning: the caller's cross-head ``wo``
    contraction must run whole on every shard, or fp reassociation in a
    partitioned psum would break greedy parity vs a single-shard engine.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    heads = P(None, "model", None, None)  # q / pool planes are all rank 4
    in_specs = (
        tuple(heads for _ in head_args)
        + tuple(P(*(None,) * jnp.ndim(a)) for a in table_args)
        + tuple(
            P(*("model" if i == 1 else None for i in range(jnp.ndim(a))))
            for a in scales
        )
    )
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=P(None, "model", None, None),
        check_rep=False,
    )
    out = fn(*head_args, *table_args, *scales)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P()))


def _pool_scale_args(k_scale, v_scale, k_sub, v_sub):
    """Pool scale arrays as a positional tuple: () fp, 2 int8, 4 int4."""
    if k_sub is not None:
        return (k_scale, v_scale, k_sub, v_sub)
    if k_scale is not None:
        return (k_scale, v_scale)
    return ()


def paged_decode_attention(
    q: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    block_tables: jnp.ndarray,
    kv_lens: jnp.ndarray,
    params: QuantParams,
    scale: float,
    *,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    k_sub: jnp.ndarray | None = None,
    v_sub: jnp.ndarray | None = None,
    block_kv: int = 512,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Decode attention over a block-paged KV cache with EXAQ softmax.

    ``use_kernel=True`` (the serving hot path) dispatches the fused Pallas
    kernel (``kernels/exaq_paged_attention.py``): block-table-indexed K/V
    DMA straight from the pool, EXAQ quantize + LUT accumulation in VMEM,
    and the two-pass global-grid chunk combine — no dense KV copy ever
    exists in HBM. On CPU the same kernel runs in interpret mode.

    ``use_kernel=False`` keeps the gather-then-dispatch reference: assemble
    each slot's window (live blocks only — dead tails clamp to the null
    block) and run the global-grid jnp path. Both anchor the quantization
    grid at the global row max, so per-block partial counts add exactly and
    paging composes with the DESIGN.md §2 combine — block boundaries are
    invisible to the softmax, and the two paths agree to fp32 roundoff.

    For an int8 pool (DESIGN.md §6) pass ``k_scale``/``v_scale`` (N, KV):
    the fused kernel scalar-prefetches them and dequantizes blocks in VMEM;
    the gather path dequantizes during assembly — either way dequant never
    round-trips through HBM at fp width. For a packed int4 pool (DESIGN.md
    §10) additionally pass ``k_sub``/``v_sub`` (N, KV, n_sub): the fused
    kernel unpacks nibbles in VMEM right after each half-width block DMA.

    q: (S, H, 1, Dh); pool_{k,v}: (N, KV, bs, Dh); block_tables: (S, MB);
    kv_lens: (S,) live positions per slot -> (S, H, 1, Dh).
    """
    if use_kernel:
        mesh = _tp_mesh(pool_k.shape[1])
        if mesh is not None:
            def local(q, pk, pv, bt, kl, *scales):
                ks, vs, ksub, vsub = (tuple(scales) + (None,) * 4)[:4]
                return exaq_paged_decode_attention(
                    q, pk, pv, bt, kl, params, scale,
                    k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                    interpret=on_cpu()
                )

            return _tp_paged_attention(
                mesh, local, (q, pool_k, pool_v), (block_tables, kv_lens),
                _pool_scale_args(k_scale, v_scale, k_sub, v_sub),
            )
        return exaq_paged_decode_attention(
            q, pool_k, pool_v, block_tables, kv_lens, params, scale,
            k_scale=k_scale, v_scale=v_scale, k_sub=k_sub, v_sub=v_sub,
            interpret=on_cpu()
        )
    k, v = gather_block_kv(pool_k, pool_v, block_tables, kv_lens,
                           k_scale, v_scale, k_sub, v_sub)
    return decode_attention(q, k, v, kv_lens, params, scale, block_kv=block_kv, use_kernel=False)


def paged_prefill_attention(
    q: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    block_table: jnp.ndarray,
    start,
    params: QuantParams,
    scale: float,
    *,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    k_sub: jnp.ndarray | None = None,
    v_sub: jnp.ndarray | None = None,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """One chunk of chunked-prefill attention over a block-paged KV cache.

    The prefill-side mirror of ``paged_decode_attention`` (DESIGN.md §7):
    the chunk's K/V are already scattered into the pool; this attends the
    chunk's C query rows (global positions ``start + i``) causally against
    the request's whole window, reading K/V from the pool.

    ``use_kernel=True`` (the serving hot path) dispatches the fused Pallas
    kernel (``kernels/exaq_paged_prefill.py``): block-table-indexed K/V DMA
    straight from the pool, EXAQ quantize + LUT accumulation in VMEM, and
    the two-pass global-grid combine — the dense per-chunk window copy the
    gather materializes never exists, so prefill bytes stop growing
    O(prompt²) in copies. On CPU the same kernel runs in interpret mode.

    ``use_kernel=False`` keeps the gather-then-attend reference: assemble
    the window (live blocks only — entries at/past ``ceil((start+C)/bs)``
    clamp to the null block) and run the global-grid jnp path. Both anchor
    each row's quantization grid at its true global max, so chunking is
    invisible to the softmax (§2) and the two paths agree to fp32 roundoff.

    For an int8 pool (DESIGN.md §6) pass ``k_scale``/``v_scale`` (N, KV):
    the fused kernel scalar-prefetches them and dequantizes blocks in VMEM;
    the gather path dequantizes during assembly. For a packed int4 pool
    (DESIGN.md §10) additionally pass ``k_sub``/``v_sub`` (N, KV, n_sub).

    q: (1, H, C, Dh); pool_{k,v}: (N, KV, bs, Dh); block_table: (MB,);
    start: scalar int32 tokens already cached -> (1, H, C, Dh) fp32.
    """
    if use_kernel:
        mesh = _tp_mesh(pool_k.shape[1])
        if mesh is not None:
            start_arr = jnp.asarray(start, jnp.int32)

            def local(q, pk, pv, bt, st, *scales):
                ks, vs, ksub, vsub = (tuple(scales) + (None,) * 4)[:4]
                return exaq_paged_prefill_attention(
                    q, pk, pv, bt, st, params, scale,
                    k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                    interpret=on_cpu()
                )

            return _tp_paged_attention(
                mesh, local, (q, pool_k, pool_v), (block_table, start_arr),
                _pool_scale_args(k_scale, v_scale, k_sub, v_sub),
            )
        return exaq_paged_prefill_attention(
            q, pool_k, pool_v, block_table, start, params, scale,
            k_scale=k_scale, v_scale=v_scale, k_sub=k_sub, v_sub=v_sub,
            interpret=on_cpu()
        )
    C = q.shape[2]
    kg, vg = gather_block_kv(pool_k, pool_v, block_table[None], start + C,
                             k_scale, v_scale, k_sub, v_sub)  # (1, KV, W, Dh)
    kk, vv = _repeat_kv(q, kg, vg)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    rows = start + jnp.arange(C, dtype=jnp.int32)
    valid = window_valid_mask(kk.shape[2], (rows + 1)[None, :])
    p = exaq_weights_ref(s, valid, params)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
