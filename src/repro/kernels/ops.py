"""Public kernel entry points.

Auto-selects Pallas (TPU) vs interpret mode (CPU validation) vs pure-jnp
reference, and provides the chunked two-pass path for rows too long for one
VMEM tile. All functions are shape-polymorphic over leading dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantParams
from repro.kernels import ref
from repro.kernels.exaq_attention import exaq_decode_attention, flash_exaq_attention
from repro.kernels.exaq_softmax import exaq_softmax_pallas

# Rows longer than this take the chunked path (fp32 row bytes vs ~16 MiB VMEM).
MAX_FUSED_COLS = 32768


@functools.lru_cache(maxsize=1)
def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def exaq_softmax(
    x: jnp.ndarray,
    params: QuantParams,
    lens: jnp.ndarray | None = None,
    *,
    block_rows: int = 8,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """EXAQ softmax over the last axis (paper Algo. 2)."""
    n = x.shape[-1]
    if not use_kernel:
        return ref.exaq_softmax_ref(x, params, lens=lens)
    if n > MAX_FUSED_COLS:
        return exaq_softmax_chunked(x, params, lens=lens)
    return exaq_softmax_pallas(x, params, lens, block_rows=block_rows, interpret=on_cpu())


def exaq_softmax_chunked(
    x: jnp.ndarray,
    params: QuantParams,
    lens: jnp.ndarray | None = None,
    chunk: int = 16384,
) -> jnp.ndarray:
    """Two-pass EXAQ softmax for very long rows (e.g. 512k decode scores).

    Pass 1: global row max. Pass 2: per-chunk quantize + LUT + histogram
    partials; partial *integer counts* compose exactly across chunks because
    the quantization grid is anchored at the global max — the same property the
    distributed seq-parallel combine exploits (counts all-reduce).
    """
    xf = x.astype(jnp.float32)
    n = xf.shape[-1]
    if lens is not None:
        col = jnp.arange(n, dtype=jnp.int32)
        valid = col < lens[..., None]
        xf = jnp.where(valid, xf, -1e30)
    m = jnp.max(xf, axis=-1, keepdims=True)
    xs = xf - m
    inv_delta = params.levels / (-params.clip)
    codes = jnp.clip(jnp.floor((xs - params.clip) * inv_delta), 0, params.levels - 1).astype(jnp.int32)
    lutv = params.lut_np()
    e = jnp.full(xs.shape, float(lutv[0]), jnp.float32)
    for k in range(1, params.levels):
        e = jnp.where(codes == k, float(lutv[k]), e)
    if lens is not None:
        e = jnp.where(valid, e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(denom, 1e-30)


def exaq_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    params: QuantParams,
    scale: float,
    causal: bool = True,
    *,
    block_q: int = 128,
    block_kv: int = 128,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Fused flash attention with EXAQ softmax. q:(B,H,Sq,D) k,v:(B,Hkv,Skv,D)."""
    if not use_kernel:
        kr, vr = _repeat_kv(q, k, v)
        return ref.flash_exaq_attention_ref(q, kr, vr, params, scale, causal=causal, block_kv=block_kv)
    return flash_exaq_attention(
        q, k, v, params, scale, causal, block_q=block_q, block_kv=block_kv, interpret=on_cpu()
    )


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_lens: jnp.ndarray,
    params: QuantParams,
    scale: float,
    *,
    block_kv: int = 512,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Single-step decode attention over a KV cache with EXAQ softmax."""
    if not use_kernel:
        kr, vr = _repeat_kv(q, k, v)
        n = kr.shape[2]
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32) * scale
        valid = jnp.arange(n)[None, None, None, :] < kv_lens[:, None, None, None]
        s = jnp.where(valid, s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        inv_delta = params.levels / (-params.clip)
        codes = jnp.clip(jnp.floor((s - m - params.clip) * inv_delta), 0, params.levels - 1)
        lutv = params.lut_np()
        e = jnp.full(s.shape, float(lutv[0]), jnp.float32)
        for kk in range(1, params.levels):
            e = jnp.where(codes == kk, float(lutv[kk]), e)
        e = jnp.where(valid, e, 0.0)
        p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return exaq_decode_attention(q, k, v, kv_lens, params, scale, block_kv=block_kv, interpret=on_cpu())


def _repeat_kv(q, k, v):
    group = q.shape[1] // k.shape[1]
    if group == 1:
        return k, v
    return jnp.repeat(k, group, axis=1), jnp.repeat(v, group, axis=1)
