"""Pallas TPU kernel: fused paged-decode attention with EXAQ softmax.

The serving hot path this kernel deletes (DESIGN.md §3, fused paged decode):
``gather_block_kv`` materializes a dense ``(slots, KV, max_blocks*bs, Dh)``
copy of every slot's KV window in HBM each decode step — the pool is read
once to build the copy, the copy is written, then read again by attention.
Three rectangular passes of bandwidth to feed a softmax whose whole point
(paper §4, Table 3) is to be cheaper than a memcpy.

Here the block table drives the DMA directly: the grid is
``(slot, kv_head, chunk)`` and the K/V BlockSpec index maps read the
*scalar-prefetched* block table, so each grid step pulls one pool block
``tables[slot, chunk]`` from HBM into VMEM at its natural layout — no
intermediate copy exists. Dead-tail chunks (``chunk * bs >= kv_lens[slot]``)
are remapped to the null block (id 0); consecutive identical indices collapse
to a single DMA, so bytes moved track *live* tokens, not table width.

Chunk-combine semantics are the global grid of ``exaq_softmax_chunked``
(exact Algo. 2, DESIGN.md §2): the chunk axis runs two passes over the
table — pass 1 reduces the global row max across live chunks, pass 2
re-reads K, quantizes every chunk's scores on the grid anchored at that max,
and accumulates the PV numerator plus the 2^M-bin histogram denominator.
Counts on a shared grid add exactly across chunks, so block boundaries are
invisible to the softmax and the kernel is bit-comparable to the
gather-then-dispatch reference (``kernels.ops.paged_decode_attention`` with
``use_kernel=False``) instead of only statistically close like the online
running-max kernels. V is fetched in pass 2 only (its pass-1 index map pins
the null block), so the fused path moves ~2x K + 1x V of *live* window bytes
versus the gather path's live pool read plus two rectangular passes over the
dense copy (see ``paged_decode_bytes_model``).

GQA is native: q is laid out ``(slots, KV, group, Dh)`` so one kv head's
query group forms the q-block rows — K/V are never repeated ``group`` times
in memory (the repeat the unfused path pays via ``repeat_kv``).

Int8 pools (DESIGN.md §6): when the pool stores int8 codes with
per-(block, kv-head) scales, the scales ride the *scalar-prefetch* channel
next to the block tables, and each K/V block is dequantized in VMEM right
after its 8-bit DMA lands — ``codes.astype(f32) * scale[blk, kv_head]`` —
before the EXAQ clip/LUT stages. HBM only ever moves the 1-byte payload,
so the modeled bytes/step drop ~2x vs bf16 (~4x vs fp32); the EXAQ
histogram math downstream is unchanged, and the kernel stays bit-comparable
to the *dequantizing* gather oracle (``gather_block_kv`` with scales).

Packed int4 pools (DESIGN.md §10) halve the payload again: the pool's last
dim is ``Dh/2`` uint8 bytes (two head-dim-adjacent nibbles per byte), and
the per-(block, kv-head, sub-block) uint8 scale codes join the block scales
on the scalar-prefetch channel. The DMA lands the *packed* block in VMEM;
nibbles are split, re-biased, and scaled by
``block_scale * sub_code / 15`` per sub-block row group right there —
no dense dequantized (or even unpacked) copy ever exists in HBM. q/out/acc
stay at the unpacked width ``2 * lane_pad(Dh/2)``; q's zero lane-padding
nulls the K-side garbage that padded nibbles decode to, and the V-side
garbage lands in output lanes >= Dh that the final slice drops.

Layouts: q ``(S, H, 1, Dh)``; pool_k/pool_v ``(N, KV, bs, Dh)`` (int4:
``(N, KV, bs, Dh/2)`` uint8); block_tables ``(S, MB)`` int32; kv_lens
``(S,)`` int32; optional k_scale/v_scale ``(N, KV)`` fp32 and int4-only
k_sub/v_sub ``(N, KV, n_sub)`` uint8. Compiled-mode tiling wants ``bs`` a
multiple of 8 and the pool's last dim lane-padded (both hold for
production shapes; tests run interpret mode where any shape goes).

Tensor-parallel contract (DESIGN.md §9): under a mesh whose 'model' axis
divides KV, ``kernels.ops.paged_decode_attention`` wraps this kernel in a
shard_map that splits q's H axis and the pool's KV axis by the same factor
and replicates tables/lens/scalars. The kernel itself is unchanged — inside
the shard_map its ``pool_k``/``pool_v`` are the *local* head partition and
its grid's kv_head axis runs over local heads only, so index maps never see
a global head id (q head ``h``'s group maps to local kv head ``h // group``
exactly as on one device). Per-(slot, head) rows are computed whole on one
shard, making the sharded kernel bit-exact vs the single-shard dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kv_codec import INT4_BIAS, INV_SUB_LEVELS, kv4_num_sub

_NEG_BIG = -1e30
_LANES = 128


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def exaq_accumulate_stage(s, m, valid, *, levels: int, clip: float, lut: tuple[float, ...]):
    """The EXAQ quantize + LUT + histogram stage shared by BOTH paged
    kernels' accumulate passes (decode and prefill — they must stay
    bit-identical for the decode-vs-prefill parity contract to hold).

    s: (rows, bs) raw scores (masked lanes already at -inf); m: (rows, 1)
    the global row max from pass 1 — the shared quantization anchor; valid:
    (rows, bs) live-lane mask. Returns (e, dden): the LUT-reconstructed
    unnormalized weights (masked lanes zeroed) and this chunk's partial
    histogram denominator — integer counts on the shared grid add exactly
    across chunks (DESIGN.md §2), so no rescale term exists.
    """
    inv_delta = levels / (-clip)
    codes = jnp.clip(jnp.floor((s - m - clip) * inv_delta), 0, levels - 1).astype(jnp.int32)
    # LUT as a select chain (VPU-friendly; gathers would leave the vector unit)
    e = jnp.full(s.shape, lut[0], jnp.float32)
    for kk in range(1, levels):
        e = jnp.where(codes == kk, lut[kk], e)
    e = jnp.where(valid, e, 0.0)
    dden = jnp.zeros((s.shape[0], 1), jnp.float32)
    for kk in range(levels):
        cnt = jnp.sum(jnp.where(valid & (codes == kk), 1, 0).astype(jnp.int32),
                      axis=-1, keepdims=True)
        dden = dden + cnt.astype(jnp.float32) * lut[kk]
    return e, dden


def _paged_decode_kernel(
    tables_ref,
    lens_ref,
    *refs,
    bs: int,
    mb: int,
    block_q: int,
    levels: int,
    clip: float,
    lut: tuple[float, ...],
    scale: float,
    kv_quant: bool,
    kv_int4: bool = False,
    n_sub: int = 0,
    sub_bs: int = 0,
):
    """Grid (S, KV, 2*MB): chunks 0..MB-1 are the max pass, MB..2*MB-1 the
    quantize+accumulate pass. Scratch (m, l, acc) carries across the chunk
    axis; the BlockSpec index maps (not this body) steer the pool DMA.
    ``kv_quant`` pools carry two extra scalar-prefetch refs — the
    per-(block, kv-head) dequant scales (DESIGN.md §6); ``kv_int4`` pools
    carry two more — the (N, KV, n_sub) sub-block scale codes — and their
    K/V refs hold *packed* nibbles at half width (DESIGN.md §10)."""
    if kv_int4:
        (ksc_ref, vsc_ref, ksub_ref, vsub_ref,
         q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    elif kv_quant:
        ksub_ref = vsub_ref = None
        ksc_ref, vsc_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        ksc_ref = vsc_ref = ksub_ref = vsub_ref = None
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    slot = pl.program_id(0)
    head = pl.program_id(1)
    j = pl.program_id(2)
    t = j % mb  # table entry this step touches (same in both passes)
    kv_len = lens_ref[slot]
    live = t * bs < kv_len
    # the block whose payload sits in k_ref/v_ref this step (dead tails are
    # pinned to the null block, whose scale is 0 — masked lanes anyway)
    blk = jnp.where(live, tables_ref[slot, t], 0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    col = t * bs + jax.lax.broadcasted_iota(jnp.int32, (block_q, bs), 1)
    valid = col < kv_len

    def _load_kv(ref, sc_ref, sub_ref):
        """One pool block from its VMEM ref to fp32 rows, dequantized.

        int4: the ref holds packed nibbles (bs, Pp); split/re-bias them to
        (bs, 2*Pp) codes and scale each sub_bs-row group by its effective
        scale ``block_scale * sub_code / 15`` — the same multiply order as
        ``kv_codec.kv4_effective_scale``, so kernel and gather oracle agree
        to fp32 roundoff. The per-row scale column is built by a static loop
        over the n_sub scalar codes (scalar broadcasts, no gather)."""
        x = ref[0, 0]
        if kv_int4:
            lo = (x & 0xF).astype(jnp.int32) - INT4_BIAS
            hi = (x >> 4).astype(jnp.int32) - INT4_BIAS
            codes = jnp.stack([lo, hi], axis=-1).reshape(bs, 2 * x.shape[-1])
            parts = []
            for sg in range(n_sub):
                s_eff = sc_ref[blk, head] * sub_ref[blk, head, sg].astype(jnp.float32) \
                    * INV_SUB_LEVELS
                parts.append(s_eff * jnp.ones((sub_bs, 1), jnp.float32))
            row_scale = jnp.concatenate(parts, axis=0) if n_sub > 1 else parts[0]
            return codes.astype(jnp.float32) * row_scale
        x = x.astype(jnp.float32)
        if kv_quant:
            x = x * sc_ref[blk, head]  # dequant in VMEM: HBM moved 1 byte/elt
        return x

    def _scores():
        q = q_ref[0, 0].astype(jnp.float32)
        k = _load_kv(k_ref, ksc_ref, ksub_ref)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        return jnp.where(valid, s, _NEG_BIG)

    @pl.when((j < mb) & live)
    def _max_pass():
        s = _scores()
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))

    @pl.when((j >= mb) & live)
    def _acc_pass():
        s = _scores()
        m = m_ref[:, :1]  # global row max from pass 1 — shared quantization grid
        e, dden = exaq_accumulate_stage(s, m, valid, levels=levels, clip=clip, lut=lut)
        l_ref[...] = l_ref[...] + dden
        v = _load_kv(v_ref, vsc_ref, vsub_ref)
        acc_ref[...] += jax.lax.dot_general(
            e, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == 2 * mb - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30))[None, None].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "scale", "interpret"),
)
def exaq_paged_decode_attention(
    q: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    block_tables: jnp.ndarray,
    kv_lens: jnp.ndarray,
    params,
    scale: float,
    *,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    k_sub: jnp.ndarray | None = None,
    v_sub: jnp.ndarray | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused paged-decode EXAQ attention over a block pool.

    q: (S, H, 1, D); pool_k/pool_v: (N, KV, bs, D); block_tables: (S, MB)
    int32 block ids (null-block padded); kv_lens: (S,) live tokens per slot.
    An int8 pool additionally takes k_scale/v_scale (N, KV) fp32 dequant
    scales (DESIGN.md §6), scalar-prefetched beside the block tables. A
    packed int4 pool (uint8 payload at last dim D/2, DESIGN.md §10) also
    takes k_sub/v_sub (N, KV, n_sub) uint8 sub-block scale codes; nibbles
    unpack in VMEM after each half-width block DMA.
    Returns (S, H, 1, D) fp32. Global-grid (exact Algo. 2) semantics.
    """
    S, H, one, D = q.shape
    assert one == 1
    N, KV, bs, _ = pool_k.shape
    MB = block_tables.shape[1]
    group = H // KV
    kv_quant = pool_k.dtype == jnp.int8
    kv_int4 = pool_k.dtype == jnp.uint8
    want_scales = kv_quant or kv_int4
    if (k_scale is not None) != want_scales or (v_scale is not None) != want_scales:
        raise ValueError(
            "quantized (int8/int4) pools require both k_scale and v_scale; fp pools forbid them"
        )
    if (k_sub is not None) != kv_int4 or (v_sub is not None) != kv_int4:
        raise ValueError(
            "packed int4 pools require both k_sub and v_sub sub-scale planes; "
            "other pools forbid them"
        )
    q = q.reshape(S, KV, group, D)
    block_q = _round_up(max(group, 8), 8)
    if block_q != group:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, block_q - group), (0, 0)))
    if kv_int4:
        if D % 2 or pool_k.shape[3] != D // 2:
            raise ValueError(
                f"packed int4 pool last dim must be head_dim/2 "
                f"(got pool {pool_k.shape[3]}, head_dim {D})"
            )
        n_sub = k_sub.shape[-1]
        sub_bs = bs // n_sub
        # the packed payload lane-pads at its own (half) width; q/out/acc
        # live at the unpacked width 2*Pp. q's zero padding nulls the K
        # garbage that padded zero-nibbles decode to (code -8 * scale);
        # the V-side garbage lands in output lanes >= D, sliced off below
        p_pad = _round_up(max(D // 2, _LANES), _LANES)
        kv_width = p_pad
        d_pad = 2 * p_pad
        if p_pad != D // 2:
            ppad = ((0, 0), (0, 0), (0, 0), (0, p_pad - D // 2))
            pool_k = jnp.pad(pool_k, ppad)
            pool_v = jnp.pad(pool_v, ppad)
        if d_pad != D:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    else:
        n_sub = sub_bs = 0
        d_pad = _round_up(max(D, _LANES), _LANES)
        kv_width = d_pad
        if d_pad != D:
            # production head dims are lane-aligned; the pad only fires on the
            # small shapes tests use (interpret mode), never on the serving path
            pad = ((0, 0), (0, 0), (0, 0), (0, d_pad - D))
            q = jnp.pad(q, pad)
            pool_k = jnp.pad(pool_k, pad)
            pool_v = jnp.pad(pool_v, pad)

    tables = block_tables.astype(jnp.int32)
    lens = kv_lens.astype(jnp.int32)
    lut = tuple(float(x) for x in params.lut_np())

    def _k_index(s, h, j, tbl, lns, *sc):
        # dead tail -> null block; consecutive identical indices are a
        # single DMA, so dead chunks cost ~nothing
        t = j % MB
        return (jnp.where(t * bs < lns[s], tbl[s, t], 0), h, 0, 0)

    def _v_index(s, h, j, tbl, lns, *sc):
        # V is only consumed by the accumulate pass; pin the max pass (and
        # dead chunks) to the null block so V moves over HBM exactly once
        t = j % MB
        return (jnp.where((j >= MB) & (t * bs < lns[s]), tbl[s, t], 0), h, 0, 0)

    def _q_index(s, h, j, tbl, lns, *sc):
        return (s, h, 0, 0)

    # the dequant scales ride the scalar-prefetch channel: (N, KV) fp32 is
    # SMEM-sized (a few hundred KiB at 7B serving shapes) and the kernel
    # indexes it by the same prefetched table entry that steered the DMA.
    # int4 adds the (N, KV, n_sub) sub codes, widened to int32 (SMEM scalars)
    prefetch = (tables, lens)
    if want_scales:
        prefetch += (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
    if kv_int4:
        prefetch += (k_sub.astype(jnp.int32), v_sub.astype(jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(S, KV, 2 * MB),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_pad), _q_index),
            pl.BlockSpec((1, 1, bs, kv_width), _k_index),
            pl.BlockSpec((1, 1, bs, kv_width), _v_index),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_pad), _q_index),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d_pad), jnp.float32),
        ],
    )
    kern = functools.partial(
        _paged_decode_kernel,
        bs=bs, mb=MB, block_q=block_q,
        levels=params.levels, clip=float(params.clip), lut=lut, scale=float(scale),
        kv_quant=kv_quant, kv_int4=kv_int4, n_sub=n_sub, sub_bs=sub_bs,
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, KV, block_q, d_pad), jnp.float32),
        # only the chunk axis carries scratch state; (slot, kv_head) programs
        # are independent and may partition across cores
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*prefetch, q, pool_k, pool_v)
    return out[:, :, :group, :D].reshape(S, H, 1, D)


KV_DTYPE_BYTES = {"fp32": 4, "bf16": 2, "int8": 1, "int4": 0.5}


def paged_decode_bytes_model(
    *,
    slots: int,
    kv_heads: int,
    max_blocks: int,
    block_size: int,
    head_dim: int,
    kv_lens,
    dtype_bytes: int = 2,
    kv_dtype: str | None = None,
    tp: int = 1,
) -> dict:
    """Modeled HBM KV bytes per decode step per layer: gather vs fused.

    gather_then_read: ``gather_block_kv`` reads each slot's *live* blocks
    from the pool (dead tails clamp to the null block), writes the dense
    rectangular per-slot copy, and attention reads the copy back — so
    (live + 2 x rect) passes over each of K and V. fused_pool_read: the
    kernel touches only live blocks — K twice (max pass + accumulate
    pass), V once. Pure arithmetic so benchmarks and tests can assert the
    >= 2x bandwidth win without hardware counters.

    ``kv_dtype`` ("fp32" | "bf16" | "int8" | "int4") sizes the pool element
    instead of the raw ``dtype_bytes`` knob. int8 (DESIGN.md §6) adds the
    4-byte per-(block, kv-head) scale to every pool-block read, and —
    because the gather oracle dequantizes during assembly — prices the
    gather path's dense intermediate copy at fp32 width, which is what
    actually crosses HBM there. int4 (DESIGN.md §10) halves the payload to
    ``block_size * head_dim / 2`` packed bytes per kv head and adds one
    uint8 sub-block scale code per ``KV_SUB_BLOCK`` tokens on top of the
    fp32 block scale; its dense gather copy is fp32-priced too.

    ``tp`` models the tensor-parallel pool split (DESIGN.md §9): the kv-head
    dim shards over the mesh's 'model' axis, so each shard reads
    ``kv_heads / tp`` heads' worth of every block (payload and scale plane
    alike) and the reported figures are *per-shard* bytes — the quantity
    that bounds a shard's step latency. ``tp`` must divide ``kv_heads``
    (non-divisible counts serve a replicated pool; model that as tp=1).
    """
    import numpy as np

    if kv_heads % tp:
        raise ValueError(f"tp={tp} must divide kv_heads={kv_heads} (replicated fallback is tp=1)")
    kv_heads //= tp
    if kv_dtype is not None:
        dtype_bytes = KV_DTYPE_BYTES[kv_dtype]
    # quantized pools carry scale planes per block read and are dequantized
    # to fp32 by the gather oracle, so the dense copy is fp32-priced
    if kv_dtype == "int4":
        payload_bytes = kv_heads * block_size * head_dim // 2  # packed nibbles
        scale_bytes = kv_heads * (4 + kv4_num_sub(block_size))
        dense_bytes_elt = 4
    elif kv_dtype == "int8":
        payload_bytes = kv_heads * block_size * head_dim
        scale_bytes = kv_heads * 4
        dense_bytes_elt = 4
    else:
        payload_bytes = kv_heads * block_size * head_dim * dtype_bytes
        scale_bytes = 0
        dense_bytes_elt = dtype_bytes

    kv_lens = np.asarray(kv_lens)
    block_bytes = payload_bytes + scale_bytes
    dense_block_bytes = kv_heads * block_size * head_dim * dense_bytes_elt
    rect_blocks = slots * max_blocks
    live_blocks = int(np.sum(-(-kv_lens // block_size)))
    # (read live pool blocks + write/read the dense rectangular copy) x (K+V)
    gather = (live_blocks * block_bytes + 2 * rect_blocks * dense_block_bytes) * 2
    fused = live_blocks * (2 + 1) * block_bytes                 # 2x K + 1x V, live only
    return {
        "kv_dtype": kv_dtype,
        "tp": tp,
        "gather_then_read_bytes": int(gather),
        "fused_pool_read_bytes": int(fused),
        "bytes_reduction_x": gather / max(fused, 1),
        "live_blocks": live_blocks,
        "rect_blocks": int(rect_blocks),
        "block_bytes": int(block_bytes),
    }
