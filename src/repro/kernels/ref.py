"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here with identical semantics
(including the online-blocking order of the flash kernel, so tests can use
tight tolerances). These are also the implementations the higher-level model
code uses on paths where a kernel is not warranted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantParams

_NEG_BIG = -1e30


def _lut_select(codes: jnp.ndarray, lut_vals: tuple[float, ...]) -> jnp.ndarray:
    """LUT lookup as a select chain (what the TPU VPU executes)."""
    e = jnp.full(codes.shape, lut_vals[0], dtype=jnp.float32)
    for k in range(1, len(lut_vals)):
        e = jnp.where(codes == k, lut_vals[k], e)
    return e


def _encode(xs: jnp.ndarray, clip: float, levels: int) -> jnp.ndarray:
    delta = -clip / levels
    return jnp.clip(jnp.floor((xs - clip) / delta), 0, levels - 1).astype(jnp.int32)


def exaq_softmax_ref(
    x: jnp.ndarray,
    params: QuantParams,
    lens: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Oracle for the exaq_softmax kernel. x: (..., n). lens: (...,) int32 or None."""
    x = x.astype(jnp.float32)
    n = x.shape[-1]
    if lens is not None:
        col = jnp.arange(n, dtype=jnp.int32)
        valid = col < lens[..., None]
        x = jnp.where(valid, x, _NEG_BIG)
    else:
        valid = None
    m = jnp.max(x, axis=-1, keepdims=True)
    xs = x - m
    codes = _encode(xs, params.clip, params.levels)
    lut = tuple(float(v) for v in params.lut_np())
    e = _lut_select(codes, lut)
    if valid is not None:
        e = jnp.where(valid, e, 0.0)
    # histogram accumulation (LUT_sum analogue)
    denom = jnp.zeros(x.shape[:-1] + (1,), jnp.float32)
    for k in range(params.levels):
        hit = codes == k
        if valid is not None:
            hit = hit & valid
        denom = denom + jnp.sum(hit, axis=-1, keepdims=True).astype(jnp.float32) * lut[k]
    return e / denom


def mha_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    scale: float,
    causal: bool = True,
) -> jnp.ndarray:
    """Exact attention oracle. q:(B,H,Sq,D) k,v:(B,H,Skv,D)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = q.shape[2], k.shape[2]
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, _NEG_BIG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def flash_exaq_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    params: QuantParams,
    scale: float,
    causal: bool = True,
    block_kv: int = 256,
) -> jnp.ndarray:
    """Oracle for the fused flash-EXAQ kernel, mirroring its online blocking.

    Semantics: per kv-block, scores are quantized on the grid anchored at the
    *running* max; accumulators are rescaled exactly like flash attention.
    q:(B,H,Sq,D) k,v:(B,H,Skv,D) -> (B,H,Sq,D) fp32.
    """
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    lut = tuple(float(x) for x in params.lut_np())
    levels = params.levels
    nkv = -(-Skv // block_kv)
    qi = jnp.arange(Sq, dtype=jnp.int32)[:, None] + (Skv - Sq)

    m0 = jnp.full((B, H, Sq, 1), _NEG_BIG, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)

    def body(carry, j):
        m, den, acc = carry
        start = j * block_kv
        kj = jax.lax.dynamic_slice_in_dim(k, start, block_kv, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, start, block_kv, axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kj.astype(jnp.float32)) * scale
        ki = start + jnp.arange(block_kv, dtype=jnp.int32)[None, :]
        valid = ki < Skv
        if causal:
            valid = valid & (ki <= qi)
        s = jnp.where(valid, s, _NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        codes = _encode(s - m_new, params.clip, levels)
        e = _lut_select(codes, lut)
        e = jnp.where(valid, e, 0.0)
        alpha = jnp.exp(m - m_new)
        # histogram accumulation of the block denominator
        dden = jnp.zeros_like(den)
        for kk in range(levels):
            cnt = jnp.sum((codes == kk) & valid, axis=-1, keepdims=True)
            dden = dden + cnt.astype(jnp.float32) * lut[kk]
        den_new = alpha * den + dden
        acc_new = alpha * acc + jnp.einsum("bhqk,bhkd->bhqd", e, vj.astype(jnp.float32))
        return (m_new, den_new, acc_new), None

    # pad kv to block multiple so dynamic_slice stays in range
    pad = nkv * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    (m, den, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nkv))
    return acc / jnp.maximum(den, 1e-30)


def exaq_attention_global_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    params: QuantParams,
    scale: float,
    causal: bool = True,
) -> jnp.ndarray:
    """EXAQ attention with a *global* quantization grid (exact Algo. 2 semantics);
    used by the unfused model path and the distributed seq-parallel combine."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    sq, skv = q.shape[2], k.shape[2]
    if causal:
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        valid = ki <= qi
        s = jnp.where(valid, s, _NEG_BIG)
    else:
        valid = None
    m = jnp.max(s, axis=-1, keepdims=True)
    codes = _encode(s - m, params.clip, params.levels)
    lut = tuple(float(x) for x in params.lut_np())
    e = _lut_select(codes, lut)
    if valid is not None:
        e = jnp.where(valid, e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
