"""KV-pool storage codecs: int8 per-block scales, packed int4 sub-block scales.

The ONE home of the quantized-pool encode/decode arithmetic (DESIGN.md
§6/§10). ``kernels/ops.py`` re-exports everything here for callers; the
fused Pallas kernels import from here directly (importing ``ops`` back
would be circular), so the in-VMEM dequant and the gather oracle share one
implementation and stay roundoff-comparable by construction.

int8 (DESIGN.md §6): symmetric codes, one fp32 scale per (block, kv-head),
dequant = ``codes * scale``. Scale 0.0 is the "never written" sentinel; a
set scale is first-write-immutable so published prefix bytes never change.

Packed int4 (DESIGN.md §10): two head-dim-adjacent values per uint8 byte
(dim 2j low nibble, dim 2j+1 high nibble, +8 bias on disk, clipped to
±INT4_QMAX on write). The fp32 block scale is kept, and each
KV_SUB_BLOCK-token sub-block adds a 4-bit scale code: effective scale of
sub-block s is ``block_scale * sub_code[s] / 15``. Sub code 0 mirrors the
block-scale sentinel — unset, decodes to exactly zero — and set codes are
immutable under the same I2 byte-stability argument.
"""

from __future__ import annotations

import jax.numpy as jnp

# --------------------------------------------------------- int8 KV blocks

# Symmetric int8 with per-(block, kv-head) scales: dequant is codes * scale
# (DESIGN.md §6). A block's scale is fixed by its FIRST write — the margin
# leaves headroom so later appends into the same block saturate rarely
# instead of ever requantizing published rows (which would break the
# prefix-hash byte-stability invariant, I2).
KV_QMAX = 127.0
KV_SCALE_MARGIN = 1.5


def kv_write_scales(amax, old_scale):
    """Scale update for an int8 KV scatter (DESIGN.md §6).

    amax: per-(target-block, kv-head) max |value| of the rows being written;
    old_scale: the blocks' current scales, 0.0 meaning "never written" (fresh
    pool / host-reset on alloc). A set scale is immutable — appends quantize
    against it (saturating); an unset one is seeded with
    ``KV_SCALE_MARGIN * amax / KV_QMAX`` so the first write lands well inside
    the int8 range and near-stationary later rows still fit.
    """
    return jnp.where(old_scale > 0.0, old_scale, KV_SCALE_MARGIN * amax / KV_QMAX)


def kv_quantize(x, scale):
    """fp values -> int8 codes at ``scale`` (dequant = codes * scale).

    scale broadcasts against x; zero scale (only possible when x is all-zero,
    since scales seed from amax) maps to code 0 rather than dividing by zero.
    """
    s = jnp.where(scale > 0.0, scale, 1.0)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s), -KV_QMAX, KV_QMAX).astype(jnp.int8)


# --------------------------------------------------------- int4 KV blocks

INT4_QMAX = 7.0
INT4_BIAS = 8
KV_SUB_BLOCK = 4  # tokens per sub-block scale group
INT4_SUB_LEVELS = 15.0  # sub codes 1..15; effective scale = block * code / 15
INV_SUB_LEVELS = 1.0 / INT4_SUB_LEVELS


def kv4_sub_block(block_size: int) -> int:
    """Tokens per sub-block scale group (block_size-capped)."""
    sub = min(KV_SUB_BLOCK, block_size)
    if block_size % sub != 0:
        raise ValueError(f"block_size {block_size} not divisible by sub-block {sub}")
    return sub


def kv4_num_sub(block_size: int) -> int:
    """Sub-block scale entries per block."""
    return block_size // kv4_sub_block(block_size)


def kv_cache_is_int4(cache_dtype) -> bool:
    """True iff ``cache_dtype`` names the packed-int4 pool format.

    int4 has no jnp dtype, so it travels as the string sentinel ``"int4"``
    (pool payload dtype uint8). Every ``jnp.dtype(cache_dtype)`` call site
    must route through here first — ``jnp.dtype("int4")`` raises.
    """
    return isinstance(cache_dtype, str) and cache_dtype == "int4"


def kv_cache_is_quantized(cache_dtype) -> bool:
    """True for pool formats that carry scale planes (int8 or packed int4)."""
    return kv_cache_is_int4(cache_dtype) or jnp.dtype(cache_dtype) == jnp.int8


def kv_pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Signed 4-bit codes in [-8, 7] -> packed uint8, two per byte.

    Packing pairs head-dim-adjacent values (last axis, which must be even):
    byte j holds dim 2j in the low nibble and dim 2j+1 in the high nibble,
    each biased by +8. Pairing along the head dim keeps every token row's
    bytes self-contained, so a single-token decode scatter rewrites whole
    bytes and never read-modify-writes a neighbour token's data.
    """
    u = (codes.astype(jnp.int32) + INT4_BIAS).astype(jnp.uint8)
    return u[..., 0::2] | (u[..., 1::2] << 4)


def kv_unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Packed uint8 -> signed int32 codes in [-8, 7], last axis doubled.

    Exact inverse of ``kv_pack_int4`` for every one of the 16 code points
    (asserted exhaustively in tests/test_kv_packing.py).
    """
    lo = (packed & 0xF).astype(jnp.int32) - INT4_BIAS
    hi = (packed >> 4).astype(jnp.int32) - INT4_BIAS
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], 2 * packed.shape[-1])


def kv4_write_block_scales(amax, old_scale):
    """Block-scale update for an int4 KV scatter — the §6 rule at the int4
    range: an unset (0.0) scale seeds to ``KV_SCALE_MARGIN * amax /
    INT4_QMAX`` and is immutable afterwards. The int8 seed (/127) would be
    ~18x too small here: sub codes only span 1..15, so the effective scale
    ``block_scale * code / 15`` can never exceed the block scale, and a
    block scale sized for ±127 codes would saturate every ±7 code.
    """
    return jnp.where(old_scale > 0.0, old_scale, KV_SCALE_MARGIN * amax / INT4_QMAX)


def kv4_write_sub_scales(amax_sub, block_scale, old_sub):
    """Sub-block scale-code update for an int4 KV scatter (DESIGN.md §10).

    amax_sub: per-(target-block, kv-head, sub-block) max |value| of the rows
    being written; block_scale: the blocks' (already-seeded) fp32 scales;
    old_sub: current uint8 sub codes, 0 meaning "never written". A set code
    is immutable; an unset one seeds to the smallest code whose effective
    scale ``block_scale * code / 15`` keeps the margined amax inside ±7 —
    ``ceil(15 * MARGIN * amax / (7 * block_scale))`` clipped to [1, 15]. A
    sub-block whose writes are all-zero (amax 0) stays unset and decodes to
    exactly zero.
    """
    bs = jnp.maximum(block_scale[..., None], 1e-30)
    c = jnp.ceil(INT4_SUB_LEVELS * KV_SCALE_MARGIN * amax_sub / (INT4_QMAX * bs))
    c = jnp.clip(c, 1.0, INT4_SUB_LEVELS)
    seeded = jnp.where(amax_sub > 0.0, c, 0.0).astype(jnp.uint8)
    return jnp.where(old_sub > 0, old_sub, seeded)


def kv4_effective_scale(block_scale, sub_codes):
    """(…,) block scale + (…, n_sub) sub codes -> (…, n_sub) fp32 scales.

    The ONE place the dequant-scale arithmetic lives: fused kernels and the
    gather oracle must multiply in this exact order (block * code, then
    * 1/15) for their fp32 results to stay roundoff-comparable.
    """
    return block_scale[..., None] * sub_codes.astype(jnp.float32) * INV_SUB_LEVELS


def kv4_quantize(x, s_eff):
    """fp values -> packed uint8 nibbles at per-token scales ``s_eff``.

    x: (..., T, Dh) values, s_eff: (..., T) effective sub-block scales
    (broadcast over Dh). Zero scale (all-zero writes) maps to code 0.
    """
    s = jnp.where(s_eff > 0.0, s_eff, 1.0)[..., None]
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -INT4_QMAX, INT4_QMAX)
    return kv_pack_int4(codes.astype(jnp.int32))


def kv4_dequantize_block(packed, block_scale, sub_codes):
    """Packed block rows -> fp32 values (the gather-oracle dequant).

    packed: (..., bs, Dh//2) uint8; block_scale: (...); sub_codes:
    (..., n_sub) uint8 with n_sub dividing bs. Unset scales (block 0.0 or
    sub code 0) decode to exactly zero — the dead-tail/null-block property
    the fused kernels rely on.
    """
    codes = kv_unpack_int4(packed)
    bs = packed.shape[-2]
    sub = bs // sub_codes.shape[-1]
    per_tok = jnp.repeat(kv4_effective_scale(block_scale, sub_codes), sub, axis=-1)
    return codes.astype(jnp.float32) * per_tok[..., None]
