"""Serving driver: batched prefill + greedy decode with EXAQ softmax.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --prompt-len 64 --gen 32 --impl exaq --bits 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import serve as serve_rt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--impl", default="exaq", choices=["exact", "exaq", "naive"])
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--clip-rule", default="paper", choices=["paper", "analytic"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_quant(softmax_impl=args.impl, bits=args.bits, clip_rule=args.clip_rule)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), jnp.bfloat16)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.frontend == "vlm":
        batch["vision_embeds"] = jnp.asarray(rng.normal(0, 1, (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.asarray(rng.normal(0, 1, (B, cfg.enc_seq, cfg.frontend_dim)), jnp.float32)

    prefill, decode = serve_rt.make_serve_fns(cfg)
    cache = serve_rt.init_cache(cfg, B, S + args.gen)
    jp = jax.jit(prefill)
    jd = jax.jit(decode)

    t0 = time.time()
    logits, cache = jp(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache, _ = jd(params, tok, cache)
        outs.append(tok)
    jax.block_until_ready(outs[-1])
    t_decode = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} impl={args.impl} int{args.bits}")
    print(f"prefill: {B}x{S} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s, includes compile)")
    print(f"decode:  {B}x{args.gen-1} tokens in {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(B, 2)):
        print(" ", np.asarray(gen[b])[:16].tolist())


if __name__ == "__main__":
    main()
