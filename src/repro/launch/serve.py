"""Serving driver: continuous-batching engine with EXAQ softmax.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 8 --slots 4 --prompt-len 64 --gen 32 --impl exaq --bits 2 \
        --temperature 0.8 --top-k 40

Attention token decoders (dense/moe) run through ``runtime.engine`` — ragged
prompt lengths, slot refill, per-request sampling, one jitted decode step for
all active slots. ``--paged`` swaps in the block-paged engine (DESIGN.md §3):
a global KV block pool with shared-prefix reuse and chunked prefill
(``--block-size`` / ``--prefill-chunk`` / ``--num-blocks`` tune it;
``--fused`` / ``--no-fused`` pick the fused Pallas paged-decode +
paged-prefill kernels vs the gather-then-dispatch references for paged
attention — DESIGN.md §3/§7; ``--kv-dtype int8`` stores the pool as int8
codes with per-block scales, dequantized inside the fused kernels —
DESIGN.md §6 — and ``--kv-dtype int4`` packs two values per byte with
4-bit per-sub-block scale codes on top, nibble-unpacked in VMEM —
DESIGN.md §10); with ``--shared-prefix N``
every request opens with the same N-token system prompt, so the printed
prefix-cache hit rate shows the reuse win. ``--tp N`` shards each block
pool's kv-head axis over an N-way 'model' mesh axis and ``--dp M`` runs M
independent engine replicas behind one shared admission queue
(DESIGN.md §9) — both paged-only; greedy tokens stay bit-exact across any
dp/tp layout. Other families fall back to the rectangular greedy loop in
``runtime.serve.generate``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import serve as serve_rt
from repro.runtime.engine_core import EngineConfig, Request
from repro.runtime.sampling import SamplingParams


def args_to_config(args) -> EngineConfig:
    """The parsed CLI namespace -> one ``EngineConfig`` — THE construction
    path for every engine this driver builds (slot, paged, data-parallel,
    online). Pure over the namespace, so unit tests exercise the mapping
    without devices. ``max_seq`` covers the worst prompt (shared prefix +
    ragged prompt cap) plus the generation budget."""
    online = getattr(args, "online", False)
    return EngineConfig(
        max_slots=args.slots,
        max_seq=args.prompt_len + args.shared_prefix + args.gen,
        block_size=args.block_size,
        prefill_chunk=args.prefill_chunk,
        num_blocks=args.num_blocks or None,
        eos_id=None if args.eos_id < 0 else args.eos_id,
        kv_dtype=args.kv_dtype,
        fused=args.fused,
        seed=args.seed,
        max_inflight=(args.max_inflight or None) if online else None,
        spec_k=args.spec_k,
        drafter=args.drafter if args.spec_k else None,
        replicas=args.dp,
    )


def validate_serve_args(args, device_count: int | None = None):
    """Reject inconsistent flag combinations with actionable messages.

    Pure function over the parsed namespace so unit tests can exercise it
    without devices; pass ``device_count`` to also check that ``--dp x --tp``
    fits the visible device set. Raises SystemExit (argparse idiom) on the
    first problem found.
    """
    if args.fused is not None and not args.paged:
        raise SystemExit("--fused/--no-fused select the paged decode path; add --paged")
    if args.fused and args.impl != "exaq":
        raise SystemExit(
            f"--fused folds the EXAQ clip/LUT into the kernel and needs --impl exaq, "
            f"got --impl {args.impl}; drop --fused or switch --impl"
        )
    if args.kv_dtype in ("int8", "int4") and not args.paged:
        raise SystemExit(
            f"--kv-dtype {args.kv_dtype} needs the block pool's per-block scales; add --paged"
        )
    if args.dp < 1 or args.tp < 1:
        raise SystemExit(f"--dp and --tp must be >= 1, got --dp {args.dp} --tp {args.tp}")
    if (args.dp > 1 or args.tp > 1) and not args.paged:
        raise SystemExit(
            "--dp/--tp shard the block pool and replicate the paged engine "
            "(DESIGN.md §9); add --paged"
        )
    if device_count is not None and args.dp * args.tp > device_count:
        raise SystemExit(
            f"--dp {args.dp} x --tp {args.tp} needs {args.dp * args.tp} devices, "
            f"only {device_count} visible (try XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N on CPU)"
        )
    if args.spec_k < 0:
        raise SystemExit(f"--spec-k must be >= 0, got {args.spec_k}")
    if args.spec_k and not args.paged:
        raise SystemExit(
            "--spec-k drafts against the paged pool's branch forks "
            "(DESIGN.md §12); add --paged"
        )
    if args.spec_k and args.temperature > 0:
        raise SystemExit(
            "--spec-k is greedy-only (the accept rule compares exact argmaxes, "
            "DESIGN.md §12); drop --temperature"
        )
    if args.online and not args.paged:
        raise SystemExit(
            "--online drives the paged engine's streaming/cancellation surface "
            "(DESIGN.md §11); add --paged"
        )
    if args.online and args.dp > 1:
        raise SystemExit("--online serves a single engine; drop --dp or --online")
    if args.priority_classes < 1:
        raise SystemExit(f"--priority-classes must be >= 1, got {args.priority_classes}")
    if args.deadline_ms < 0 or args.max_inflight < 0:
        raise SystemExit(
            f"--deadline-ms and --max-inflight must be >= 0 (0 = off), got "
            f"--deadline-ms {args.deadline_ms} --max-inflight {args.max_inflight}"
        )
    if not args.online and (args.priority_classes != 1 or args.deadline_ms
                            or args.max_inflight):
        raise SystemExit(
            "--priority-classes/--deadline-ms/--max-inflight shape online "
            "admission; add --online"
        )


def _serve_online(eng, prompts, args, sp):
    """Drive the asyncio serving front (runtime/frontend.py) over the built
    paged engine: submissions cycle through the priority classes, every
    stream is collected concurrently, and shed load is reported with its
    structured rejection instead of failing the run."""
    import asyncio

    from repro.runtime.engine_core import Rejected
    from repro.runtime.frontend import AsyncFrontend

    deadline = args.deadline_ms / 1e3 if args.deadline_ms else None

    async def go():
        handles, shed = [], []
        async with AsyncFrontend(eng) as fe:
            for i, p in enumerate(prompts):
                h = await fe.submit(Request(p, args.gen, sp,
                                            priority=i % args.priority_classes,
                                            deadline=deadline))
                (shed if isinstance(h, Rejected) else handles).append(h)
            for h in handles:
                await h.collect()
        return handles, shed

    t0 = time.time()
    handles, shed = asyncio.run(go())
    wall = time.time() - t0
    # post-admission deadline sheds resolve as closed "shed" streams
    shed += [h.rejected for h in handles if h.finish_reason == "shed"]
    done = [h for h in handles if h.finish_reason != "shed"]
    n_out = sum(len(h.tokens) for h in done)
    unit = "s" if args.deadline_ms else " ticks"  # engine-clock units (see above)
    print(f"online front: {len(done)} served / {len(shed)} shed of "
          f"{len(prompts)} requests ({args.priority_classes} priority classes, "
          f"deadline {args.deadline_ms or 'off'} ms, "
          f"max-inflight {args.max_inflight or 'off'})")
    print(f"streamed {n_out} tokens in {wall*1e3:.1f} ms "
          f"({n_out/max(wall, 1e-9):.0f} tok/s incl. compile)")
    for h in done[:2]:
        ttft = eng.ttft.get(h.uid)
        ttft_s = "?" if ttft is None else f"{ttft:.3f}{unit}"
        print(f"  req {h.uid} [{h.finish_reason}] ttft={ttft_s}:", h.tokens[:16])
    for r in shed[:2]:
        print(f"  shed [{r.reason}] retryable={r.retryable} "
              f"backoff_hint={r.backoff_hint:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--impl", default="exaq", choices=["exact", "exaq", "naive"])
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--clip-rule", default="paper", choices=["paper", "analytic"])
    ap.add_argument("--temperature", type=float, default=0.0, help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos-id", type=int, default=-1, help="-1 disables EOS stopping")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache with shared-prefix reuse (DESIGN.md §3)")
    ap.add_argument("--block-size", type=int, default=16, help="tokens per KV block (paged)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefilled per interleaved chunk (paged)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size in blocks; 0 = full provisioning (paged)")
    ap.add_argument("--fused", dest="fused", action="store_true", default=None,
                    help="paged serving: fused Pallas paged-decode AND paged-prefill "
                         "kernels (no HBM KV gather on decode, no dense window copy "
                         "per prefill chunk; needs --impl exaq)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="paged serving: force the gather-then-dispatch references")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["fp32", "fp16", "bf16", "int8", "int4"],
                    help="KV cache storage dtype; int8 (paged only) stores the pool "
                         "quantized with per-block scales (DESIGN.md §6); int4 (paged "
                         "only) packs two values per byte with 4-bit sub-block scale "
                         "codes (DESIGN.md §10)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend the same N-token system prompt to every request")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel engine replicas, each with its own block "
                         "pool over a disjoint device slice (paged; DESIGN.md §9)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards per replica: block pool split on "
                         "the kv-head axis over the 'model' mesh axis (paged; "
                         "DESIGN.md §9)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per slot per "
                         "round and verify them in one fused paged-prefill call "
                         "(paged + greedy only; 0 = off; DESIGN.md §12)")
    ap.add_argument("--drafter", default="ngram", choices=["ngram"],
                    help="draft proposer for --spec-k: 'ngram' reuses the longest "
                         "matching suffix of the request's own context")
    ap.add_argument("--online", action="store_true",
                    help="asyncio serving front: streaming admission with "
                         "per-request cancellation, priority classes, and TTFT "
                         "deadlines over the paged engine (DESIGN.md §11)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="online: cycle submissions through N priority classes "
                         "(0 = most urgent; the scheduler preempts across classes)")
    ap.add_argument("--deadline-ms", type=int, default=0,
                    help="online: per-request TTFT deadline in wall-clock ms "
                         "(0 = off); expired queued requests shed with a "
                         "structured retryable rejection + backoff hint")
    ap.add_argument("--max-inflight", type=int, default=0,
                    help="online: admission cap on requests in the system "
                         "(0 = off); overflow is shed at submit with a backoff "
                         "hint instead of growing the queue")
    args = ap.parse_args()
    validate_serve_args(args, device_count=jax.device_count())

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_quant(softmax_impl=args.impl, bits=args.bits, clip_rule=args.clip_rule)
    if args.paged and cfg.family in ("ssm", "hybrid"):
        # paged state pools checkpoint recurrent state per block; the SSD
        # recurrence must run per token so the checkpoints reproduce the
        # rectangular scan bit-exactly (DESIGN.md §13)
        cfg = dataclasses.replace(cfg, ssm_chunk=1)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), jnp.bfloat16)
    rng = np.random.default_rng(args.seed)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k, top_p=args.top_p)
    eos = None if args.eos_id < 0 else args.eos_id

    print(f"arch={cfg.name} impl={args.impl} int{args.bits} kv={args.kv_dtype} "
          f"sampling=(T={sp.temperature}, k={sp.top_k}, p={sp.top_p})")

    if cfg.family in ("dense", "moe") or (args.paged and cfg.family in ("ssm", "hybrid")):
        from repro.runtime.engine import Engine, PagedEngine

        # ragged prompts: uniform in [prompt_len/2, prompt_len]
        lens = rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1, args.requests)
        shared = rng.integers(0, cfg.vocab_size, args.shared_prefix)
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, int(n))])
                   for n in lens]
        config = args_to_config(args)

        if args.paged:
            # deadlines compare against the engine clock: wall seconds when
            # deadlines are live, deterministic scheduler ticks otherwise
            clock = time.monotonic if (args.online and args.deadline_ms) else None
            if args.dp > 1 or args.tp > 1:
                from repro.launch.mesh import make_replica_meshes

                meshes = make_replica_meshes(args.dp, args.tp)
                if args.dp > 1:
                    from repro.runtime.engine import DataParallelEngine

                    eng = DataParallelEngine(cfg, params, config, meshes=meshes,
                                             clock=clock)
                else:
                    eng = PagedEngine(cfg, params, config, mesh=meshes[0], clock=clock)
            else:
                eng = PagedEngine(cfg, params, config, clock=clock)
        else:
            eng = Engine(cfg, params, config)
        if args.online:
            _serve_online(eng, prompts, args, sp)
            return
        t0 = time.time()
        uids = [eng.submit(Request(p, args.gen, sp)) for p in prompts]
        results = eng.run()
        wall = time.time() - t0
        n_out = sum(len(g.tokens) for g in results.values())
        kind = "paged engine" if args.paged else "engine"
        if args.dp > 1 or args.tp > 1:
            kind += f" (dp={args.dp}, tp={args.tp})"
        print(f"{kind}: {args.requests} requests (prompts "
              f"{min(map(len, prompts))}-{max(map(len, prompts))} tok) "
              f"through {args.slots} slots")
        print(f"decoded {n_out} tokens in {wall*1e3:.1f} ms "
              f"({n_out/max(wall, 1e-9):.0f} tok/s incl. compile); "
              f"mean slot occupancy {eng.mean_occupancy:.2f}/{args.slots}")
        if args.paged:
            st = eng.pool_stats
            print(f"prefix-cache hit rate {100*eng.prefix_hit_rate:.1f}% "
                  f"({eng.stats['prefix_hit_tokens']}/{eng.stats['prompt_tokens']} prompt tokens); "
                  f"{eng.stats['prefill_chunks']} prefill chunks of {args.prefill_chunk}; "
                  f"pool {eng.kv_pool_bytes/2**20:.1f} MiB, "
                  f"{st.cow_copies} CoW copies, {st.evictions} evictions")
            if args.spec_k:
                es = eng.stats
                print(f"speculative: k={args.spec_k} drafter={args.drafter}; "
                      f"{es['spec_rounds']} verify rounds, accepted "
                      f"{es['spec_accepted']}/{es['spec_drafted']} drafts "
                      f"({es['spec_accepted']/max(es['spec_rounds'],1):.2f} per verify)")
        if args.dp > 1:
            for i, s in enumerate(eng.per_replica_stats):
                print(f"  replica {i}: {s['prefills']} requests, "
                      f"occupancy {s['mean_occupancy']:.2f}/{args.slots}, "
                      f"hit rate {100*s['prefix_hit_rate']:.1f}%")
        for uid in uids[: min(2, len(uids))]:
            print(f"  req {uid} [{results[uid].finish_reason}]:",
                  results[uid].tokens[:16])
    else:
        if sp != SamplingParams() or eos is not None:
            raise SystemExit(
                f"--temperature/--top-k/--top-p/--eos-id are engine-only; "
                f"family {cfg.family!r} uses the greedy rectangular loop"
            )
        B, S = args.slots, args.prompt_len
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        t0 = time.time()
        gen = serve_rt.generate(params, cfg, prompts, args.gen)
        jax.block_until_ready(gen)
        wall = time.time() - t0
        print(f"rectangular loop ({cfg.family}): {B}x{args.gen} tokens in "
              f"{wall*1e3:.1f} ms ({B*args.gen/max(wall,1e-9):.0f} tok/s incl. compile)")
        for b in range(min(B, 2)):
            print(" ", np.asarray(gen[b])[:16].tolist())


if __name__ == "__main__":
    main()
