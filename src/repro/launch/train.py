"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Production behaviours demonstrated at any scale:
  * mesh-aware pjit (in/out shardings from runtime/sharding.py)
  * checkpoint/restart: atomic keep-k checkpoints, auto-resume from latest,
    deterministic data replay (pipeline state in checkpoint meta)
  * failure handling: on any step exception the driver re-loads the last
    checkpoint and continues (crash-equivalent restart); a heartbeat file lets
    an external watchdog re-exec the process on hangs
  * optional EXAQ-STE quantized-softmax training (paper §7.2 extension)
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpointing.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU scale)")
    ap.add_argument("--d-model", type=int, default=0, help="override width (with --reduced)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-mesh", type=int, default=0, help="data axis size (0 = all local devices)")
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--exaq-train", action="store_true", help="EXAQ-STE softmax during training")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model, d_ff=args.d_model * 3)
        if args.layers:
            over["num_layers"] = args.layers
        cfg = cfg.reduced(**over)
    cfg = cfg.with_quant(softmax_impl="exaq" if args.exaq_train else "exact")

    n_dev = len(jax.devices())
    dsize = args.data_mesh or max(n_dev // args.model_mesh, 1)
    mesh = jax.make_mesh((dsize, args.model_mesh), ("data", "model")) if dsize * args.model_mesh > 1 else None

    opt = AdamW(lr=cosine_with_warmup(args.lr, 20, args.steps))
    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, seed=0)
    state = train_rt.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step_fn = train_rt.make_train_step(cfg, opt, microbatches=args.microbatches)

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        state, meta = mgr.restore(jax.eval_shape(lambda: state))
        data.load_state_dict(meta["data"])
        start = int(meta["step"])
        print(f"resumed from step {start}")

    if mesh:
        st_sh = train_rt.state_shardings(cfg, mesh, jax.eval_shape(lambda: state))
        with mesh:
            state = jax.device_put(state, st_sh)
            jit_step = jax.jit(step_fn, in_shardings=(st_sh, None), out_shardings=(st_sh, None))
    else:
        jit_step = jax.jit(step_fn)

    hb = os.path.join(args.ckpt_dir or "/tmp", "heartbeat")
    t0 = time.time()
    i = start
    while i < args.steps:
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        try:
            if mesh:
                with mesh, shd.activation_rules(mesh, shd.make_activation_rules(cfg, mesh)):
                    state, metrics = jit_step(state, batch)
            else:
                state, metrics = jit_step(state, batch)
        except Exception as e:  # crash-equivalent restart from last checkpoint
            if mgr is None or mgr.latest_step() is None:
                raise
            print(f"step {i} failed ({e}); restoring last checkpoint")
            state, meta = mgr.restore(jax.eval_shape(lambda: state))
            data.load_state_dict(meta["data"])
            i = int(meta["step"])
            continue
        i += 1
        with open(hb, "w") as f:
            f.write(str(time.time()))
        if i % 10 == 0 or i == args.steps:
            print(f"step {i}: loss={float(metrics['loss']):.4f} lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/max(i-start,1):.2f}s/step)")
        if mgr is not None and (i % args.ckpt_every == 0 or i == args.steps):
            mgr.save(i, state, extra_meta={"step": i, "data": data.state_dict(), "arch": cfg.name})
    if mgr is not None:
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
