"""Production mesh definitions.

A function (not a module-level constant) so importing never touches jax
device state. Single-pod: (data=16, model=16) = 256 chips (TPU v5e pod);
multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for unit tests (requires >= data*model local devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
