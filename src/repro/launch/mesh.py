"""Production mesh definitions.

A function (not a module-level constant) so importing never touches jax
device state. Single-pod: (data=16, model=16) = 256 chips (TPU v5e pod);
multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for unit tests (requires >= data*model local devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_replica_meshes(dp: int, tp: int, devices=None):
    """One (1, tp) mesh per data-parallel replica over disjoint device slices.

    Data parallelism in the serving stack is *replica* parallelism
    (DESIGN.md §9): each ``DataParallelEngine`` replica owns an
    independent block pool sharded over its own ``model`` axis, so each
    replica gets its own Mesh rather than one global (dp, tp) mesh.
    Replica ``i`` spans ``devices[i*tp : (i+1)*tp]``.
    """
    import numpy as np

    if dp < 1 or tp < 1:
        raise ValueError(f"dp and tp must be >= 1, got dp={dp} tp={tp}")
    if devices is None:
        devices = jax.devices()
    need = dp * tp
    if len(devices) < need:
        raise ValueError(
            f"dp={dp} x tp={tp} needs {need} devices, only {len(devices)} visible"
        )
    return [
        jax.sharding.Mesh(
            np.asarray(devices[i * tp : (i + 1) * tp]).reshape(1, tp),
            ("data", "model"),
        )
        for i in range(dp)
    ]
