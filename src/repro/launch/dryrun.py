import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware. For every (arch x shape x mesh) cell:

    jit(step, in_shardings, out_shardings).lower(*input_specs).compile()

and extract cost_analysis / memory_analysis / per-device collective bytes
(parsed from the post-SPMD HLO) into a JSON record consumed by
benchmarks/bench_roofline.py and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--multi-pod]   # every applicable cell
"""

import argparse
import gzip
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_configs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.runtime import serve as serve_rt
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt
from repro.runtime.sharding import activation_rules, make_activation_rules

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


# ------------------------------------------------------------ input specs

def input_specs(cfg, shape) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        specs = {"tokens": sds((B, S), jnp.int32)}
    else:  # decode
        specs = {"tokens": sds((B, 1), jnp.int32)}
    if cfg.frontend == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = sds((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["audio_embeds"] = sds((B, cfg.enc_seq, cfg.frontend_dim), jnp.float32)
    return specs


# ----------------------------------------------------- collective parsing

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota format [groups,size]
    if m:
        return int(m.group(2))
    return total_devices


def collective_bytes(hlo_text: str, total_devices: int) -> dict[str, float]:
    """Per-device bytes moved over ICI per collective kind (ring algorithm
    cost model: all-reduce 2(n-1)/n x payload; gather/scatter/a2a (n-1)/n)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op not in _COLLECTIVES:
            continue
        n = _group_size(ls, total_devices)
        payload = _shape_bytes(result_type)
        if op == "all-reduce":
            moved = 2.0 * (n - 1) / max(n, 1) * payload
        elif op == "all-gather":
            moved = (n - 1) / max(n, 1) * payload
        elif op == "reduce-scatter":
            moved = (n - 1) * payload  # result is one shard; ring moves (n-1) shards
        elif op == "all-to-all":
            moved = (n - 1) / max(n, 1) * payload
        else:  # collective-permute
            moved = payload
        out[op] += moved
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# ------------------------------------------------------------- dry runs

def _scan_multiplier(hlo_text: str) -> int:
    return 1  # scan trip counts are already inside while loops in cost analysis


VARIANTS = {
    # §Perf hillclimb variants (EXPERIMENTS.md). Composable with '+'.
    "padvocab": lambda c: __import__("dataclasses").replace(c, pad_vocab_to=256),
    "bf16scores": lambda c: __import__("dataclasses").replace(c, attn_scores_bf16=True),
    "spdecode": lambda c: c.with_quant(sp_decode=True),
    "fusedattn": lambda c: c.with_quant(use_fused_kernel=True),
    "mb4": lambda c: c,   # handled via microbatches arg below
    "mb2": lambda c: c,
    "qrows": lambda c: c,  # sequence-parallel attention rows (code default for
                           # heads%tp!=0 archs; named for bookkeeping)
    "donate": lambda c: c, # buffer donation (handled at jit below)
    "selectlut": lambda c: c,  # select-chain LUT lookup (code default now; named for bookkeeping)
    "maskfold": lambda c: c,   # mask folded into max-reduce only (code default; bookkeeping)
    "groupq": lambda c: c,     # grouped-query einsum in SP decode (code default; bookkeeping)
    "bq2048": lambda c: __import__("dataclasses").replace(c, attn_block_q=2048),
    "divpv": lambda c: c,  # normalization folded into PV epilogue (code default; bookkeeping)
}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False, verbose: bool = True,
                microbatches: int = 8, variant: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if variant:
        for v in variant.split("+"):
            cfg = VARIANTS[v](cfg)
        if "mb4" in variant:
            microbatches = 4
        if "mb2" in variant:
            microbatches = 2
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()

    from jax.sharding import NamedSharding, PartitionSpec as P

    rules = make_activation_rules(cfg, mesh)
    specs = input_specs(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name, "kind": shape.kind, "variant": variant,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "devices": int(n_dev),
    }

    with mesh, activation_rules(mesh, rules):
        if shape.kind == "train":
            opt = AdamW(lr=cosine_with_warmup(3e-4, 100, 10000))
            state_struct = jax.eval_shape(
                lambda k: train_rt.init_train_state(cfg, opt, k), jax.ShapeDtypeStruct((2,), jnp.uint32)
            )
            st_sh = train_rt.state_shardings(cfg, mesh, state_struct)
            b_sh = train_rt.batch_shardings(mesh, specs)
            step = train_rt.make_train_step(cfg, opt, microbatches=microbatches)
            rec["microbatches"] = microbatches
            lowered = jax.jit(
                step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None), donate_argnums=(0,)
            ).lower(state_struct, specs)
        else:
            model_struct = jax.eval_shape(
                lambda k: __import__("repro.models", fromlist=["build_model"]).build_model(cfg).init(k, jnp.bfloat16),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            p_sh = shd.tree_shardings(model_struct, cfg, mesh, mode="serve")
            cache_struct = jax.eval_shape(
                lambda: serve_rt.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            c_sh = serve_rt.cache_shardings(cfg, mesh, cache_struct)
            prefill_step, decode_step = serve_rt.make_serve_fns(cfg)
            dp = shd.data_axes(mesh)
            tok_sh = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, shd.validate_spec(P(dp, *([None] * (len(s.shape) - 1))), s.shape, mesh)
                ),
                specs,
            )
            if shape.kind == "prefill":
                lowered = jax.jit(
                    prefill_step, in_shardings=(p_sh, tok_sh, c_sh), out_shardings=(None, c_sh),
                    donate_argnums=(2,),
                ).lower(model_struct, specs, cache_struct)
            else:
                lowered = jax.jit(
                    decode_step, in_shardings=(p_sh, tok_sh["tokens"], c_sh),
                    out_shardings=(tok_sh["tokens"], c_sh, None), donate_argnums=(2,),
                ).lower(model_struct, specs["tokens"], cache_struct)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items() if isinstance(v, (int, float)) and k in (
        "flops", "bytes accessed", "bytes accessed output", "optimal_seconds", "utilization operand 0 {}",
    ) or k in ("flops", "bytes accessed")}
    rec["flops"] = float(ca.get("flops", 0.0))
    rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = {"error": str(e)}
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo, n_dev)
    # trip-counted cost model (XLA cost_analysis counts while bodies once)
    from repro.utils import hlo_cost

    tc = hlo_cost.analyze(hlo, n_dev)
    rec["tc_flops"] = tc.flops
    rec["tc_bytes"] = tc.bytes
    rec["tc_collectives"] = dict(tc.collectives)
    rec["tc_collectives"]["total"] = tc.collective_total
    rec["tc_collective_counts"] = {k: float(v) for k, v in tc.collective_counts.items()}
    rec["top_collective_sites"] = [
        {"site": k, "bytes": b, "execs": e} for k, b, e in hlo_cost.per_collective_sites(hlo, n_dev, top=8)
    ]
    rec["hlo_bytes"] = len(hlo)
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    hlo_dir = os.path.join(os.path.dirname(os.path.abspath(RESULTS_DIR)), "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    tag = "multipod" if multi_pod else "singlepod"
    if variant:
        tag = tag + "__" + variant
    with gzip.open(os.path.join(hlo_dir, f"{arch}__{shape_name}__{tag}.hlo.gz"), "wt") as f:
        f.write(hlo)
    if verbose:
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "devices", "flops", "bytes_accessed", "compile_s")}))
        print("memory:", rec["memory_analysis"])
        print("collectives:", {k: v for k, v in rec["collectives"].items() if k != "counts"})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    outdir = os.path.abspath(args.out or RESULTS_DIR)
    os.makedirs(outdir, exist_ok=True)

    cells = []
    if args.all:
        for arch in list_configs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        tag = "multipod" if args.multi_pod else "singlepod"
        if args.variant:
            tag = tag + "__" + args.variant
        path = os.path.join(outdir, f"{arch}__{shape}__{tag}.json")
        if os.path.exists(path):
            print(f"skip (cached): {path}")
            continue
        try:
            rec = dryrun_cell(arch, shape, multi_pod=args.multi_pod, variant=args.variant)
        except Exception:
            rec = {"arch": arch, "shape": shape, "error": traceback.format_exc()}
            print(rec["error"])
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print("wrote", path)


if __name__ == "__main__":
    main()
