"""Pure-host engine core: slot table, block allocator, prefix cache, scheduler.

This module is the HOST half of the serving engines (DESIGN.md §9): every
scheduling decision — admission against the rolling-hash prefix cache, block
allocation and copy-on-write adjudication, chunked-prefill planning,
preempt-and-recompute back-pressure, finish transitions, telemetry — lives
here as plain Python + numpy over small integer state. It imports **no jax**
(enforced by tests/test_engine_core.py): the device half is
``runtime/device_step.py``, which holds the jitted functions that consume the
plans produced here and carry the (possibly mesh-sharded) pool pytree.

The split is what lets the system scale past one chip: the same ``EngineCore``
instance schedules a single-device engine, a tensor-parallel engine whose pool
is sharded over the 'model' mesh axis, or one replica of a data-parallel
fleet (``runtime.engine.DataParallelEngine``) — the core never knows, because
block ids, tables, and lengths are device-layout-free.

Two classes:

  * ``HostCore``   — slot-level state shared by the slot and paged engines:
                     per-slot arrays (lens / active / budget / sampling
                     params), the request queue, results, finish transitions,
                     chunk-absorption bookkeeping, occupancy telemetry.
  * ``EngineCore`` — the paged scheduler on top: ``BlockPool`` allocator +
                     per-slot block tables, prefix-hash admission, chunked-
                     prefill planning, CoW planning (device copies are
                     *queued* as (src, dst) pairs for the device step to
                     drain), fresh-block scale-reset queueing for int8 pools,
                     and the preempt-and-recompute policy.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.runtime.kv_pool import NULL_BLOCK, BlockPool, PoolExhausted, chain_hashes


@dataclass(frozen=True)
class GreedySampling:
    """jax-free stand-in for ``runtime.sampling.SamplingParams`` defaults —
    the engines pass real SamplingParams; the core only reads these fields."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0


GREEDY = GreedySampling()


@dataclass(frozen=True)
class Request:
    uid: int
    prompt: tuple[int, ...]
    max_new: int
    sampling: Any = GREEDY


@dataclass
class Generation:
    """Finished request: generated ids (EOS included when hit) + why it ended."""

    uid: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length"


@dataclass
class _Slot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.uid < 0

    @property
    def prefilling(self) -> bool:
        return False  # slot-engine prefill is synchronous at admission


@dataclass
class _PagedSlot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)
    req: Request | None = None
    table: list[int] = field(default_factory=list)   # host truth; mirrored to _tables
    hashes: list[tuple[int, int]] = field(default_factory=list)
    filled: int = 0        # prompt tokens with KV materialized (hits + chunks)
    cached: int = 0        # tokens satisfied from the prefix cache
    _prefilling: bool = False

    @property
    def free(self) -> bool:
        return self.uid < 0

    @property
    def prefilling(self) -> bool:
        return self._prefilling


@dataclass(frozen=True)
class PrefillChunkPlan:
    """Host-computed launch plan for one chunked-prefill step: everything the
    device step needs, as plain numpy (the device step ships it)."""

    slot: int
    tokens: np.ndarray       # (1, C) int32, right-padded
    start: int               # tokens already materialized (hits + prior chunks)
    n: int                   # live tokens in this chunk
    blk_t: np.ndarray        # (C,) int32 scatter target blocks (pad -> null)
    off_t: np.ndarray        # (C,) int32 scatter target offsets


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class HostCore:
    """Slot-level host scheduler state shared by both engines (no jax)."""

    def __init__(self, *, max_slots: int, max_seq: int, eos_id: int | None = None,
                 steps_per_sync: int = 8):
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.steps_per_sync = steps_per_sync

        # host-side slot state (small; shipped to device each chunk)
        self._slots = [self._new_slot() for _ in range(max_slots)]
        self.kv_lens = np.zeros((max_slots,), np.int32)
        self._active = np.zeros((max_slots,), bool)
        self._budget = np.zeros((max_slots,), np.int32)
        self._tokens = np.zeros((max_slots, 1), np.int32)
        self._temperature = np.zeros((max_slots,), np.float32)
        self._top_k = np.zeros((max_slots,), np.int32)
        self._top_p = np.ones((max_slots,), np.float32)

        self._queue: deque[Request] = deque()
        self._results: dict[int, Generation] = {}
        self._next_uid = 0

        # telemetry for bench_serving
        self.stats = {"decode_steps": 0, "tokens_out": 0, "occupancy_sum": 0.0,
                      "max_active": 0, "prefills": 0, "decode_time": 0.0}

    def _new_slot(self):
        return _Slot()

    def _validate_request(self, prompt, max_new: int) -> None:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_seq:
            raise ValueError(f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")

    def submit(self, prompt, max_new: int, sampling=GREEDY) -> int:
        prompt = tuple(int(t) for t in np.asarray(prompt).reshape(-1))
        self._validate_request(prompt, max_new)
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, prompt, max_new, sampling))
        return uid

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return (bool(self._queue) or self.num_active > 0
                or any(not s.free and s.prefilling for s in self._slots))

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s.free]

    def _complete_first(self, slot: int, req: Request, first: int) -> None:
        """Record the first generated token and flip the slot into decode
        state (or finish immediately on EOS / budget 1). The *sampling* of
        that token from prefill logits is device work (the engine's
        ``_sample_first``); this is the host transition it feeds."""
        sp = req.sampling
        self.stats["tokens_out"] += 1
        s = self._slots[slot]
        s.uid, s.generated = req.uid, [first]
        self.kv_lens[slot] = len(req.prompt)
        self._tokens[slot, 0] = first
        self._temperature[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._budget[slot] = req.max_new - 1
        hit_eos = self.eos_id is not None and first == self.eos_id
        if hit_eos or req.max_new == 1:
            self._finish(slot, "eos" if hit_eos else "length")
        else:
            self._active[slot] = True

    def _finish(self, slot: int, reason: str):
        s = self._slots[slot]
        self._results[s.uid] = Generation(s.uid, list(s.generated), reason)
        self._slots[slot] = self._new_slot()
        self._active[slot] = False

    def _pick_sampler(self) -> str:
        """Cheapest chunk sampler covering every active slot's params."""
        act = self._active
        if (self._temperature[act] <= 0.0).all():
            return "greedy"
        if (self._top_k[act] == 0).all() and (self._top_p[act] >= 1.0).all():
            return "temperature"
        return "full"

    def _clamp_steps(self, steps: int | None) -> int:
        # clamp to the largest remaining budget among active slots: a tail
        # chunk never runs whole-model decode steps nobody can consume (at
        # most steps_per_sync distinct scan lengths ever compile)
        max_budget = int(self._budget[self._active].max())
        return min(steps or self.steps_per_sync, max(max_budget, 1))

    def _absorb_chunk(self, tokens, lens, active, budget, emitted, masks, was_active) -> int:
        """Pull a finished decode chunk's state back to host: emissions per
        slot, occupancy telemetry, and finish transitions for slots that
        went inactive inside the chunk."""
        self._tokens = np.array(tokens)
        self.kv_lens = np.array(lens)
        self._active = np.array(active)
        self._budget = np.array(budget)
        emitted = np.asarray(emitted)  # (steps, S)
        masks = np.asarray(masks)
        n_out = 0
        for t in range(emitted.shape[0]):
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += float(masks[t].sum())
            self.stats["max_active"] = max(self.stats["max_active"], int(masks[t].sum()))
            for slot in np.nonzero(masks[t])[0]:
                self._slots[slot].generated.append(int(emitted[t, slot]))
                n_out += 1
        self.stats["tokens_out"] += n_out
        for slot in range(self.max_slots):
            if was_active[slot] and not self._active[slot]:
                last = self._slots[slot].generated[-1]
                hit_eos = self.eos_id is not None and last == self.eos_id
                self._finish(slot, "eos" if hit_eos else "length")
        return n_out

    def step_chunk(self, steps: int | None = None) -> int:  # pragma: no cover
        raise NotImplementedError("the engine layer (runtime/engine.py) drives device chunks")

    def run(self) -> dict[int, Generation]:
        """Drain the queue and all active slots; returns {uid: Generation}."""
        while self.has_work():
            self.step_chunk()
        out, self._results = self._results, {}
        return out

    @property
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps


# ===================================================================== paged


class EngineCore(HostCore):
    """Host scheduler for the block-paged engine (DESIGN.md §3/§9).

    Owns every paged scheduling decision with zero device state: the
    ``BlockPool`` allocator + refcounted prefix index, the per-slot block
    tables (host truth in ``_slots[i].table``, device mirror in ``_tables``),
    prefix-hash admission, chunked-prefill planning, the preempt-and-
    recompute policy, and the int8 fresh-block scale-reset queue.

    Device effects are *queued, never performed*: copy-on-write forks append
    ``(src, dst)`` to ``pending_copies`` and fresh allocations accumulate in
    ``_fresh_blocks`` — ``runtime.engine.PagedEngine`` drains both through
    the jitted functions in ``runtime/device_step.py`` before any launch
    that reads or writes the pool. Draining order matters and is part of the
    contract: copies first (in queue order — a queued copy's source may be
    released and recycled afterwards, and a later fork may target it), then
    scale resets (so a stale queued copy can never resurrect a recycled
    block's old quantization grid).
    """

    def __init__(self, *, max_slots: int, max_seq: int, block_size: int = 16,
                 prefill_chunk: int = 32, num_blocks: int | None = None,
                 eos_id: int | None = None, steps_per_sync: int = 8,
                 quantized: bool = False):
        # explicit base call: PagedEngine linearizes as (EngineCore, Engine,
        # HostCore) and Engine.__init__ must not run on this path
        HostCore.__init__(self, max_slots=max_slots, max_seq=max_seq, eos_id=eos_id,
                          steps_per_sync=steps_per_sync)
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.blocks_per_table = -(-max_seq // block_size)
        if num_blocks is None:
            num_blocks = 1 + max_slots * self.blocks_per_table  # +1: reserved null block
        self.num_blocks = num_blocks
        self.pool = BlockPool(num_blocks, block_size)
        self._tables = np.full((max_slots, self.blocks_per_table), NULL_BLOCK, np.int32)
        self._quantized = quantized

        self.stats.update(prompt_tokens=0, prefix_hit_tokens=0,
                          prefill_tokens=0, prefill_chunks=0, preemptions=0)
        self._preempt_carry: dict[int, list[int]] = {}
        # CoW device copies planned but not yet performed: (src, dst) pairs in
        # the order they must execute (see class docstring)
        self.pending_copies: list[tuple[int, int]] = []
        # blocks handed out by the pool since the last device launch whose
        # scale planes must be reset to "unset" before anything writes them
        # (recycled/evicted blocks carry a stale grid otherwise) — int8 only.
        # A set: an id can be released (admission rollback, preemption) and
        # re-allocated before the flush, and a CoW fork destination must be
        # *removed* (its valid scales arrive with the copied payload)
        self._fresh_blocks: set[int] = set()

    def _new_slot(self):
        return _PagedSlot()

    def _validate_request(self, prompt, max_new: int) -> None:
        super()._validate_request(prompt, max_new)
        worst = min(len(prompt) + max_new, self.max_seq)
        need = -(-worst // self.block_size)
        if need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request needs up to {need} blocks of {self.block_size} but the pool "
                f"has {self.pool.num_blocks - 1} usable blocks"
            )

    # -------------------------------------------------------------- block ops

    def _make_writable(self, slot: int, bi: int) -> None:
        """CoW: before appending into table entry ``bi``, fork a shared block
        (refcount > 1) and queue its payload copy; exclusive blocks append in
        place (appends land beyond the hashed token count — DESIGN.md §3)."""
        s = self._slots[slot]
        blk = s.table[bi]
        if self.pool.writable(blk):
            return
        new = self.pool.fork(blk)
        # the fork gets payload AND scales copied, so it must NOT be pending
        # a scale reset: fork() allocates internally and can hand back an id
        # that was _alloc_fresh'd and then released (rollback/preemption)
        # while still queued — flushing that id after this copy would zero
        # the fork's grid and corrupt its dequant
        self._fresh_blocks.discard(new)
        self.pending_copies.append((blk, new))
        s.table[bi] = new
        self._tables[slot, bi] = new

    def take_pending_copies(self) -> list[tuple[int, int]]:
        """Hand the queued CoW copies to the device layer (clears the queue)."""
        out, self.pending_copies = self.pending_copies, []
        return out

    def _ensure_decode_blocks(self, slot: int, steps: int) -> None:
        """Pre-chunk allocation: positions [lens, lens+writes) must have
        writable blocks before the jitted chunk launches (tables are fixed
        for the whole chunk). ``writes`` is bounded by the slot's own budget
        so a nearly-finished slot never allocates blocks it cannot write;
        blocks over-allocated for an EOS mid-chunk are reclaimed at finish."""
        s = self._slots[slot]
        lens = int(self.kv_lens[slot])
        writes = min(steps, int(self._budget[slot]) + 1)  # +1: the finishing write
        last_pos = min(lens + writes, self.max_seq) - 1
        bi0 = lens // self.block_size
        if bi0 < len(s.table):
            self._make_writable(slot, bi0)
        need = last_pos // self.block_size + 1
        while len(s.table) < need:
            blk = self._alloc_fresh()
            self._tables[slot, len(s.table)] = blk
            s.table.append(blk)

    def _alloc_fresh(self) -> int:
        """Pool alloc that queues the block for a scale reset (int8 pools):
        a block off the free list or evicted from the LRU carries a stale
        quantization grid that must not seed the next write."""
        blk = self.pool.alloc()
        if self._quantized:
            self._fresh_blocks.add(blk)
        return blk

    def take_fresh_scale_ids(self) -> list[int]:
        """Blocks allocated since the last device launch whose scale planes
        the device layer must reset before any jitted write (clears the
        queue; sorted for a deterministic device call)."""
        fresh = sorted(self._fresh_blocks)
        self._fresh_blocks = set()
        return fresh

    def _preempt(self, slot: int) -> None:
        """Release a live slot's blocks under pool pressure and requeue the
        request for recompute: the continuation prompt is the original prompt
        plus everything generated so far, so prefilling it reproduces the
        decode state exactly (greedy continuation is bit-identical — chunked
        prefill is exact, DESIGN.md §3), and its prompt blocks usually hit
        the prefix cache the preempted slot just parked."""
        s = self._slots[slot]
        req = s.req
        done = list(s.generated)
        remaining = int(self._budget[slot])
        self._preempt_carry[req.uid] = self._preempt_carry.pop(req.uid, []) + done
        cont = Request(req.uid, req.prompt + tuple(done), remaining, req.sampling)
        for blk in s.table:
            self.pool.release(blk)
        self._tables[slot, :] = NULL_BLOCK
        self._slots[slot] = self._new_slot()
        self._active[slot] = False
        self.stats["preemptions"] += 1
        self._queue.appendleft(cont)  # continuation bypasses _validate_request:
        # its prompt may legitimately reach max_seq (finishes right after prefill)

    def _reserve_chunk_blocks(self, steps: int) -> None:
        """Ensure every active slot can write its share of the coming chunk.
        Exhaustion preempts the newest active slot (its blocks free up, its
        request recomputes later) instead of crashing the engine — honest
        back-pressure on undersized pools."""
        for i in np.argsort([self._slots[i].uid if self._active[i] else np.iinfo(np.int64).max
                             for i in range(self.max_slots)]):
            i = int(i)
            if not self._active[i]:
                continue
            while self._active[i]:
                try:
                    self._ensure_decode_blocks(i, steps)
                    break
                except PoolExhausted:
                    victims = [j for j in range(self.max_slots) if self._active[j]]
                    victim = max(victims, key=lambda j: self._slots[j].uid)
                    if victim == i and len(victims) == 1:
                        raise PoolExhausted(
                            f"cannot grow KV for the only active request (uid "
                            f"{self._slots[i].uid}): pool of {self.pool.num_blocks - 1} "
                            f"usable blocks is too small for max_seq {self.max_seq}"
                        ) from None
                    self._preempt(victim)

    # ------------------------------------------------------------- scheduling

    def _admit(self) -> int:
        """Match prefix hashes, retain hits, allocate the rest of the prompt's
        blocks, and park the slot in chunked-prefill state. Pool exhaustion
        rolls the request back into the queue (back-pressure)."""
        admitted = 0
        free = self._free_slots()
        while free and self._queue:
            req = self._queue[0]
            hashes = chain_hashes(req.prompt, self.block_size)
            table, cached = [], 0
            for h, n in hashes:
                blk = self.pool.lookup(h)
                if blk is None:
                    break
                table.append(blk)
                cached += n
            # always re-prefill at least the last prompt token: sampling needs
            # its logits (a fully-cached prompt has KV but no logits)
            cached = min(cached, len(req.prompt) - 1)
            try:
                while len(table) < len(hashes):
                    table.append(self._alloc_fresh())
            except PoolExhausted:
                for b in table:
                    self.pool.release(b)
                break
            self._queue.popleft()
            slot = free.pop(0)
            s = self._slots[slot]
            s.uid, s.req, s.table, s.hashes = req.uid, req, table, hashes
            s.filled = s.cached = cached
            s._prefilling = True
            self._tables[slot, :] = NULL_BLOCK
            self._tables[slot, : len(table)] = table
            self.stats["prompt_tokens"] += len(req.prompt)
            self.stats["prefix_hit_tokens"] += cached
            admitted += 1
        return admitted

    def plan_prefill_chunk(self, slot: int) -> PrefillChunkPlan:
        """Plan the next ``prefill_chunk``-token chunk for a prefilling slot:
        CoW-protect the chunk's target blocks (copies are queued) and compute
        the padded token window plus per-row scatter targets. Does not
        advance ``filled`` — ``commit_prefill_chunk`` does, after the device
        step ran the plan."""
        s = self._slots[slot]
        req = s.req
        bs = self.block_size
        n = min(self.prefill_chunk, len(req.prompt) - s.filled)
        start = s.filled
        for bi in range(start // bs, (start + n - 1) // bs + 1):
            self._make_writable(slot, bi)
        C = self.prefill_chunk
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = req.prompt[start : start + n]
        blk_t = np.full((C,), NULL_BLOCK, np.int32)
        off_t = np.arange(C, dtype=np.int32) % bs  # spread padded-row writes in the null block
        for i in range(n):
            pos = start + i
            blk_t[i] = s.table[pos // bs]
            off_t[i] = pos % bs
        return PrefillChunkPlan(slot, toks, start, n, blk_t, off_t)

    def commit_prefill_chunk(self, slot: int, n: int) -> bool:
        """Host transitions after a prefill chunk ran on device: advance
        ``filled``, publish fully-materialized hashed blocks to the prefix
        index, and report whether the prompt just completed (the engine then
        samples the first token from the chunk's logits)."""
        s = self._slots[slot]
        s.filled += n
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += n
        bs = self.block_size
        for bi, (h, ntok) in enumerate(s.hashes):
            if bi * bs + ntok <= s.filled:
                self.pool.register(h, s.table[bi])
        if s.filled == len(s.req.prompt):
            s._prefilling = False
            self.stats["prefills"] += 1
            return True
        return False

    def _finish(self, slot: int, reason: str):
        s = self._slots[slot]
        for blk in s.table:
            self.pool.release(blk)
        self._tables[slot, :] = NULL_BLOCK
        carry = self._preempt_carry.pop(s.uid, None)
        super()._finish(slot, reason)
        if carry:  # tokens generated before a preemption lead the final answer
            g = self._results[s.uid]
            self._results[s.uid] = Generation(g.uid, carry + g.tokens, g.finish_reason)

    # -------------------------------------------------------------- telemetry

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of submitted prompt tokens served from the prefix cache."""
        return self.stats["prefix_hit_tokens"] / max(self.stats["prompt_tokens"], 1)

    @property
    def live_kv_tokens(self) -> int:
        """Tokens of KV currently materialized for unfinished requests."""
        total = 0
        for i, s in enumerate(self._slots):
            if s.free:
                continue
            total += s.filled if s.prefilling else int(self.kv_lens[i])
        return total
