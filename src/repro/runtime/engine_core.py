"""Pure-host engine core: slot table, block allocator, prefix cache, scheduler.

This module is the HOST half of the serving engines (DESIGN.md §9): every
scheduling decision — admission against the rolling-hash prefix cache, block
allocation and copy-on-write adjudication, chunked-prefill planning,
preempt-and-recompute back-pressure, finish transitions, telemetry — lives
here as plain Python + numpy over small integer state. It imports **no jax**
(enforced by tests/test_engine_core.py): the device half is
``runtime/device_step.py``, which holds the jitted functions that consume the
plans produced here and carry the (possibly mesh-sharded) pool pytree.

The split is what lets the system scale past one chip: the same ``EngineCore``
instance schedules a single-device engine, a tensor-parallel engine whose pool
is sharded over the 'model' mesh axis, or one replica of a data-parallel
fleet (``runtime.engine.DataParallelEngine``) — the core never knows, because
block ids, tables, and lengths are device-layout-free.

Two classes:

  * ``HostCore``   — slot-level state shared by the slot and paged engines:
                     per-slot arrays (lens / active / budget / sampling
                     params), the request queue, results, finish transitions,
                     chunk-absorption bookkeeping, occupancy telemetry.
  * ``EngineCore`` — the paged scheduler on top: ``BlockPool`` allocator +
                     per-slot block tables, prefix-hash admission, chunked-
                     prefill planning, CoW planning (device copies are
                     *queued* as (src, dst) pairs for the device step to
                     drain), fresh-block scale-reset queueing for int8 pools,
                     and the preempt-and-recompute policy.
"""

from __future__ import annotations

import bisect
import dataclasses
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.runtime.kv_pool import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    PoolOccupancy,
    chain_hashes,
)
from repro.runtime.speculative import greedy_accept_length


@dataclass(frozen=True)
class GreedySampling:
    """jax-free stand-in for ``runtime.sampling.SamplingParams`` defaults —
    the engines pass real SamplingParams; the core only reads these fields."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0


GREEDY = GreedySampling()


@dataclass(frozen=True)
class Request:
    """One generation request — the single submission surface of every engine
    front (``submit``/``try_submit``/``AsyncFrontend.submit`` all accept one
    in place of the legacy (prompt, max_new, ...) spread). ``uid`` is
    engine-assigned at admission; user-constructed requests leave it at -1.
    """

    prompt: tuple[int, ...]
    max_new: int
    sampling: Any = GREEDY
    # SLA annotations (DESIGN.md §11): lower priority value = more urgent
    # class; deadline is an *absolute* TTFT deadline on the core clock
    # (``HostCore.now()``) — None means no SLA.
    priority: int = 0
    deadline: float | None = None
    uid: int = -1


@dataclass(frozen=True)
class EngineConfig:
    """Single construction surface for every serving engine (jax-free).

    Pool sizing, cache dtype, fusion / speculative knobs, and scheduling
    knobs in one frozen value: ``launch/serve.py`` builds exactly one from
    argparse (``args_to_config``) and hands it to whichever engine class the
    config family selects; benches, tests, the chaos harness and the async
    frontend pass it through unchanged. ``kv_dtype`` is a *string* key
    (fp32 | bf16 | fp16 | int8 | int4) resolved to a device dtype at the
    engine layer — this module stays jax-free. The legacy per-field kwarg
    spreads survive as thin deprecated shims on the engine constructors.
    """

    max_slots: int
    max_seq: int
    block_size: int = 16
    prefill_chunk: int = 32
    num_blocks: int | None = None
    eos_id: int | None = None
    steps_per_sync: int = 8
    kv_dtype: str = "bf16"
    fused: bool | None = None       # None = auto (fused kernels when they apply)
    seed: int = 0
    max_inflight: int | None = None
    admit_watermark: float | None = None
    spec_k: int = 0                 # speculative draft length (0 = vanilla)
    drafter: str | None = None      # "ngram" | "pool" (spec_k > 0 only)
    replicas: int = 1               # DataParallelEngine fan-out

    def core_kwargs(self) -> dict:
        """kwargs for the host half (``EngineCore``) — the paged scheduler
        knows nothing of dtypes, fusion or speculation."""
        return dict(
            max_slots=self.max_slots, max_seq=self.max_seq,
            block_size=self.block_size, prefill_chunk=self.prefill_chunk,
            num_blocks=self.num_blocks, eos_id=self.eos_id,
            steps_per_sync=self.steps_per_sync, max_inflight=self.max_inflight,
            admit_watermark=self.admit_watermark,
            quantized=self.kv_dtype in ("int8", "int4"),
        )


@dataclass(frozen=True)
class Rejected:
    """Structured shed-load response (DESIGN.md §11): the admission layer's
    alternative to silently queueing into an eviction storm. ``retryable``
    distinguishes transient overload (back off ``backoff_hint`` clock units
    and resubmit) from requests that can never be served (malformed, larger
    than the pool). ``occupancy`` is the pool census at decision time when a
    paged pool was consulted; ``uid`` is set only for post-admission sheds
    (a queued request whose TTFT deadline expired), -1 otherwise."""

    reason: str  # "invalid" | "max_inflight" | "pool_pressure" | "deadline"
    detail: str = ""
    retryable: bool = True
    backoff_hint: float = 0.0
    occupancy: PoolOccupancy | None = None
    uid: int = -1


class AdmissionRejected(RuntimeError):
    """Raised by ``submit`` when admission control sheds the request; the
    structured ``Rejected`` rides in ``.rejected`` (``try_submit`` returns
    it instead of raising — the frontend path)."""

    def __init__(self, rejected: Rejected):
        super().__init__(f"request shed: {rejected.reason} {rejected.detail}".strip())
        self.rejected = rejected


@dataclass
class Generation:
    """Finished request: generated ids (EOS included when hit) + why it ended."""

    uid: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length" | "cancelled"


class _ReqQueue:
    """Priority request queue with the deque surface the cores grew up with.

    Entries order by ``(priority, seq)``: equal priorities stay FIFO on the
    admission sequence number, and a preempted continuation — which reuses
    its original uid as ``seq`` — re-enters *ahead* of later arrivals of its
    class, preserving the pre-priority engines' appendleft semantics (and
    their bit-exact admission order when every request is class 0)."""

    def __init__(self):
        self._items: list[tuple[int, int, Request]] = []

    def append(self, req: Request) -> None:
        # uid is monotone per core, so it doubles as the admission seq;
        # ties in (priority, seq) are impossible and Request never compares
        bisect.insort(self._items, (req.priority, req.uid, req))

    appendleft = append  # continuations re-sort by their (old, small) uid

    def popleft(self) -> Request:
        return self._items.pop(0)[2]

    def remove_uid(self, uid: int) -> Request | None:
        for i, (_, _, req) in enumerate(self._items):
            if req.uid == uid:
                return self._items.pop(i)[2]
        return None

    def __bool__(self) -> bool:
        return bool(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __getitem__(self, i: int) -> Request:
        return self._items[i][2]

    def __iter__(self):
        return (req for _, _, req in self._items)


@dataclass
class _Slot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.uid < 0

    @property
    def prefilling(self) -> bool:
        return False  # slot-engine prefill is synchronous at admission


@dataclass
class _PagedSlot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)
    req: Request | None = None
    table: list[int] = field(default_factory=list)   # host truth; mirrored to _tables
    hashes: list[tuple[int, int]] = field(default_factory=list)
    filled: int = 0        # prompt tokens with KV materialized (hits + chunks)
    cached: int = 0        # tokens satisfied from the prefix cache
    _prefilling: bool = False

    @property
    def free(self) -> bool:
        return self.uid < 0

    @property
    def prefilling(self) -> bool:
        return self._prefilling


@dataclass(frozen=True)
class PrefillChunkPlan:
    """Host-computed launch plan for one chunked-prefill step: everything the
    device step needs, as plain numpy (the device step ships it)."""

    slot: int
    tokens: np.ndarray       # (1, C) int32, right-padded
    start: int               # tokens already materialized (hits + prior chunks)
    n: int                   # live tokens in this chunk
    blk_t: np.ndarray        # (C,) int32 scatter target blocks (pad -> null)
    off_t: np.ndarray        # (C,) int32 scatter target offsets


@dataclass
class SpecBranch:
    """One in-flight speculative draft branch (DESIGN.md §12): the blocks
    backing verify positions ``[start, start + len(drafts)]`` of slot
    ``slot``. Every entry of ``table`` is branch-owned (holds exactly one
    refcount); the slot's shared prefix blocks are covered by the slot's own
    references and are NOT retained again — a branch dies (abort) or is
    spliced into the slot table (commit) without ever touching prefix
    refcounts. When ``forked`` the first entry is a CoW read-fork of the
    slot's partially-filled tail block, delivered by a queued
    ``pending_copies`` entry (drained before the verify launches)."""

    slot: int
    uid: int
    drafts: tuple[int, ...]
    start: int               # kv length at fork time = write position of row 0
    bi0: int                 # first table index the branch owns
    table: list[int]         # branch-owned block ids for indices [bi0, ...]
    forked: bool             # table[0] is a CoW copy of the slot's tail block


@dataclass(frozen=True)
class SpecVerifyPlan:
    """Launch plan for one speculative verify round: one fused paged-prefill
    call over the window [start, start + C) with the branch's blocks spliced
    over the slot's table (device mirror composed here, host-side)."""

    slot: int
    branch: SpecBranch
    tokens: np.ndarray       # (1, C) int32: [pending, draft_1..draft_{C-1}]
    start: int
    table: np.ndarray        # (MB,) int32 slot-prefix + branch window table
    blk_t: np.ndarray        # (C,) int32 scatter target blocks (branch-owned)
    off_t: np.ndarray        # (C,) int32 scatter target offsets


@dataclass(frozen=True)
class SpecCommit:
    """Host outcome of a verify round, pre-absorb: what to emit plus the
    int4 tail-hygiene coordinates (the engine trims sub codes the rejected
    rows seeded past the accepted length — DESIGN.md §12)."""

    slot: int
    emitted: list[int]       # drafts[:accepted] + [correction token]
    accepted: int            # accepted draft prefix length in [0, k]
    tail_block: int          # the committed tail block id
    tail_rows: int           # committed-valid rows of that block: (start+a)%bs+1
    trim_tail: bool          # rejected rows wrote into the kept tail block


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class HostCore:
    """Slot-level host scheduler state shared by both engines (no jax)."""

    def __init__(self, *, max_slots: int, max_seq: int, eos_id: int | None = None,
                 steps_per_sync: int = 8, clock=None, max_inflight: int | None = None):
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.steps_per_sync = steps_per_sync
        # SLA clock (DESIGN.md §11): deadlines are absolute values of now().
        # Default clock is the deterministic core tick counter (one tick per
        # decode step or prefill chunk) so scheduler tests and bench traces
        # are machine-portable; an online frontend passes time.monotonic.
        self._clock = clock
        self._ticks = 0
        self.max_inflight = max_inflight

        # host-side slot state (small; shipped to device each chunk)
        self._slots = [self._new_slot() for _ in range(max_slots)]
        self.kv_lens = np.zeros((max_slots,), np.int32)
        self._active = np.zeros((max_slots,), bool)
        self._budget = np.zeros((max_slots,), np.int32)
        self._tokens = np.zeros((max_slots, 1), np.int32)
        self._temperature = np.zeros((max_slots,), np.float32)
        self._top_k = np.zeros((max_slots,), np.int32)
        self._top_p = np.ones((max_slots,), np.float32)

        self._queue = _ReqQueue()
        self._results: dict[int, Generation] = {}
        self.sheds: dict[int, Rejected] = {}  # post-admission deadline sheds
        self._next_uid = 0
        # tokens emitted before a preemption, merged back at finish (paged
        # engines populate it; the slot engine never preempts)
        self._preempt_carry: dict[int, list[int]] = {}
        self._submit_time: dict[int, float] = {}
        self.ttft: dict[int, float] = {}  # uid -> first-token latency in now() units

        # telemetry for bench_serving
        self.stats = {"decode_steps": 0, "tokens_out": 0, "occupancy_sum": 0.0,
                      "max_active": 0, "prefills": 0, "decode_time": 0.0,
                      "cancelled": 0, "shed": 0}

    def _new_slot(self):
        return _Slot()

    def now(self) -> float:
        """Current SLA-clock reading: wall clock when one was injected, else
        the deterministic tick counter."""
        return float(self._clock()) if self._clock is not None else float(self._ticks)

    def _validate_request(self, prompt, max_new: int) -> None:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_seq:
            raise ValueError(f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")

    # ---------------------------------------------------------------- admission

    def _occupancy(self) -> PoolOccupancy | None:
        return None  # the paged core reports its BlockPool census

    def _in_system(self) -> int:
        """Requests admitted but not finished: queued + occupying a slot."""
        return len(self._queue) + sum(not s.free for s in self._slots)

    def _admission_check(self) -> Rejected | None:
        """Load-shedding gate for new submissions (DESIGN.md §11). Returns a
        structured ``Rejected`` when the request should back off, None when
        it may enter the queue. Never sheds on request *validity* — that is
        ``_validate_request``'s job and is non-retryable."""
        if self.max_inflight is not None and self._in_system() >= self.max_inflight:
            return Rejected(
                "max_inflight",
                detail=f"{self._in_system()} requests in flight >= cap {self.max_inflight}",
                retryable=True, backoff_hint=float(self.steps_per_sync),
                occupancy=self._occupancy(),
            )
        return None

    def _as_request(self, prompt, max_new, sampling, priority,
                    deadline) -> Request:
        """Normalize the two submission forms — a ``Request`` value, or the
        legacy ``(prompt, max_new, ...)`` spread — into one canonical
        ``Request`` with an int-tuple prompt. The uid stays -1 here;
        ``_enqueue`` assigns it at admission."""
        if isinstance(prompt, Request):
            if max_new is not None:
                raise ValueError("pass either a Request or (prompt, max_new), not both")
            r = prompt
        else:
            if max_new is None:
                raise ValueError("max_new is required when submitting a raw prompt")
            r = Request(prompt, max_new, sampling, int(priority), deadline)
        toks = tuple(int(t) for t in np.asarray(r.prompt).reshape(-1))
        return dataclasses.replace(r, prompt=toks)

    def _enqueue(self, req: Request) -> int:
        uid = self._next_uid
        self._next_uid += 1
        req = dataclasses.replace(req, uid=uid)
        self._queue.append(req)
        self._submit_time[uid] = self.now()
        return uid

    def submit(self, prompt, max_new: int | None = None, sampling=GREEDY, *,
               priority: int = 0, deadline: float | None = None) -> int:
        """Admit or die: malformed requests raise ValueError, shed load raises
        ``AdmissionRejected`` (offline callers treat both as fatal); returns
        the uid. Frontends wanting structured outcomes use ``try_submit``.

        Accepts either a ``Request`` value (the canonical submission path) or
        the legacy ``(prompt, max_new, ...)`` spread."""
        req = self._as_request(prompt, max_new, sampling, priority, deadline)
        self._validate_request(req.prompt, req.max_new)
        rej = self._admission_check()
        if rej is not None:
            raise AdmissionRejected(rej)
        return self._enqueue(req)

    def try_submit(self, prompt, max_new: int | None = None, sampling=GREEDY, *,
                   priority: int = 0, deadline: float | None = None) -> int | Rejected:
        """Non-raising admission for the serving front: returns a uid, or a
        ``Rejected`` — non-retryable for malformed requests, retryable with a
        backoff hint for shed load. Accepts a ``Request`` or the legacy
        kwarg spread, like ``submit``."""
        try:
            req = self._as_request(prompt, max_new, sampling, priority, deadline)
            self._validate_request(req.prompt, req.max_new)
        except (ValueError, TypeError) as e:
            return Rejected("invalid", detail=str(e), retryable=False,
                            occupancy=self._occupancy())
        rej = self._admission_check()
        if rej is not None:
            return rej
        return self._enqueue(req)

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return (bool(self._queue) or self.num_active > 0
                or any(not s.free and s.prefilling for s in self._slots))

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s.free]

    # ------------------------------------------------- cancellation / streaming

    def cancel(self, uid: int) -> bool:
        """Abort a request wherever it lives — queued, prefilling, or decoding.
        Every block it holds is released back to the pool (the paged
        ``_finish`` path), tokens generated so far land in results with
        finish_reason "cancelled", and a queued preempt-continuation resolves
        to its carried tokens. Returns False for unknown/finished uids (a
        disconnect racing a finish is not an error)."""
        req = self._queue.remove_uid(uid)
        if req is not None:
            carry = self._preempt_carry.pop(uid, [])
            self._results[uid] = Generation(uid, carry, "cancelled")
            self._submit_time.pop(uid, None)
            self.stats["cancelled"] += 1
            return True
        for slot, s in enumerate(self._slots):
            if not s.free and s.uid == uid:
                self._cancel_slot(slot)
                self._submit_time.pop(uid, None)
                self.stats["cancelled"] += 1
                return True
        return False

    def _cancel_slot(self, slot: int) -> None:
        self._finish(slot, "cancelled")

    def tokens_so_far(self, uid: int) -> list[int]:
        """Every token generated for ``uid`` so far (preempt carry included) —
        the frontend's streaming source between chunks. Finished requests
        report their final tokens; unknown uids report []."""
        carry = self._preempt_carry.get(uid, [])
        for i, s in enumerate(self._slots):
            if not s.free and s.uid == uid:
                return list(carry) + list(s.generated)
        if uid in self._results:
            return list(self._results[uid].tokens)
        return list(carry)  # queued (possibly a preempted continuation)

    def take_finished(self) -> dict[int, Generation]:
        """Drain completed results (finish/EOS/cancel) since the last call."""
        out, self._results = self._results, {}
        return out

    def take_shed(self) -> dict[int, Rejected]:
        """Drain post-admission deadline sheds since the last call."""
        out, self.sheds = self.sheds, {}
        return out

    def _shed_expired(self) -> int:
        """Shed queued requests whose TTFT deadline already passed — running
        their prefill can only waste pool blocks the punctual requests need.
        Continuations of preempted requests are exempt: their admission
        decision was already made (and a decoding request's TTFT was met).
        Shed uids land in ``sheds`` as retryable ``Rejected`` responses."""
        if not self._queue:
            return 0
        now = self.now()
        expired = [r.uid for r in self._queue
                   if r.deadline is not None and r.deadline <= now
                   and r.uid not in self._preempt_carry]
        for uid in expired:
            req = self._queue.remove_uid(uid)
            self.sheds[uid] = Rejected(
                "deadline",
                detail=f"TTFT deadline {req.deadline:g} expired at clock {now:g}",
                retryable=True, backoff_hint=float(self.steps_per_sync),
                occupancy=self._occupancy(), uid=uid,
            )
            self._submit_time.pop(uid, None)
            self.stats["shed"] += 1
        return len(expired)

    def _complete_first(self, slot: int, req: Request, first: int) -> None:
        """Record the first generated token and flip the slot into decode
        state (or finish immediately on EOS / budget 1). The *sampling* of
        that token from prefill logits is device work (the engine's
        ``_sample_first``); this is the host transition it feeds."""
        sp = req.sampling
        self.stats["tokens_out"] += 1
        t0 = self._submit_time.pop(req.uid, None)
        if t0 is not None and req.uid not in self.ttft:  # continuations keep the original TTFT
            self.ttft[req.uid] = self.now() - t0
        s = self._slots[slot]
        s.uid, s.generated = req.uid, [first]
        self.kv_lens[slot] = len(req.prompt)
        self._tokens[slot, 0] = first
        self._temperature[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._budget[slot] = req.max_new - 1
        hit_eos = self.eos_id is not None and first == self.eos_id
        if hit_eos or req.max_new == 1:
            self._finish(slot, "eos" if hit_eos else "length")
        else:
            self._active[slot] = True

    def _finish(self, slot: int, reason: str):
        s = self._slots[slot]
        self._results[s.uid] = Generation(s.uid, list(s.generated), reason)
        self._slots[slot] = self._new_slot()
        self._active[slot] = False

    def _pick_sampler(self) -> str:
        """Cheapest chunk sampler covering every active slot's params."""
        act = self._active
        if (self._temperature[act] <= 0.0).all():
            return "greedy"
        if (self._top_k[act] == 0).all() and (self._top_p[act] >= 1.0).all():
            return "temperature"
        return "full"

    def _clamp_steps(self, steps: int | None) -> int:
        # clamp to the largest remaining budget among active slots: a tail
        # chunk never runs whole-model decode steps nobody can consume (at
        # most steps_per_sync distinct scan lengths ever compile)
        max_budget = int(self._budget[self._active].max())
        return min(steps or self.steps_per_sync, max(max_budget, 1))

    def _absorb_chunk(self, tokens, lens, active, budget, emitted, masks, was_active) -> int:
        """Pull a finished decode chunk's state back to host: emissions per
        slot, occupancy telemetry, and finish transitions for slots that
        went inactive inside the chunk."""
        self._tokens = np.array(tokens)
        self.kv_lens = np.array(lens)
        self._active = np.array(active)
        self._budget = np.array(budget)
        emitted = np.asarray(emitted)  # (steps, S)
        masks = np.asarray(masks)
        n_out = 0
        self._ticks += emitted.shape[0]  # SLA clock: one tick per decode step
        for t in range(emitted.shape[0]):
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += float(masks[t].sum())
            self.stats["max_active"] = max(self.stats["max_active"], int(masks[t].sum()))
            for slot in np.nonzero(masks[t])[0]:
                self._slots[slot].generated.append(int(emitted[t, slot]))
                n_out += 1
        self.stats["tokens_out"] += n_out
        for slot in range(self.max_slots):
            if was_active[slot] and not self._active[slot]:
                last = self._slots[slot].generated[-1]
                hit_eos = self.eos_id is not None and last == self.eos_id
                self._finish(slot, "eos" if hit_eos else "length")
        return n_out

    def step_chunk(self, steps: int | None = None) -> int:  # pragma: no cover
        raise NotImplementedError("the engine layer (runtime/engine.py) drives device chunks")

    def run(self) -> dict[int, Generation]:
        """Drain the queue and all active slots; returns {uid: Generation}."""
        while self.has_work():
            self.step_chunk()
        out, self._results = self._results, {}
        return out

    @property
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps


# ===================================================================== paged


class EngineCore(HostCore):
    """Host scheduler for the block-paged engine (DESIGN.md §3/§9).

    Owns every paged scheduling decision with zero device state: the
    ``BlockPool`` allocator + refcounted prefix index, the per-slot block
    tables (host truth in ``_slots[i].table``, device mirror in ``_tables``),
    prefix-hash admission, chunked-prefill planning, the preempt-and-
    recompute policy, and the int8 fresh-block scale-reset queue.

    Device effects are *queued, never performed*: copy-on-write forks append
    ``(src, dst)`` to ``pending_copies`` and fresh allocations accumulate in
    ``_fresh_blocks`` — ``runtime.engine.PagedEngine`` drains both through
    the jitted functions in ``runtime/device_step.py`` before any launch
    that reads or writes the pool. Draining order matters and is part of the
    contract: copies first (in queue order — a queued copy's source may be
    released and recycled afterwards, and a later fork may target it), then
    scale resets (so a stale queued copy can never resurrect a recycled
    block's old quantization grid).
    """

    def __init__(self, *, max_slots: int, max_seq: int, block_size: int = 16,
                 prefill_chunk: int = 32, num_blocks: int | None = None,
                 eos_id: int | None = None, steps_per_sync: int = 8,
                 quantized: bool = False, clock=None, max_inflight: int | None = None,
                 admit_watermark: float | None = None, state_blocks: bool = False):
        # explicit base call: PagedEngine linearizes as (EngineCore, Engine,
        # HostCore) and Engine.__init__ must not run on this path
        HostCore.__init__(self, max_slots=max_slots, max_seq=max_seq, eos_id=eos_id,
                          steps_per_sync=steps_per_sync, clock=clock,
                          max_inflight=max_inflight)
        # shed new work once this fraction of pool blocks is live (None = off):
        # admission control before the allocator thrashes into eviction storms
        self.admit_watermark = admit_watermark
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.blocks_per_table = -(-max_seq // block_size)
        if num_blocks is None:
            num_blocks = 1 + max_slots * self.blocks_per_table  # +1: reserved null block
        self.num_blocks = num_blocks
        self.pool = BlockPool(num_blocks, block_size)
        self._tables = np.full((max_slots, self.blocks_per_table), NULL_BLOCK, np.int32)
        self._quantized = quantized
        # SSM/hybrid state pools checkpoint recurrent state at *block*
        # granularity and decode overwrites the partial tail block in place,
        # so only full blocks may enter the prefix index and cache hits must
        # be block-aligned (DESIGN.md §13)
        self.state_blocks = state_blocks

        self.stats.update(prompt_tokens=0, prefix_hit_tokens=0,
                          prefill_tokens=0, prefill_chunks=0, preemptions=0)
        # CoW device copies planned but not yet performed: (src, dst) pairs in
        # the order they must execute (see class docstring)
        self.pending_copies: list[tuple[int, int]] = []
        # blocks handed out by the pool since the last device launch whose
        # scale planes must be reset to "unset" before anything writes them
        # (recycled/evicted blocks carry a stale grid otherwise) — int8 only.
        # A set: an id can be released (admission rollback, preemption) and
        # re-allocated before the flush, and a CoW fork destination must be
        # *removed* (its valid scales arrive with the copied payload)
        self._fresh_blocks: set[int] = set()
        # speculative-decoding branches in flight, slot -> [SpecBranch]
        # (DESIGN.md §12). Branch blocks are invisible to the slot table
        # until commit; every fault path (_preempt, _finish, cancel) must
        # abort them first so their refcounts and queued fork copies die
        # with the slot.
        self._branches: dict[int, list[SpecBranch]] = {}
        self.stats.update(spec_rounds=0, spec_drafted=0, spec_accepted=0,
                          spec_emitted=0, spec_forks=0)

    def _new_slot(self):
        return _PagedSlot()

    def _validate_request(self, prompt, max_new: int) -> None:
        super()._validate_request(prompt, max_new)
        worst = min(len(prompt) + max_new, self.max_seq)
        need = -(-worst // self.block_size)
        if need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request needs up to {need} blocks of {self.block_size} but the pool "
                f"has {self.pool.num_blocks - 1} usable blocks"
            )

    def _occupancy(self) -> PoolOccupancy:
        return self.pool.occupancy()

    def _admission_check(self) -> Rejected | None:
        rej = super()._admission_check()
        if rej is not None:
            return rej
        if self.admit_watermark is not None:
            occ = self.pool.occupancy()
            if occ.live_fraction >= self.admit_watermark:
                return Rejected(
                    "pool_pressure",
                    detail=(f"{occ.num_live}/{occ.num_blocks} blocks live "
                            f">= watermark {self.admit_watermark:g}"),
                    retryable=True, backoff_hint=float(self.steps_per_sync),
                    occupancy=occ,
                )
        return None

    def _cancel_slot(self, slot: int) -> None:
        # queued CoW copies into blocks this cancel releases must never run:
        # the dst can be recycled to another slot before the next drain, and
        # a stale copy would overwrite its payload (the live engine drains
        # copies eagerly so this is a host-only-core concern, but the chaos
        # harness runs exactly that configuration)
        doomed = set(self._slots[slot].table)
        if doomed and self.pending_copies:
            self.pending_copies = [(s, d) for (s, d) in self.pending_copies
                                   if d not in doomed]
        super()._cancel_slot(slot)

    # -------------------------------------------------------------- block ops

    def _make_writable(self, slot: int, bi: int) -> None:
        """CoW: before appending into table entry ``bi``, fork a shared block
        (refcount > 1) and queue its payload copy; exclusive blocks append in
        place (appends land beyond the hashed token count — DESIGN.md §3)."""
        s = self._slots[slot]
        blk = s.table[bi]
        if self.pool.writable(blk):
            return
        new = self.pool.fork(blk)
        # the fork gets payload AND scales copied, so it must NOT be pending
        # a scale reset: fork() allocates internally and can hand back an id
        # that was _alloc_fresh'd and then released (rollback/preemption)
        # while still queued — flushing that id after this copy would zero
        # the fork's grid and corrupt its dequant
        self._fresh_blocks.discard(new)
        self.pending_copies.append((blk, new))
        s.table[bi] = new
        self._tables[slot, bi] = new

    def take_pending_copies(self) -> list[tuple[int, int]]:
        """Hand the queued CoW copies to the device layer (clears the queue)."""
        out, self.pending_copies = self.pending_copies, []
        return out

    def _ensure_decode_blocks(self, slot: int, steps: int) -> None:
        """Pre-chunk allocation: positions [lens, lens+writes) must have
        writable blocks before the jitted chunk launches (tables are fixed
        for the whole chunk). ``writes`` is bounded by the slot's own budget
        so a nearly-finished slot never allocates blocks it cannot write;
        blocks over-allocated for an EOS mid-chunk are reclaimed at finish."""
        s = self._slots[slot]
        lens = int(self.kv_lens[slot])
        writes = min(steps, int(self._budget[slot]) + 1)  # +1: the finishing write
        last_pos = min(lens + writes, self.max_seq) - 1
        bi0 = lens // self.block_size
        if bi0 < len(s.table):
            self._make_writable(slot, bi0)
        need = last_pos // self.block_size + 1
        while len(s.table) < need:
            blk = self._alloc_fresh()
            self._tables[slot, len(s.table)] = blk
            s.table.append(blk)

    def _alloc_fresh(self) -> int:
        """Pool alloc that queues the block for a scale reset (int8 pools):
        a block off the free list or evicted from the LRU carries a stale
        quantization grid that must not seed the next write."""
        blk = self.pool.alloc()
        if self._quantized:
            self._fresh_blocks.add(blk)
        return blk

    def take_fresh_scale_ids(self) -> list[int]:
        """Blocks allocated since the last device launch whose scale planes
        the device layer must reset before any jitted write (clears the
        queue; sorted for a deterministic device call)."""
        fresh = sorted(self._fresh_blocks)
        self._fresh_blocks = set()
        return fresh

    def _preempt(self, slot: int) -> None:
        """Release a live slot's blocks under pool pressure and requeue the
        request for recompute: the continuation prompt is the original prompt
        plus everything generated so far, so prefilling it reproduces the
        decode state exactly (greedy continuation is bit-identical — chunked
        prefill is exact, DESIGN.md §3), and its prompt blocks usually hit
        the prefix cache the preempted slot just parked. Works on decoding
        *and* mid-prefill slots (priority admission evicts either); the
        continuation keeps the request's priority class and deadline."""
        self.abort_spec_branches(slot)  # branch blocks + queued fork copies die first
        s = self._slots[slot]
        req = s.req
        done = list(s.generated)
        # mid-prefill: nothing sampled yet, the continuation is the original
        # request verbatim (its _budget is stale — never set for this slot)
        remaining = req.max_new if s.prefilling else int(self._budget[slot])
        carry = self._preempt_carry.pop(req.uid, []) + done
        if carry:  # no empty entries: _shed_expired treats presence as TTFT-met
            self._preempt_carry[req.uid] = carry
        cont = Request(req.prompt + tuple(done), remaining, req.sampling,
                       req.priority, req.deadline, uid=req.uid)
        doomed = set(s.table)
        if doomed and self.pending_copies:  # same staleness hazard as _cancel_slot
            self.pending_copies = [(a, b) for (a, b) in self.pending_copies
                                   if b not in doomed]
        for blk in s.table:
            self.pool.release(blk)
        self._tables[slot, :] = NULL_BLOCK
        self._slots[slot] = self._new_slot()
        self._active[slot] = False
        self.stats["preemptions"] += 1
        self._queue.appendleft(cont)  # continuation bypasses _validate_request:
        # its prompt may legitimately reach max_seq (finishes right after prefill)

    def _victim_rank(self, j: int):
        """Sort key for preemption policy (DESIGN.md §11): under max(), the
        victim is the least-urgent occupied slot — highest priority value
        first, then the most deadline slack (no deadline = infinite slack),
        then the newest uid. With every request at the defaults this reduces
        exactly to the pre-SLA policy (preempt the newest)."""
        s = self._slots[j]
        req = s.req
        prio = req.priority if req is not None else 0
        slack = req.deadline if (req is not None and req.deadline is not None) else float("inf")
        return (prio, slack, s.uid)

    def _reserve_chunk_blocks(self, steps: int) -> None:
        """Ensure every active slot can write its share of the coming chunk,
        most-urgent slots reserving first. Exhaustion preempts the least
        urgent active slot per ``_victim_rank`` (its blocks free up, its
        request recomputes later) instead of crashing the engine — honest
        back-pressure on undersized pools."""
        order = sorted((i for i in range(self.max_slots) if self._active[i]),
                       key=self._victim_rank)
        for i in order:
            # a slot later in the order may have been preempted for an earlier one
            while self._active[i]:
                try:
                    self._ensure_decode_blocks(i, steps)
                    break
                except PoolExhausted:
                    victims = [j for j in range(self.max_slots) if self._active[j]]
                    victim = max(victims, key=self._victim_rank)
                    if victim == i and len(victims) == 1:
                        raise PoolExhausted(
                            f"cannot grow KV for the only active request (uid "
                            f"{self._slots[i].uid}): pool of {self.pool.num_blocks - 1} "
                            f"usable blocks is too small for max_seq {self.max_seq}",
                            retryable=False, occupancy=self.pool.occupancy(),
                        ) from None
                    self._preempt(victim)

    # ------------------------------------------------------------- scheduling

    def _preempt_for(self, req: Request) -> int | None:
        """Occupied slot to evict so the strictly-more-urgent ``req`` can run:
        the least-urgent per ``_victim_rank``, and only when its priority
        class is strictly less urgent than ``req``'s — equal-class arrivals
        wait their turn (no churn within a class). None when nothing
        qualifies. Mid-prefill slots are eligible victims: they hold blocks
        and haven't produced a token yet, so they are the cheapest to redo."""
        occupied = [j for j in range(self.max_slots) if not self._slots[j].free]
        if not occupied:
            return None
        victim = max(occupied, key=self._victim_rank)
        vreq = self._slots[victim].req
        if vreq is None or vreq.priority <= req.priority:
            return None
        return victim

    def _admit(self) -> int:
        """Shed expired deadlines, then match prefix hashes, retain hits,
        allocate the rest of the prompt's blocks, and park the slot in
        chunked-prefill state. The queue is priority-ordered, so the head is
        always the most urgent waiter; when it is blocked on a slot or on
        pool blocks held by a strictly-less-urgent class, that victim is
        preempted (deadline-aware ``_victim_rank``). Otherwise pool
        exhaustion rolls the request back into the queue (back-pressure)."""
        self._shed_expired()
        admitted = 0
        free = self._free_slots()
        while self._queue:
            req = self._queue[0]
            if not free:
                victim = self._preempt_for(req)
                if victim is None:
                    break
                self._preempt(victim)
                free = self._free_slots()
            hashes = chain_hashes(req.prompt, self.block_size)
            table, cached = [], 0
            for h, n in hashes:
                blk = self.pool.lookup(h)
                if blk is None:
                    break
                table.append(blk)
                cached += n
            # always re-prefill at least the last prompt token: sampling needs
            # its logits (a fully-cached prompt has KV but no logits)
            if self.state_blocks:
                # state planes checkpoint at block boundaries only, and decode
                # mutates partial tail blocks in place — a prefix hit is only
                # usable up to the last *full* block strictly inside the
                # prompt. Release over-matched blocks (lookup retained them).
                limit = ((len(req.prompt) - 1) // self.block_size) * self.block_size
                keep = min(cached, limit) // self.block_size
                for b in table[keep:]:
                    self.pool.release(b)
                del table[keep:]
                cached = keep * self.block_size
            else:
                cached = min(cached, len(req.prompt) - 1)
            try:
                while len(table) < len(hashes):
                    table.append(self._alloc_fresh())
            except PoolExhausted:
                for b in table:
                    self.pool.release(b)
                victim = self._preempt_for(req)
                if victim is None:
                    break
                self._preempt(victim)  # frees its blocks; retry the same head
                free = self._free_slots()
                continue
            self._queue.popleft()
            slot = free.pop(0)
            s = self._slots[slot]
            s.uid, s.req, s.table, s.hashes = req.uid, req, table, hashes
            s.filled = s.cached = cached
            s._prefilling = True
            self._tables[slot, :] = NULL_BLOCK
            self._tables[slot, : len(table)] = table
            self.stats["prompt_tokens"] += len(req.prompt)
            self.stats["prefix_hit_tokens"] += cached
            admitted += 1
        return admitted

    def plan_prefill_chunk(self, slot: int) -> PrefillChunkPlan:
        """Plan the next ``prefill_chunk``-token chunk for a prefilling slot:
        CoW-protect the chunk's target blocks (copies are queued) and compute
        the padded token window plus per-row scatter targets. Does not
        advance ``filled`` — ``commit_prefill_chunk`` does, after the device
        step ran the plan."""
        s = self._slots[slot]
        req = s.req
        bs = self.block_size
        n = min(self.prefill_chunk, len(req.prompt) - s.filled)
        start = s.filled
        for bi in range(start // bs, (start + n - 1) // bs + 1):
            self._make_writable(slot, bi)
        C = self.prefill_chunk
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = req.prompt[start : start + n]
        blk_t = np.full((C,), NULL_BLOCK, np.int32)
        off_t = np.arange(C, dtype=np.int32) % bs  # spread padded-row writes in the null block
        for i in range(n):
            pos = start + i
            blk_t[i] = s.table[pos // bs]
            off_t[i] = pos % bs
        return PrefillChunkPlan(slot, toks, start, n, blk_t, off_t)

    def commit_prefill_chunk(self, slot: int, n: int) -> bool:
        """Host transitions after a prefill chunk ran on device: advance
        ``filled``, publish fully-materialized hashed blocks to the prefix
        index, and report whether the prompt just completed (the engine then
        samples the first token from the chunk's logits)."""
        s = self._slots[slot]
        s.filled += n
        self._ticks += 1  # SLA clock: one tick per prefill chunk
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += n
        bs = self.block_size
        for bi, (h, ntok) in enumerate(s.hashes):
            # state pools: partial tail blocks are decode-mutable in place, so
            # only full blocks may ever enter the prefix index (DESIGN.md §13)
            if bi * bs + ntok <= s.filled and (not self.state_blocks or ntok == bs):
                self.pool.register(h, s.table[bi])
        if s.filled == len(s.req.prompt):
            s._prefilling = False
            self.stats["prefills"] += 1
            return True
        return False

    # ------------------------------------------------- speculative decoding

    def plan_spec_round(self, slot: int, drafts) -> SpecVerifyPlan:
        """Fork a draft branch and plan its verify window (DESIGN.md §12).

        The branch owns fresh blocks for every table index the window
        ``[start, start + k]`` touches. When the slot's tail block is
        partially filled, the branch's first block is a CoW *read-fork* of
        it — payload copy queued on ``pending_copies``, the slot's own
        reference left untouched — so a rejected round releases the copy and
        the slot is exactly as it was. Raises ``PoolExhausted`` with full
        rollback (no branch registered, no blocks leaked) when the pool
        cannot cover the window; the engine retries with k=0 or preempts.
        """
        s = self._slots[slot]
        bs = self.block_size
        drafts = tuple(int(d) for d in drafts)
        k = len(drafts)
        L = int(self.kv_lens[slot])
        assert self._active[slot] and not s.prefilling, "spec round needs a decoding slot"
        assert L + k < self.max_seq, "k_eff clamp must keep the window inside max_seq"
        bi0 = L // bs
        forked = (L % bs) != 0
        # active decode slots always satisfy len(table) == ceil(L / bs): spec
        # rounds grow the table themselves and decode chunks never run on a
        # spec engine, so over-allocation (an EOS mid-chunk) cannot occur
        assert len(s.table) == bi0 + (1 if forked else 0), (
            f"slot {slot} table length {len(s.table)} inconsistent with kv_len {L}"
        )
        bik = (L + k) // bs
        table: list[int] = []
        try:
            for bi in range(bi0, bik + 1):
                blk = self._alloc_fresh()
                if bi == bi0 and forked:
                    # read-fork of the partially-filled tail: the copied
                    # payload carries valid scales, so the block must not sit
                    # in the fresh-reset queue (same hazard as _make_writable)
                    self._fresh_blocks.discard(blk)
                    self.pending_copies.append((s.table[bi0], blk))
                table.append(blk)
        except PoolExhausted:
            self._release_branch_blocks(table)
            raise
        br = SpecBranch(slot, s.uid, drafts, L, bi0, table, forked)
        self._branches.setdefault(slot, []).append(br)
        if forked:
            self.stats["spec_forks"] += 1
        # window rows: the pending token (sampled last round, KV not yet
        # written) then the k drafts — C = k + 1 rows at positions [L, L + k]
        C = k + 1
        toks = np.zeros((1, C), np.int32)
        toks[0, 0] = self._tokens[slot, 0]
        toks[0, 1:] = drafts
        win = np.full((self.blocks_per_table,), NULL_BLOCK, np.int32)
        win[:bi0] = s.table[:bi0]
        win[bi0 : bik + 1] = table
        blk_t = np.zeros((C,), np.int32)
        off_t = np.zeros((C,), np.int32)
        for i in range(C):
            pos = L + i
            blk_t[i] = table[pos // bs - bi0]
            off_t[i] = pos % bs
        return SpecVerifyPlan(slot, br, toks, L, win, blk_t, off_t)

    def commit_spec_round(self, plan: SpecVerifyPlan, verified) -> SpecCommit:
        """Adjudicate a verify round: greedy accept rule, splice the winning
        branch prefix into the slot table, release the losing tail. The
        committed tail block is always a branch block (the branch covers
        position ``start`` onward), so ``keep >= 1`` and the slot's old tail
        — if the branch forked it — is released here: safe, because the
        engine drained the fork copy before the verify launched."""
        br = plan.branch
        slot = plan.slot
        s = self._slots[slot]
        bs = self.block_size
        verified = [int(v) for v in np.asarray(verified).reshape(-1)]
        k = len(br.drafts)
        assert len(verified) == k + 1, "verify must return one token per window row"
        a = greedy_accept_length(br.drafts, verified)
        L = br.start
        bi0 = br.bi0
        tail_bi = (L + a) // bs
        keep = tail_bi - bi0 + 1
        self._release_branch_blocks(br.table[keep:])
        kept = br.table[:keep]
        if br.forked:
            old = s.table[bi0]
            s.table[bi0] = kept[0]
            self.pool.release(old)
            s.table.extend(kept[1:])
        else:
            s.table.extend(kept)
        self._tables[slot, bi0 : bi0 + keep] = kept
        self._branches[slot].remove(br)
        if not self._branches[slot]:
            del self._branches[slot]
        self.stats["spec_rounds"] += 1
        self.stats["spec_drafted"] += k
        self.stats["spec_accepted"] += a
        emitted = list(br.drafts[:a]) + [verified[a]]
        # int4 tail hygiene: rejected rows at positions [L+a+1, L+k] seeded
        # immutable sub-block codes; when the first of them shares the kept
        # tail block, the engine must zero codes past the committed rows
        trim_tail = a < k and (L + a + 1) // bs == tail_bi
        return SpecCommit(slot, emitted, a, kept[-1], (L + a) % bs + 1, trim_tail)

    def absorb_spec_round(self, slot: int, emitted: list[int]) -> int:
        """Pull one committed spec round into host state: each emitted token
        replays the decode-scan transition (append, kv_len++, budget--,
        pending-token update, finish checks in scan order) so greedy spec is
        bit-identical to vanilla including where generation stops — later
        emissions past a finish are truncated, exactly the tokens vanilla
        would never have produced. One round = one device step = one SLA
        tick, which is what makes steps-per-token the speedup metric."""
        s = self._slots[slot]
        self._ticks += 1
        self.stats["decode_steps"] += 1
        self.stats["occupancy_sum"] += 1.0  # spec rounds run one slot per launch
        self.stats["max_active"] = max(self.stats["max_active"], self.num_active)
        n_out = 0
        finished = None
        for t in emitted:
            t = int(t)
            s.generated.append(t)
            self.kv_lens[slot] += 1
            self._budget[slot] -= 1
            self._tokens[slot, 0] = t
            n_out += 1
            if self.eos_id is not None and t == self.eos_id:
                finished = "eos"
                break
            if self._budget[slot] <= 0 or self.kv_lens[slot] >= self.max_seq:
                finished = "length"
                break
        self.stats["tokens_out"] += n_out
        self.stats["spec_emitted"] += n_out
        if finished is not None:
            self._finish(slot, finished)
        return n_out

    def _release_branch_blocks(self, blocks) -> None:
        """Release branch-owned blocks, first purging any queued CoW copy
        whose destination is one of them: the id can be recycled before the
        next drain, and a stale fork copy landing in it would corrupt the
        new owner (the drain-ordering hazard of DESIGN.md §9). Released ids
        stay in ``_fresh_blocks`` — a queued reset on a freed block is
        harmless, and the id re-entering via alloc needs the reset anyway."""
        doomed = set(blocks)
        if doomed and self.pending_copies:
            self.pending_copies = [(a, b) for (a, b) in self.pending_copies
                                   if b not in doomed]
        for blk in blocks:
            self.pool.release(blk)

    def abort_spec_branches(self, slot: int) -> int:
        """Kill every in-flight branch of ``slot`` (losing sibling, cancel,
        preemption, mid-verify PoolExhausted): all branch blocks release and
        their queued fork copies are purged. The slot's own table is
        untouched — a read-fork never dropped the slot's references."""
        branches = self._branches.pop(slot, [])
        for br in branches:
            self._release_branch_blocks(br.table)
        return len(branches)

    def _finish(self, slot: int, reason: str):
        # in-flight spec branches die with the slot (cancel mid-verify, EOS
        # truncation): their blocks and queued fork copies must go before the
        # slot's own references drop, or a recycled dst could eat a stale copy
        self.abort_spec_branches(slot)
        s = self._slots[slot]
        for blk in s.table:
            self.pool.release(blk)
        self._tables[slot, :] = NULL_BLOCK
        carry = self._preempt_carry.pop(s.uid, None)
        super()._finish(slot, reason)
        if carry:  # tokens generated before a preemption lead the final answer
            g = self._results[s.uid]
            self._results[s.uid] = Generation(g.uid, carry + g.tokens, g.finish_reason)

    # -------------------------------------------------------------- telemetry

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of submitted prompt tokens served from the prefix cache."""
        return self.stats["prefix_hit_tokens"] / max(self.stats["prompt_tokens"], 1)

    @property
    def live_kv_tokens(self) -> int:
        """Tokens of KV currently materialized for unfinished requests."""
        total = 0
        for i, s in enumerate(self._slots):
            if s.free:
                continue
            total += s.filled if s.prefilling else int(self.kv_lens[i])
        return total
