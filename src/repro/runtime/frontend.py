"""Async serving front: streaming admission over a stepped engine.

The engines (``runtime/engine.py``) are pull-driven — someone must call
``step_chunk`` — and their results surface per *request set* via ``run()``.
Heavy online traffic needs the inverse shape (DESIGN.md §11): requests
arrive at any time, tokens stream back per request as they are produced,
clients vanish mid-stream, and overload must resolve to structured
back-pressure, not a growing queue. ``AsyncFrontend`` is that inversion,
built on the host core's SLA surface (``try_submit`` / ``tokens_so_far`` /
``take_finished`` / ``take_shed`` / ``cancel``):

  * one background *pump* task steps the engine whenever work exists and
    flushes per-request token deltas into each ``StreamHandle``'s queue;
  * an ``asyncio.Lock`` serializes every engine touch (submit, cancel,
    step) — the host core is not thread-safe, and the lock is the entire
    concurrency story;
  * the blocking ``step_chunk`` runs in the default executor, so a slow or
    stalled device step never blocks the event loop: submissions and
    cancellations keep being *accepted* (they queue on the lock) and every
    other coroutine keeps running — the chaos suite injects exactly this;
  * per-request cancellation routes through ``HostCore.cancel``, which
    releases every block the request holds back to the pool (the audit in
    ``runtime/faults.py`` proves no leak), and resolves the stream with
    finish_reason "cancelled";
  * admission rejections and post-admission deadline sheds surface as
    structured ``Rejected`` values (retryable + backoff hint + pool
    occupancy), never as exceptions mid-stream.

Deadlines passed to ``submit`` are *relative* TTFT budgets in the engine
clock's units (deterministic scheduler ticks by default, seconds when the
engine was built with ``clock=time.monotonic``); the frontend converts them
to the absolute form the core compares against.

This module imports no jax: it drives any object with the HostCore serving
surface, which is how the chaos suite runs it against the numpy-emulated
core at fuzz speed.
"""

from __future__ import annotations

import asyncio
import dataclasses

from repro.runtime.engine_core import GREEDY, Rejected, Request
from repro.runtime.kv_pool import PoolExhausted

__all__ = ["AsyncFrontend", "StreamHandle"]

_DONE = object()  # stream terminator sentinel


class StreamHandle:
    """One request's streaming view: an async iterator of generated tokens.

    Iteration ends when the request finishes, is cancelled, or is shed;
    ``finish_reason`` then holds "eos" / "length" / "cancelled" / "shed"
    (``rejected`` carries the structured ``Rejected`` for sheds). ``tokens``
    accumulates everything pushed so far — preempt-recompute carries
    included, so the stream is the request's exact greedy output."""

    def __init__(self, frontend: "AsyncFrontend", uid: int):
        self.uid = uid
        self.tokens: list[int] = []
        self.finish_reason: str | None = None
        self.rejected: Rejected | None = None
        self._frontend = frontend
        self._q: asyncio.Queue = asyncio.Queue()
        self._sent = 0  # engine-side tokens already pushed into the queue

    # pump-side (always under the frontend lock)

    def _push(self, tok: int) -> None:
        self.tokens.append(tok)
        self._q.put_nowait(tok)

    def _close(self, reason: str) -> None:
        if self.finish_reason is None:
            self.finish_reason = reason
            self._q.put_nowait(_DONE)

    def _fail(self, rej: Rejected) -> None:
        self.rejected = rej
        self._close("shed")

    # client-side

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        if self.finish_reason is not None and self._q.empty():
            raise StopAsyncIteration
        tok = await self._q.get()
        if tok is _DONE:
            raise StopAsyncIteration
        return tok

    async def collect(self) -> list[int]:
        """Drain the stream to completion; returns all tokens."""
        async for _ in self:
            pass
        return list(self.tokens)

    async def cancel(self) -> None:
        """Client disconnect: abort the request and release its blocks. The
        stream closes with finish_reason "cancelled" (no-op if already
        finished — a disconnect racing a finish is not an error)."""
        await self._frontend._cancel(self)


class AsyncFrontend:
    """Asyncio admission + streaming layer over one engine (DESIGN.md §11).

    Use as an async context manager::

        async with AsyncFrontend(engine) as fe:
            h = await fe.submit(prompt, max_new=32, priority=0, deadline=50)
            if isinstance(h, Rejected):      # shed: back off h.backoff_hint
                ...
            else:
                async for tok in h:          # tokens stream per engine chunk
                    ...

    ``chunk_steps`` bounds decode steps per pump iteration (smaller = lower
    inter-token latency, more host overhead); None uses the engine's
    ``steps_per_sync``. On exit, unresolved streams are cancelled — call
    ``drain()`` first for a graceful finish.
    """

    def __init__(self, engine, *, chunk_steps: int | None = None):
        self.engine = engine
        self.chunk_steps = chunk_steps
        self._handles: dict[int, StreamHandle] = {}
        self._lock = asyncio.Lock()
        self._wake = asyncio.Event()
        self._closed = False
        self._pump_task: asyncio.Task | None = None
        self._fatal: Exception | None = None

    # ------------------------------------------------------------- lifecycle

    async def __aenter__(self) -> "AsyncFrontend":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start(self) -> None:
        if self._pump_task is None:
            self._pump_task = asyncio.get_running_loop().create_task(self._pump())

    async def aclose(self) -> None:
        """Cancel every unresolved stream, stop the pump."""
        async with self._lock:
            for uid in list(self._handles):
                self.engine.cancel(uid)
            self._flush_locked()
        self._closed = True
        self._wake.set()
        if self._pump_task is not None:
            await self._pump_task
            self._pump_task = None

    async def drain(self) -> None:
        """Wait until every admitted request has resolved (finished, shed,
        or cancelled). Raises the pump's error if stepping died fatally."""
        while self._handles and self._fatal is None:
            self._wake.set()
            await asyncio.sleep(0.001)
        if self._fatal is not None:
            raise self._fatal

    # ------------------------------------------------------------- admission

    async def submit(self, prompt, max_new: int | None = None, sampling=GREEDY, *,
                     priority: int = 0,
                     deadline: float | None = None) -> StreamHandle | Rejected:
        """Admit a request; returns a ``StreamHandle`` or a structured
        ``Rejected`` (non-retryable for malformed input, retryable with a
        backoff hint under load shed). Accepts an ``engine_core.Request``
        (canonical) or the legacy ``(prompt, max_new, ...)`` spread.
        ``deadline`` (or ``Request.deadline``) is a relative TTFT budget in
        the engine clock's units."""
        async with self._lock:
            if isinstance(prompt, Request):
                req = prompt
                if req.deadline is not None:
                    req = dataclasses.replace(
                        req, deadline=self.engine.now() + req.deadline)
                r = self.engine.try_submit(req)
            else:
                abs_deadline = None if deadline is None else self.engine.now() + deadline
                r = self.engine.try_submit(prompt, max_new, sampling,
                                           priority=priority, deadline=abs_deadline)
            if isinstance(r, Rejected):
                return r
            h = StreamHandle(self, r)
            self._handles[r] = h
        self._wake.set()
        return h

    async def _cancel(self, handle: StreamHandle) -> None:
        async with self._lock:
            self.engine.cancel(handle.uid)
            self._flush_locked()
            # unknown/already-finished uids resolve here too: never leave a
            # client awaiting a stream nobody will close
            if self._handles.pop(handle.uid, None) is not None:
                handle._close("cancelled")

    # ------------------------------------------------------------------ pump

    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        while not self._closed:
            if not (self._handles and self.engine.has_work()):
                self._wake.clear()
                # re-check before sleeping: submit may have landed in between
                if not (self._handles and self.engine.has_work()):
                    await self._wake.wait()
                continue
            async with self._lock:
                try:
                    await loop.run_in_executor(
                        None, self.engine.step_chunk, self.chunk_steps)
                except PoolExhausted as e:
                    # terminal (non-retryable) exhaustion: the engine cannot
                    # make progress at all — fail every live stream with the
                    # structured census rather than hanging the clients
                    rej = Rejected("pool_pressure", detail=str(e),
                                   retryable=e.retryable, occupancy=e.occupancy)
                    self._fatal = e
                    for uid, h in list(self._handles.items()):
                        self.engine.cancel(uid)
                        h._fail(rej)
                    self._handles.clear()
                    return
                self._flush_locked()
            await asyncio.sleep(0)  # let clients consume between chunks

    def _flush_locked(self) -> None:
        """Push per-request token deltas and resolve finished/shed streams.
        Caller holds the lock."""
        eng = self.engine
        for uid, h in self._handles.items():
            toks = eng.tokens_so_far(uid)
            for t in toks[h._sent:]:
                h._push(t)
            h._sent = len(toks)
        for uid, g in eng.take_finished().items():
            h = self._handles.pop(uid, None)
            if h is not None:
                for t in g.tokens[h._sent:]:
                    h._push(t)
                h._sent = len(g.tokens)
                h._close(g.finish_reason)
        for uid, rej in eng.take_shed().items():
            h = self._handles.pop(uid, None)
            if h is not None:
                h._fail(rej)

    # ------------------------------------------------------------- telemetry

    @property
    def inflight(self) -> int:
        return len(self._handles)

    def ttft(self, uid: int) -> float | None:
        """First-token latency for ``uid`` in engine-clock units, once the
        first token exists (None before)."""
        return self.engine.ttft.get(uid)
