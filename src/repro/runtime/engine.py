"""Continuous-batching decode engine over a slot-based ragged KV cache.

The serving problem EXAQ targets (paper §4: attention-heavy decode) is only
won at the *runtime* level: many concurrent requests of different lengths
must share one jitted step, or the kernel savings drown in per-request
dispatch and padding waste (cf. QUIK/SoftmAP — low-bit inference pays off
when the surrounding runtime is batched and fused). This engine provides:

  * Slot cache   — fixed (L, max_slots, KV, max_seq, Dh) K/V buffers plus a
                   per-slot ``kv_lens`` vector. Shapes never change, so the
                   decode step compiles exactly once; raggedness lives in the
                   lengths, and ``attention_decode_ragged`` masks/writes per
                   slot (DESIGN.md §Serving).
  * Scheduler    — requests queue up host-side; free slots are filled by a
                   bucketed single-request prefill (padded to a power-of-two
                   length; the true length picks the logits row), finished
                   slots (EOS / token budget / max_seq) are evicted and
                   immediately refilled.
  * Decode chunk — ``steps_per_sync`` decode steps run inside one jitted
                   ``lax.scan``; every step batches ALL active slots through
                   one ragged attention dispatch per layer and one batched
                   sampling dispatch (greedy / temperature / top-k / top-p
                   with per-slot params — runtime/sampling.py).

Families: dense / moe (token-only attention decoders). SSM/hybrid/audio
caches have no ragged sequence axis to slot-batch; vlm decode would work
(its KV cache is regular) but the engine's prefill builds token-only
batches — admitting vlm needs per-request ``vision_embeds`` plumbing first.
``runtime.serve.generate`` keeps the rectangular loop for all of these.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, default_qstate
from repro.runtime import sampling as smp
from repro.runtime import sharding as shd


@dataclass(frozen=True)
class Request:
    uid: int
    prompt: tuple[int, ...]
    max_new: int
    sampling: smp.SamplingParams = smp.GREEDY


@dataclass
class Generation:
    """Finished request: generated ids (EOS included when hit) + why it ended."""

    uid: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length"


@dataclass
class _Slot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.uid < 0


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class Engine:
    """Continuous-batching serving engine for one model + qstate.

    Typical use::

        eng = Engine(cfg, params, max_slots=8, max_seq=512, eos_id=2)
        eng.submit([1, 5, 7], max_new=32)
        eng.submit([9, 9], max_new=16, sampling=SamplingParams(temperature=0.8))
        results = eng.run()          # {uid: Generation}

    or incrementally (arrival-driven traces): ``submit`` whenever requests
    arrive, ``step_chunk()`` to advance ``steps_per_sync`` decode steps.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int,
        max_seq: int,
        qstate=None,
        eos_id: int | None = None,
        steps_per_sync: int = 8,
        cache_dtype=jnp.bfloat16,
        seed: int = 0,
        mesh=None,
    ):
        if cfg.family not in ("dense", "moe") or cfg.frontend is not None:
            raise ValueError(
                f"Engine supports token-only attention decoders (dense/moe), got "
                f"family={cfg.family!r} frontend={cfg.frontend!r} (frontend models need "
                "per-request embeds at prefill; ssm/hybrid/audio caches aren't slot-ragged)"
            )
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.qstate = qstate if qstate is not None else default_qstate(cfg)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.steps_per_sync = steps_per_sync
        self.cache_dtype = cache_dtype
        self._key = jax.random.PRNGKey(seed)

        cache = self.model.init_cache(max_slots, max_seq, cache_dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding

            spec = shd.slot_cache_spec(cfg, mesh)
            cache["k"] = jax.device_put(cache["k"], NamedSharding(mesh, spec))
            cache["v"] = jax.device_put(cache["v"], NamedSharding(mesh, spec))
        self._cache_k, self._cache_v = cache["k"], cache["v"]

        # host-side slot state (small; shipped to device each chunk)
        self._slots = [_Slot() for _ in range(max_slots)]
        self.kv_lens = np.zeros((max_slots,), np.int32)
        self._active = np.zeros((max_slots,), bool)
        self._budget = np.zeros((max_slots,), np.int32)
        self._tokens = np.zeros((max_slots, 1), np.int32)
        self._temperature = np.zeros((max_slots,), np.float32)
        self._top_k = np.zeros((max_slots,), np.int32)
        self._top_p = np.ones((max_slots,), np.float32)

        self._queue: deque[Request] = deque()
        self._results: dict[int, Generation] = {}
        self._next_uid = 0

        # telemetry for bench_serving
        self.stats = {"decode_steps": 0, "tokens_out": 0, "occupancy_sum": 0.0,
                      "max_active": 0, "prefills": 0, "decode_time": 0.0}

        # donate the K/V buffers on the hot paths: the engine rebinds them from
        # the outputs immediately, so XLA may update the cache in place instead
        # of copying the full (L, slots, KV, max_seq, Dh) arrays per chunk /
        # admission (CPU ignores donation; TPU/GPU halve peak cache memory)
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn, donate_argnums=(0, 1))
        self._jit_sample = jax.jit(smp.sample_tokens)
        self._jit_chunk = jax.jit(self._chunk_fn, static_argnames=("steps", "sampler"),
                                  donate_argnums=(1, 2))

    # ------------------------------------------------------------ jitted fns

    def _prefill_fn(self, params, tokens, length):
        """tokens (1, P) right-padded; length (1,) true prompt length."""
        cache = self.model.init_cache(1, tokens.shape[1], self.cache_dtype)
        logits, cache = self.model.prefill(
            params, {"tokens": tokens}, cache, self.qstate, lens=length
        )
        return logits, cache["k"], cache["v"]

    def _insert_fn(self, big_k, big_v, ks, vs, slot):
        """Write a (L, 1, KV, P, Dh) prefill cache into slot ``slot``."""
        start = (0, slot, 0, 0, 0)
        return (
            jax.lax.dynamic_update_slice(big_k, ks.astype(big_k.dtype), start),
            jax.lax.dynamic_update_slice(big_v, vs.astype(big_v.dtype), start),
        )

    def _chunk_fn(self, params, k, v, tokens, lens, active, budget, temperature,
                  top_k, top_p, key, *, steps, sampler):
        """``steps`` decode iterations under one jit: per step, one ragged
        attention dispatch over all slots + one batched sampling dispatch.
        EOS/budget/max_seq transitions update the active mask *inside* the
        scan, so a slot that finishes mid-chunk stops consuming budget and
        its later emissions are masked. ``sampler`` (static, known host-side
        from the active slots' params) picks the cheapest variant: "greedy"
        is pure argmax, "temperature" is sort-free Gumbel-max, "full" is the
        general top-k/top-p sampler."""
        eos = -1 if self.eos_id is None else self.eos_id

        def step(carry, _):
            k, v, tokens, lens, active, budget, key = carry
            logits, cache = self.model.decode_step_ragged(
                params, tokens, {"k": k, "v": v}, lens, self.qstate
            )
            key, sub = jax.random.split(key)
            if sampler == "greedy":
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            elif sampler == "temperature":
                nxt = smp.sample_temperature(logits, temperature, sub)
            else:
                nxt = smp.sample_tokens(logits, temperature, top_k, top_p, sub)
            emit_mask = active
            new_lens = jnp.where(active, lens + 1, lens)
            new_budget = jnp.where(active, budget - 1, budget)
            finished = (nxt == eos) | (new_budget <= 0) | (new_lens >= self.max_seq)
            new_active = active & ~finished
            new_tokens = jnp.where(active, nxt, tokens[:, 0])[:, None]
            emitted = jnp.where(emit_mask, nxt, -1)
            return (cache["k"], cache["v"], new_tokens, new_lens, new_active, new_budget, key), (
                emitted,
                emit_mask,
            )

        init = (k, v, tokens, lens, active, budget, key)
        (k, v, tokens, lens, active, budget, key), (emitted, masks) = jax.lax.scan(
            step, init, None, length=steps
        )
        return k, v, tokens, lens, active, budget, key, emitted, masks

    # ------------------------------------------------------------- scheduling

    def submit(self, prompt, max_new: int, sampling: smp.SamplingParams = smp.GREEDY) -> int:
        prompt = tuple(int(t) for t in np.asarray(prompt).reshape(-1))
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_seq:
            raise ValueError(f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, prompt, max_new, sampling))
        return uid

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return bool(self._queue) or self.num_active > 0

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s.free]

    def _admit(self) -> int:
        """Prefill queued requests into free slots; returns #admitted."""
        admitted = 0
        free = self._free_slots()
        while free and self._queue:
            req = self._queue.popleft()
            slot = free.pop(0)
            P = min(_bucket(len(req.prompt)), self.max_seq)
            padded = np.zeros((1, P), np.int32)
            padded[0, : len(req.prompt)] = req.prompt
            logits, ks, vs = self._jit_prefill(
                self.params, jnp.asarray(padded), jnp.asarray([len(req.prompt)], jnp.int32)
            )
            self._cache_k, self._cache_v = self._jit_insert(
                self._cache_k, self._cache_v, ks, vs, slot
            )
            self.stats["prefills"] += 1
            self._key, sub = jax.random.split(self._key)
            sp = req.sampling
            first = int(
                self._jit_sample(
                    logits,
                    jnp.asarray([sp.temperature], jnp.float32),
                    jnp.asarray([sp.top_k], jnp.int32),
                    jnp.asarray([sp.top_p], jnp.float32),
                    sub,
                )[0]
            )
            self.stats["tokens_out"] += 1
            s = self._slots[slot]
            s.uid, s.generated = req.uid, [first]
            self.kv_lens[slot] = len(req.prompt)
            self._tokens[slot, 0] = first
            self._temperature[slot] = sp.temperature
            self._top_k[slot] = sp.top_k
            self._top_p[slot] = sp.top_p
            self._budget[slot] = req.max_new - 1
            hit_eos = self.eos_id is not None and first == self.eos_id
            if hit_eos or req.max_new == 1:
                self._finish(slot, "eos" if hit_eos else "length")
            else:
                self._active[slot] = True
            admitted += 1
        return admitted

    def _finish(self, slot: int, reason: str):
        s = self._slots[slot]
        self._results[s.uid] = Generation(s.uid, list(s.generated), reason)
        self._slots[slot] = _Slot()
        self._active[slot] = False

    def step_chunk(self, steps: int | None = None) -> int:
        """Admit + run one jitted decode chunk; returns #tokens emitted."""
        self._admit()
        if self.num_active == 0:
            return 0
        # clamp to the largest remaining budget among active slots: a tail
        # chunk never runs whole-model decode steps nobody can consume (at
        # most steps_per_sync distinct scan lengths ever compile)
        max_budget = int(self._budget[self._active].max())
        steps = min(steps or self.steps_per_sync, max(max_budget, 1))
        t0 = time.perf_counter()
        act = self._active
        if (self._temperature[act] <= 0.0).all():
            sampler = "greedy"
        elif (self._top_k[act] == 0).all() and (self._top_p[act] >= 1.0).all():
            sampler = "temperature"
        else:
            sampler = "full"
        out = self._jit_chunk(
            self.params, self._cache_k, self._cache_v,
            jnp.asarray(self._tokens), jnp.asarray(self.kv_lens),
            jnp.asarray(self._active), jnp.asarray(self._budget),
            jnp.asarray(self._temperature), jnp.asarray(self._top_k),
            jnp.asarray(self._top_p), self._key, steps=steps, sampler=sampler,
        )
        k, v, tokens, lens, active, budget, self._key, emitted, masks = out
        jax.block_until_ready(emitted)
        self.stats["decode_time"] += time.perf_counter() - t0
        self._cache_k, self._cache_v = k, v
        was_active = self._active
        self._tokens = np.array(tokens)
        self.kv_lens = np.array(lens)
        self._active = np.array(active)
        self._budget = np.array(budget)
        emitted = np.asarray(emitted)  # (steps, S)
        masks = np.asarray(masks)
        n_out = 0
        for t in range(emitted.shape[0]):
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += float(masks[t].sum())
            self.stats["max_active"] = max(self.stats["max_active"], int(masks[t].sum()))
            for slot in np.nonzero(masks[t])[0]:
                self._slots[slot].generated.append(int(emitted[t, slot]))
                n_out += 1
        self.stats["tokens_out"] += n_out
        for slot in range(self.max_slots):
            if was_active[slot] and not self._active[slot]:
                last = self._slots[slot].generated[-1]
                hit_eos = self.eos_id is not None and last == self.eos_id
                self._finish(slot, "eos" if hit_eos else "length")
        return n_out

    def run(self) -> dict[int, Generation]:
        """Drain the queue and all active slots; returns {uid: Generation}."""
        while self.has_work():
            self.step_chunk()
        out, self._results = self._results, {}
        return out

    @property
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps
