"""Continuous-batching decode engines: slot-ragged and block-paged KV caches.

The serving problem EXAQ targets (paper §4: attention-heavy decode) is only
won at the *runtime* level: many concurrent requests of different lengths
must share one jitted step, or the kernel savings drown in per-request
dispatch and padding waste (cf. QUIK/SoftmAP — low-bit inference pays off
when the surrounding runtime is batched and fused). Two engines share the
host scheduler scaffolding:

``Engine`` — slot cache (PR 1 baseline, kept as the parity oracle):

  * Slot cache   — fixed (L, max_slots, KV, max_seq, Dh) K/V buffers plus a
                   per-slot ``kv_lens`` vector. Shapes never change, so the
                   decode step compiles exactly once; raggedness lives in the
                   lengths, and ``attention_decode_ragged`` masks/writes per
                   slot (DESIGN.md §Serving).
  * Scheduler    — requests queue up host-side; free slots are filled by a
                   bucketed single-request prefill (padded to a power-of-two
                   length; the true length picks the logits row), finished
                   slots (EOS / token budget / max_seq) are evicted and
                   immediately refilled.
  * Decode chunk — ``steps_per_sync`` decode steps run inside one jitted
                   ``lax.scan``; every step batches ALL active slots through
                   one ragged attention dispatch per layer and one batched
                   sampling dispatch (greedy / temperature / top-k / top-p
                   with per-slot params — runtime/sampling.py).

``PagedEngine`` — block-paged cache (DESIGN.md §3): the slot engine's memory
model scales as ``max_slots x max_seq`` regardless of live lengths and
re-prefills identical prefixes per request. The paged engine replaces the
rectangular buffers with a global block pool (``runtime/kv_pool.py``) plus
per-request block tables:

  * Block pool    — K/V live in (L, num_blocks, KV, block_size, Dh); a slot's
                    cache is the blocks its table names, so memory tracks the
                    sum of live lengths, not slots x max_seq.
  * Prefix reuse  — prompt blocks are published under a rolling chain hash;
                    later requests sharing a prefix retain the cached blocks
                    (refcounted, copy-on-write on append) and skip their
                    prefill entirely.
  * Chunked prefill — prompts prefill in fixed-size chunks interleaved with
                    decode chunks, so a long prompt never stalls the running
                    batch; chunking is bit-exact vs one-shot prefill because
                    the EXAQ histogram combine composes across partitions
                    (DESIGN.md §2/§3).

Families: dense / moe (token-only attention decoders). SSM/hybrid/audio
caches have no ragged sequence axis to slot-batch; vlm decode would work
(its KV cache is regular) but the engines' prefill builds token-only
batches — admitting vlm needs per-request ``vision_embeds`` plumbing first.
``runtime.serve.generate`` keeps the rectangular loop for all of these.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, default_qstate
from repro.runtime import sampling as smp
from repro.runtime import sharding as shd
from repro.runtime.kv_pool import NULL_BLOCK, BlockPool, PoolExhausted, chain_hashes


@dataclass(frozen=True)
class Request:
    uid: int
    prompt: tuple[int, ...]
    max_new: int
    sampling: smp.SamplingParams = smp.GREEDY


@dataclass
class Generation:
    """Finished request: generated ids (EOS included when hit) + why it ended."""

    uid: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length"


@dataclass
class _Slot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.uid < 0

    @property
    def prefilling(self) -> bool:
        return False  # slot-engine prefill is synchronous at admission


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class Engine:
    """Continuous-batching serving engine for one model + qstate.

    Typical use::

        eng = Engine(cfg, params, max_slots=8, max_seq=512, eos_id=2)
        eng.submit([1, 5, 7], max_new=32)
        eng.submit([9, 9], max_new=16, sampling=SamplingParams(temperature=0.8))
        results = eng.run()          # {uid: Generation}

    or incrementally (arrival-driven traces): ``submit`` whenever requests
    arrive, ``step_chunk()`` to advance ``steps_per_sync`` decode steps.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int,
        max_seq: int,
        qstate=None,
        eos_id: int | None = None,
        steps_per_sync: int = 8,
        cache_dtype=jnp.bfloat16,
        seed: int = 0,
        mesh=None,
    ):
        self._init_common(cfg, params, max_slots=max_slots, max_seq=max_seq, qstate=qstate,
                          eos_id=eos_id, steps_per_sync=steps_per_sync,
                          cache_dtype=cache_dtype, seed=seed)

        cache = self.model.init_cache(max_slots, max_seq, cache_dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding

            spec = shd.slot_cache_spec(cfg, mesh)
            cache["k"] = jax.device_put(cache["k"], NamedSharding(mesh, spec))
            cache["v"] = jax.device_put(cache["v"], NamedSharding(mesh, spec))
        self._cache_k, self._cache_v = cache["k"], cache["v"]

        # donate the K/V buffers on the hot paths: the engine rebinds them from
        # the outputs immediately, so XLA may update the cache in place instead
        # of copying the full (L, slots, KV, max_seq, Dh) arrays per chunk /
        # admission (CPU ignores donation; TPU/GPU halve peak cache memory)
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn, donate_argnums=(0, 1))
        self._jit_chunk = jax.jit(self._chunk_fn, static_argnames=("steps", "sampler"),
                                  donate_argnums=(1,))

    # --------------------------------------------------- shared host scaffold

    def _init_common(self, cfg, params, *, max_slots, max_seq, qstate, eos_id,
                     steps_per_sync, cache_dtype, seed):
        if cfg.family not in ("dense", "moe") or cfg.frontend is not None:
            raise ValueError(
                f"Engine supports token-only attention decoders (dense/moe), got "
                f"family={cfg.family!r} frontend={cfg.frontend!r} (frontend models need "
                "per-request embeds at prefill; ssm/hybrid/audio caches aren't slot-ragged)"
            )
        if jnp.dtype(cache_dtype) == jnp.int8 and not isinstance(self, PagedEngine):
            raise ValueError(
                "int8 KV is a paged-pool storage format (per-block scales — DESIGN.md §6); "
                "the slot engine's rectangular cache supports fp dtypes only"
            )
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.qstate = qstate if qstate is not None else default_qstate(cfg)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.steps_per_sync = steps_per_sync
        self.cache_dtype = cache_dtype
        self._key = jax.random.PRNGKey(seed)

        # host-side slot state (small; shipped to device each chunk)
        self._slots = [self._new_slot() for _ in range(max_slots)]
        self.kv_lens = np.zeros((max_slots,), np.int32)
        self._active = np.zeros((max_slots,), bool)
        self._budget = np.zeros((max_slots,), np.int32)
        self._tokens = np.zeros((max_slots, 1), np.int32)
        self._temperature = np.zeros((max_slots,), np.float32)
        self._top_k = np.zeros((max_slots,), np.int32)
        self._top_p = np.ones((max_slots,), np.float32)

        self._queue: deque[Request] = deque()
        self._results: dict[int, Generation] = {}
        self._next_uid = 0

        # telemetry for bench_serving
        self.stats = {"decode_steps": 0, "tokens_out": 0, "occupancy_sum": 0.0,
                      "max_active": 0, "prefills": 0, "decode_time": 0.0}

        self._jit_sample = jax.jit(smp.sample_tokens)

    def _new_slot(self):
        return _Slot()

    def _validate_request(self, prompt, max_new: int) -> None:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_seq:
            raise ValueError(f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")

    def submit(self, prompt, max_new: int, sampling: smp.SamplingParams = smp.GREEDY) -> int:
        prompt = tuple(int(t) for t in np.asarray(prompt).reshape(-1))
        self._validate_request(prompt, max_new)
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, prompt, max_new, sampling))
        return uid

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return (bool(self._queue) or self.num_active > 0
                or any(not s.free and s.prefilling for s in self._slots))

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s.free]

    def _sample_first(self, slot: int, req: Request, logits) -> None:
        """Sample the first generated token from prefill logits and flip the
        slot into decode state (or finish immediately on EOS / budget 1)."""
        self._key, sub = jax.random.split(self._key)
        sp = req.sampling
        first = int(
            self._jit_sample(
                logits,
                jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([sp.top_p], jnp.float32),
                sub,
            )[0]
        )
        self.stats["tokens_out"] += 1
        s = self._slots[slot]
        s.uid, s.generated = req.uid, [first]
        self.kv_lens[slot] = len(req.prompt)
        self._tokens[slot, 0] = first
        self._temperature[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._budget[slot] = req.max_new - 1
        hit_eos = self.eos_id is not None and first == self.eos_id
        if hit_eos or req.max_new == 1:
            self._finish(slot, "eos" if hit_eos else "length")
        else:
            self._active[slot] = True

    def _finish(self, slot: int, reason: str):
        s = self._slots[slot]
        self._results[s.uid] = Generation(s.uid, list(s.generated), reason)
        self._slots[slot] = self._new_slot()
        self._active[slot] = False

    def _pick_sampler(self) -> str:
        """Cheapest chunk sampler covering every active slot's params."""
        act = self._active
        if (self._temperature[act] <= 0.0).all():
            return "greedy"
        if (self._top_k[act] == 0).all() and (self._top_p[act] >= 1.0).all():
            return "temperature"
        return "full"

    def _clamp_steps(self, steps: int | None) -> int:
        # clamp to the largest remaining budget among active slots: a tail
        # chunk never runs whole-model decode steps nobody can consume (at
        # most steps_per_sync distinct scan lengths ever compile)
        max_budget = int(self._budget[self._active].max())
        return min(steps or self.steps_per_sync, max(max_budget, 1))

    def _absorb_chunk(self, tokens, lens, active, budget, emitted, masks, was_active) -> int:
        """Pull a finished decode chunk's state back to host: emissions per
        slot, occupancy telemetry, and finish transitions for slots that
        went inactive inside the chunk."""
        self._tokens = np.array(tokens)
        self.kv_lens = np.array(lens)
        self._active = np.array(active)
        self._budget = np.array(budget)
        emitted = np.asarray(emitted)  # (steps, S)
        masks = np.asarray(masks)
        n_out = 0
        for t in range(emitted.shape[0]):
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += float(masks[t].sum())
            self.stats["max_active"] = max(self.stats["max_active"], int(masks[t].sum()))
            for slot in np.nonzero(masks[t])[0]:
                self._slots[slot].generated.append(int(emitted[t, slot]))
                n_out += 1
        self.stats["tokens_out"] += n_out
        for slot in range(self.max_slots):
            if was_active[slot] and not self._active[slot]:
                last = self._slots[slot].generated[-1]
                hit_eos = self.eos_id is not None and last == self.eos_id
                self._finish(slot, "eos" if hit_eos else "length")
        return n_out

    def _decode_scan(self, step_kv, kv, tokens, lens, active, budget, temperature,
                     top_k, top_p, key, *, steps, sampler):
        """``steps`` decode iterations under one jit: per step, one attention
        dispatch over all slots + one batched sampling dispatch. EOS/budget/
        max_seq transitions update the active mask *inside* the scan, so a
        slot that finishes mid-chunk stops consuming budget and its later
        emissions are masked. ``sampler`` (static, known host-side from the
        active slots' params) picks the cheapest variant: "greedy" is pure
        argmax, "temperature" is sort-free Gumbel-max, "full" is the general
        top-k/top-p sampler. ``step_kv(tokens, kv, lens, active)`` is the
        engine-specific model call (slot-ragged or paged); ``kv`` is the
        engine's cache pytree — {"k","v"} for the slot cache, plus
        "k_scale"/"v_scale" planes for an int8 paged pool."""
        eos = -1 if self.eos_id is None else self.eos_id

        def step(carry, _):
            kv, tokens, lens, active, budget, key = carry
            logits, kv = step_kv(tokens, kv, lens, active)
            key, sub = jax.random.split(key)
            if sampler == "greedy":
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            elif sampler == "temperature":
                nxt = smp.sample_temperature(logits, temperature, sub)
            else:
                nxt = smp.sample_tokens(logits, temperature, top_k, top_p, sub)
            emit_mask = active
            new_lens = jnp.where(active, lens + 1, lens)
            new_budget = jnp.where(active, budget - 1, budget)
            finished = (nxt == eos) | (new_budget <= 0) | (new_lens >= self.max_seq)
            new_active = active & ~finished
            new_tokens = jnp.where(active, nxt, tokens[:, 0])[:, None]
            emitted = jnp.where(emit_mask, nxt, -1)
            return (kv, new_tokens, new_lens, new_active, new_budget, key), (
                emitted,
                emit_mask,
            )

        init = (kv, tokens, lens, active, budget, key)
        (kv, tokens, lens, active, budget, key), (emitted, masks) = jax.lax.scan(
            step, init, None, length=steps
        )
        return kv, tokens, lens, active, budget, key, emitted, masks

    # ------------------------------------------------------------ jitted fns

    def _prefill_fn(self, params, tokens, length):
        """tokens (1, P) right-padded; length (1,) true prompt length."""
        cache = self.model.init_cache(1, tokens.shape[1], self.cache_dtype)
        logits, cache = self.model.prefill(
            params, {"tokens": tokens}, cache, self.qstate, lens=length
        )
        return logits, cache["k"], cache["v"]

    def _insert_fn(self, big_k, big_v, ks, vs, slot):
        """Write a (L, 1, KV, P, Dh) prefill cache into slot ``slot``."""
        start = (0, slot, 0, 0, 0)
        return (
            jax.lax.dynamic_update_slice(big_k, ks.astype(big_k.dtype), start),
            jax.lax.dynamic_update_slice(big_v, vs.astype(big_v.dtype), start),
        )

    def _chunk_fn(self, params, kv, tokens, lens, active, budget, temperature,
                  top_k, top_p, key, *, steps, sampler):
        def step_kv(tokens, kv, lens, active):
            logits, cache = self.model.decode_step_ragged(
                params, tokens, kv, lens, self.qstate
            )
            return logits, {"k": cache["k"], "v": cache["v"]}

        return self._decode_scan(step_kv, kv, tokens, lens, active, budget,
                                 temperature, top_k, top_p, key, steps=steps, sampler=sampler)

    # ------------------------------------------------------------- scheduling

    def _admit(self) -> int:
        """Prefill queued requests into free slots; returns #admitted."""
        admitted = 0
        free = self._free_slots()
        while free and self._queue:
            req = self._queue.popleft()
            slot = free.pop(0)
            P = min(_bucket(len(req.prompt)), self.max_seq)
            padded = np.zeros((1, P), np.int32)
            padded[0, : len(req.prompt)] = req.prompt
            logits, ks, vs = self._jit_prefill(
                self.params, jnp.asarray(padded), jnp.asarray([len(req.prompt)], jnp.int32)
            )
            self._cache_k, self._cache_v = self._jit_insert(
                self._cache_k, self._cache_v, ks, vs, slot
            )
            self.stats["prefills"] += 1
            self._sample_first(slot, req, logits)
            admitted += 1
        return admitted

    def step_chunk(self, steps: int | None = None) -> int:
        """Admit + run one jitted decode chunk; returns #tokens emitted."""
        self._admit()
        if self.num_active == 0:
            return 0
        steps = self._clamp_steps(steps)
        t0 = time.perf_counter()
        out = self._jit_chunk(
            self.params, {"k": self._cache_k, "v": self._cache_v},
            jnp.asarray(self._tokens), jnp.asarray(self.kv_lens),
            jnp.asarray(self._active), jnp.asarray(self._budget),
            jnp.asarray(self._temperature), jnp.asarray(self._top_k),
            jnp.asarray(self._top_p), self._key, steps=steps, sampler=self._pick_sampler(),
        )
        kv, tokens, lens, active, budget, self._key, emitted, masks = out
        jax.block_until_ready(emitted)
        self.stats["decode_time"] += time.perf_counter() - t0
        self._cache_k, self._cache_v = kv["k"], kv["v"]
        was_active = self._active
        return self._absorb_chunk(tokens, lens, active, budget, emitted, masks, was_active)

    def run(self) -> dict[int, Generation]:
        """Drain the queue and all active slots; returns {uid: Generation}."""
        while self.has_work():
            self.step_chunk()
        out, self._results = self._results, {}
        return out

    @property
    def mean_occupancy(self) -> float:
        steps = max(self.stats["decode_steps"], 1)
        return self.stats["occupancy_sum"] / steps


# ===================================================================== paged


@dataclass
class _PagedSlot:
    uid: int = -1
    generated: list[int] = field(default_factory=list)
    req: Request | None = None
    table: list[int] = field(default_factory=list)   # host truth; mirrored to _tables
    hashes: list[tuple[int, int]] = field(default_factory=list)
    filled: int = 0        # prompt tokens with KV materialized (hits + chunks)
    cached: int = 0        # tokens satisfied from the prefix cache
    _prefilling: bool = False

    @property
    def free(self) -> bool:
        return self.uid < 0

    @property
    def prefilling(self) -> bool:
        return self._prefilling


class PagedEngine(Engine):
    """Continuous batching over a block-paged KV cache (DESIGN.md §3).

    Same public surface as ``Engine`` (submit / step_chunk / run), same
    sampling and finish semantics, different memory model:

      * KV lives in a global pool of ``num_blocks`` blocks of ``block_size``
        tokens; each slot's cache is the blocks its table names
        (``runtime/kv_pool.BlockPool`` owns ids, refcounts, the prefix index
        and CoW adjudication — this engine performs the device copies).
      * Admission matches the prompt's rolling block hashes against the
        prefix index; hits retain cached blocks and skip their prefill. At
        least the prompt's last token is always re-prefilled so sampling has
        logits.
      * Remaining prompt tokens prefill in ``prefill_chunk``-token chunks —
        one chunk per prefilling slot per ``step_chunk``, *interleaved* with
        decode chunks for the already-active slots, so a long prompt never
        stalls the running batch.
      * Greedy outputs are bit-exact vs the slot engine on the same trace:
        chunking and paging both compose under the EXAQ histogram combine
        (§2), and the benchmark asserts it (benchmarks/bench_serving.py).

    ``num_blocks`` defaults to full provisioning (every slot can reach
    ``max_seq``), which makes pool exhaustion impossible; smaller pools are
    allowed (prefix sharing usually covers the gap) and exhaustion becomes
    back-pressure, never KV corruption: admission leaves requests queued,
    and decode growth preempts the newest active request — its blocks free
    up (prompt blocks stay parked in the prefix cache) and it is requeued
    for recompute with prompt+generated-so-far, which reproduces greedy
    output bit-exactly (chunked prefill is exact, DESIGN.md §3).

    ``cache_dtype=jnp.int8`` stores the pool quantized (DESIGN.md §6):
    int8 payloads plus per-(layer, block, kv-head) fp32 scale planes.
    Scatters quantize, reads dequantize (the fused kernel in VMEM, the
    gather during assembly), CoW copies carry the scales with the payload,
    and blocks freshly allocated off the free list / evicted have their
    scales host-reset to the "unset" sentinel before the next device write
    so recycled blocks can't inherit a stale quantization grid.

    ``fused`` selects the paged attention path for BOTH halves of the
    serving loop (DESIGN.md §3 fused paged decode, §7 fused paged prefill):
    ``True`` dispatches the fused Pallas kernels — block-table-indexed K/V
    loads straight from the pool, no HBM gather copy on decode steps and no
    dense window copy per prefill chunk — requires ``softmax_impl="exaq"``;
    ``False`` forces the gather-then-dispatch references; ``None``
    (default) keeps whatever ``cfg.quant.use_fused_kernel`` says. All
    paths share the global-grid EXAQ combine, so greedy outputs agree
    under the default qstate (asserted by the tier-1 suite). Caveat: the
    fused kernels fold the default-sigma clip as a compile-time constant —
    a *calibrated* per-layer ``qstate`` only takes effect on the gather
    paths, so keep ``fused=False`` when serving calibrated clips.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int,
        max_seq: int,
        block_size: int = 16,
        prefill_chunk: int = 32,
        num_blocks: int | None = None,
        qstate=None,
        eos_id: int | None = None,
        steps_per_sync: int = 8,
        cache_dtype=jnp.bfloat16,
        seed: int = 0,
        mesh=None,
        fused: bool | None = None,
    ):
        if fused is not None:
            if fused and cfg.quant.softmax_impl != "exaq":
                raise ValueError(
                    f"fused=True needs softmax_impl='exaq' (static clip/LUT folded into the "
                    f"kernel), got {cfg.quant.softmax_impl!r}"
                )
            cfg = cfg.with_quant(use_fused_kernel=fused)
        self._init_common(cfg, params, max_slots=max_slots, max_seq=max_seq, qstate=qstate,
                          eos_id=eos_id, steps_per_sync=steps_per_sync,
                          cache_dtype=cache_dtype, seed=seed)
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.blocks_per_table = -(-max_seq // block_size)
        if num_blocks is None:
            num_blocks = 1 + max_slots * self.blocks_per_table  # +1: reserved null block
        self.pool = BlockPool(num_blocks, block_size)
        self._tables = np.full((max_slots, self.blocks_per_table), NULL_BLOCK, np.int32)

        self._quantized = jnp.dtype(cache_dtype) == jnp.int8
        pool = self.model.init_block_pool(num_blocks, block_size, cache_dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding

            spec = shd.block_pool_spec(cfg, mesh)
            pool["k"] = jax.device_put(pool["k"], NamedSharding(mesh, spec))
            pool["v"] = jax.device_put(pool["v"], NamedSharding(mesh, spec))
            if self._quantized:
                sspec = shd.block_scale_spec(cfg, mesh)
                pool["k_scale"] = jax.device_put(pool["k_scale"], NamedSharding(mesh, sspec))
                pool["v_scale"] = jax.device_put(pool["v_scale"], NamedSharding(mesh, sspec))
        self._pool = pool

        self.stats.update(prompt_tokens=0, prefix_hit_tokens=0,
                          prefill_tokens=0, prefill_chunks=0, preemptions=0)
        self._preempt_carry: dict[int, list[int]] = {}
        # blocks handed out by the pool since the last device launch whose
        # scale planes must be reset to "unset" before anything writes them
        # (recycled/evicted blocks carry a stale grid otherwise) — int8 only.
        # A set: an id can be released (admission rollback, preemption) and
        # re-allocated before the flush, and a CoW fork destination must be
        # *removed* (its valid scales arrive with the copied payload)
        self._fresh_blocks: set[int] = set()

        self._jit_prefill_chunk = jax.jit(self._prefill_chunk_fn, donate_argnums=(1,))
        self._jit_copy_block = jax.jit(self._copy_block_fn, donate_argnums=(0,))
        self._jit_reset_scales = jax.jit(self._reset_scales_fn, donate_argnums=(0,))
        self._jit_chunk = jax.jit(self._paged_chunk_fn, static_argnames=("steps", "sampler"),
                                  donate_argnums=(1,))

    def _new_slot(self):
        return _PagedSlot()

    def _validate_request(self, prompt, max_new: int) -> None:
        super()._validate_request(prompt, max_new)
        worst = min(len(prompt) + max_new, self.max_seq)
        need = -(-worst // self.block_size)
        if need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request needs up to {need} blocks of {self.block_size} but the pool "
                f"has {self.pool.num_blocks - 1} usable blocks"
            )

    # ------------------------------------------------------------ jitted fns

    def _prefill_chunk_fn(self, params, pool, tokens, table, start, chunk_len, blk_t, off_t):
        return self.model.prefill_paged_chunk(
            params, tokens, pool, table, start, chunk_len, blk_t, off_t, self.qstate
        )

    def _copy_block_fn(self, pool, src, dst):
        """Copy-on-write device half: duplicate block ``src`` into ``dst``
        across all layers (the pool already moved the refcounts). For an int8
        pool the per-block scale planes travel with the payload — the fork
        must dequantize identically to the shared original (DESIGN.md §6)."""
        return {k: a.at[:, dst].set(a[:, src]) for k, a in pool.items()}

    def _reset_scales_fn(self, pool, ids):
        """Zero the scale planes of freshly allocated blocks: 0 is the
        "unset" sentinel the next scatter seeds from (DESIGN.md §6)."""
        pool = dict(pool)
        pool["k_scale"] = pool["k_scale"].at[:, ids].set(0.0)
        pool["v_scale"] = pool["v_scale"].at[:, ids].set(0.0)
        return pool

    def _paged_chunk_fn(self, params, pool, tables, tokens, lens, active, budget,
                        temperature, top_k, top_p, key, *, steps, sampler):
        def step_kv(tokens, pool, lens, active):
            return self.model.decode_step_paged(
                params, tokens, pool, tables, lens, active, self.qstate
            )

        return self._decode_scan(step_kv, pool, tokens, lens, active, budget,
                                 temperature, top_k, top_p, key, steps=steps, sampler=sampler)

    # -------------------------------------------------------------- block ops

    def _make_writable(self, slot: int, bi: int) -> None:
        """CoW: before appending into table entry ``bi``, fork a shared block
        (refcount > 1) and copy its payload; exclusive blocks append in place
        (appends land beyond the hashed token count — DESIGN.md §3)."""
        s = self._slots[slot]
        blk = s.table[bi]
        if self.pool.writable(blk):
            return
        new = self.pool.fork(blk)
        # the fork gets payload AND scales copied, so it must NOT be pending
        # a scale reset: fork() allocates internally and can hand back an id
        # that was _alloc_fresh'd and then released (rollback/preemption)
        # while still queued — flushing that id after this copy would zero
        # the fork's grid and corrupt its dequant
        self._fresh_blocks.discard(new)
        self._pool = self._jit_copy_block(
            self._pool, jnp.asarray(blk, jnp.int32), jnp.asarray(new, jnp.int32)
        )
        s.table[bi] = new
        self._tables[slot, bi] = new

    def _ensure_decode_blocks(self, slot: int, steps: int) -> None:
        """Pre-chunk allocation: positions [lens, lens+writes) must have
        writable blocks before the jitted chunk launches (tables are fixed
        for the whole chunk). ``writes`` is bounded by the slot's own budget
        so a nearly-finished slot never allocates blocks it cannot write;
        blocks over-allocated for an EOS mid-chunk are reclaimed at finish."""
        s = self._slots[slot]
        lens = int(self.kv_lens[slot])
        writes = min(steps, int(self._budget[slot]) + 1)  # +1: the finishing write
        last_pos = min(lens + writes, self.max_seq) - 1
        bi0 = lens // self.block_size
        if bi0 < len(s.table):
            self._make_writable(slot, bi0)
        need = last_pos // self.block_size + 1
        while len(s.table) < need:
            blk = self._alloc_fresh()
            self._tables[slot, len(s.table)] = blk
            s.table.append(blk)

    def _alloc_fresh(self) -> int:
        """Pool alloc that queues the block for a scale reset (int8 pools):
        a block off the free list or evicted from the LRU carries a stale
        quantization grid that must not seed the next write."""
        blk = self.pool.alloc()
        if self._quantized:
            self._fresh_blocks.add(blk)
        return blk

    def _flush_fresh_scales(self) -> None:
        """Reset the scale planes of blocks allocated since the last launch.
        Runs (bucketed, null-block padded — idempotent) before any jitted
        write so the first scatter into a recycled block seeds a fresh scale.
        Released-but-still-queued ids are harmless: a free block's scales
        may be zeroed; only fork destinations must escape (see
        ``_make_writable``)."""
        if not self._fresh_blocks:
            return
        fresh = sorted(self._fresh_blocks)
        self._fresh_blocks = set()
        n = _bucket(len(fresh), 8)
        ids = np.full((n,), NULL_BLOCK, np.int32)
        ids[: len(fresh)] = fresh
        self._pool = self._jit_reset_scales(self._pool, jnp.asarray(ids))

    def _preempt(self, slot: int) -> None:
        """Release a live slot's blocks under pool pressure and requeue the
        request for recompute: the continuation prompt is the original prompt
        plus everything generated so far, so prefilling it reproduces the
        decode state exactly (greedy continuation is bit-identical — chunked
        prefill is exact, DESIGN.md §3), and its prompt blocks usually hit
        the prefix cache the preempted slot just parked."""
        s = self._slots[slot]
        req = s.req
        done = list(s.generated)
        remaining = int(self._budget[slot])
        self._preempt_carry[req.uid] = self._preempt_carry.pop(req.uid, []) + done
        cont = Request(req.uid, req.prompt + tuple(done), remaining, req.sampling)
        for blk in s.table:
            self.pool.release(blk)
        self._tables[slot, :] = NULL_BLOCK
        self._slots[slot] = self._new_slot()
        self._active[slot] = False
        self.stats["preemptions"] += 1
        self._queue.appendleft(cont)  # continuation bypasses _validate_request:
        # its prompt may legitimately reach max_seq (finishes right after prefill)

    def _reserve_chunk_blocks(self, steps: int) -> None:
        """Ensure every active slot can write its share of the coming chunk.
        Exhaustion preempts the newest active slot (its blocks free up, its
        request recomputes later) instead of crashing the engine — honest
        back-pressure on undersized pools."""
        for i in np.argsort([self._slots[i].uid if self._active[i] else np.iinfo(np.int64).max
                             for i in range(self.max_slots)]):
            i = int(i)
            if not self._active[i]:
                continue
            while self._active[i]:
                try:
                    self._ensure_decode_blocks(i, steps)
                    break
                except PoolExhausted:
                    victims = [j for j in range(self.max_slots) if self._active[j]]
                    victim = max(victims, key=lambda j: self._slots[j].uid)
                    if victim == i and len(victims) == 1:
                        raise PoolExhausted(
                            f"cannot grow KV for the only active request (uid "
                            f"{self._slots[i].uid}): pool of {self.pool.num_blocks - 1} "
                            f"usable blocks is too small for max_seq {self.max_seq}"
                        ) from None
                    self._preempt(victim)

    # ------------------------------------------------------------- scheduling

    def _admit(self) -> int:
        """Match prefix hashes, retain hits, allocate the rest of the prompt's
        blocks, and park the slot in chunked-prefill state. Pool exhaustion
        rolls the request back into the queue (back-pressure)."""
        admitted = 0
        free = self._free_slots()
        while free and self._queue:
            req = self._queue[0]
            hashes = chain_hashes(req.prompt, self.block_size)
            table, cached = [], 0
            for h, n in hashes:
                blk = self.pool.lookup(h)
                if blk is None:
                    break
                table.append(blk)
                cached += n
            # always re-prefill at least the last prompt token: sampling needs
            # its logits (a fully-cached prompt has KV but no logits)
            cached = min(cached, len(req.prompt) - 1)
            try:
                while len(table) < len(hashes):
                    table.append(self._alloc_fresh())
            except PoolExhausted:
                for b in table:
                    self.pool.release(b)
                break
            self._queue.popleft()
            slot = free.pop(0)
            s = self._slots[slot]
            s.uid, s.req, s.table, s.hashes = req.uid, req, table, hashes
            s.filled = s.cached = cached
            s._prefilling = True
            self._tables[slot, :] = NULL_BLOCK
            self._tables[slot, : len(table)] = table
            self.stats["prompt_tokens"] += len(req.prompt)
            self.stats["prefix_hit_tokens"] += cached
            admitted += 1
        return admitted

    def _prefill_step(self, slot: int) -> None:
        """Advance one ``prefill_chunk``-token chunk for a prefilling slot;
        on prompt completion, sample the first token and activate."""
        s = self._slots[slot]
        req = s.req
        L = len(req.prompt)
        bs = self.block_size
        n = min(self.prefill_chunk, L - s.filled)
        start = s.filled
        for bi in range(start // bs, (start + n - 1) // bs + 1):
            self._make_writable(slot, bi)
        C = self.prefill_chunk
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = req.prompt[start : start + n]
        blk_t = np.full((C,), NULL_BLOCK, np.int32)
        off_t = np.arange(C, dtype=np.int32) % bs  # spread padded-row writes in the null block
        for i in range(n):
            pos = start + i
            blk_t[i] = s.table[pos // bs]
            off_t[i] = pos % bs
        self._flush_fresh_scales()
        logits, self._pool = self._jit_prefill_chunk(
            self.params, self._pool, jnp.asarray(toks),
            jnp.asarray(self._tables[slot]), jnp.asarray(start, jnp.int32),
            jnp.asarray(n, jnp.int32), jnp.asarray(blk_t), jnp.asarray(off_t),
        )
        s.filled += n
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += n
        # publish blocks whose hashed tokens are now fully materialized
        for bi, (h, ntok) in enumerate(s.hashes):
            if bi * bs + ntok <= s.filled:
                self.pool.register(h, s.table[bi])
        if s.filled == L:
            s._prefilling = False
            self.stats["prefills"] += 1
            self._sample_first(slot, req, logits)
            # a preempted-at-the-brink continuation can legally have
            # len(prompt) == max_seq: its first sampled token is also its
            # last (no cache room to decode further)
            if self._active[slot] and int(self.kv_lens[slot]) >= self.max_seq:
                self._finish(slot, "length")

    def _finish(self, slot: int, reason: str):
        s = self._slots[slot]
        for blk in s.table:
            self.pool.release(blk)
        self._tables[slot, :] = NULL_BLOCK
        carry = self._preempt_carry.pop(s.uid, None)
        super()._finish(slot, reason)
        if carry:  # tokens generated before a preemption lead the final answer
            g = self._results[s.uid]
            self._results[s.uid] = Generation(g.uid, carry + g.tokens, g.finish_reason)

    def step_chunk(self, steps: int | None = None) -> int:
        """Admit; advance one prefill chunk per prefilling slot; run one
        jitted decode chunk over the active slots. Returns #tokens emitted."""
        self._admit()
        for i, s in enumerate(self._slots):
            if not s.free and s.prefilling:
                self._prefill_step(i)
        if self.num_active == 0:
            return 0
        steps = self._clamp_steps(steps)
        self._reserve_chunk_blocks(steps)  # may preempt slots under pool pressure
        if self.num_active == 0:
            return 0
        self._flush_fresh_scales()
        t0 = time.perf_counter()
        out = self._jit_chunk(
            self.params, self._pool, jnp.asarray(self._tables),
            jnp.asarray(self._tokens), jnp.asarray(self.kv_lens),
            jnp.asarray(self._active), jnp.asarray(self._budget),
            jnp.asarray(self._temperature), jnp.asarray(self._top_k),
            jnp.asarray(self._top_p), self._key, steps=steps, sampler=self._pick_sampler(),
        )
        pool, tokens, lens, active, budget, self._key, emitted, masks = out
        jax.block_until_ready(emitted)
        self.stats["decode_time"] += time.perf_counter() - t0
        self._pool = pool
        was_active = self._active
        return self._absorb_chunk(tokens, lens, active, budget, emitted, masks, was_active)

    # -------------------------------------------------------------- telemetry

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of submitted prompt tokens served from the prefix cache."""
        return self.stats["prefix_hit_tokens"] / max(self.stats["prompt_tokens"], 1)

    @property
    def kv_pool_bytes(self) -> int:
        """Device bytes of the whole pool (int8: payloads + scale planes)."""
        return sum(a.nbytes for a in self._pool.values())

    @property
    def live_kv_tokens(self) -> int:
        """Tokens of KV currently materialized for unfinished requests."""
        total = 0
        for i, s in enumerate(self._slots):
            if s.free:
                continue
            total += s.filled if s.prefilling else int(self.kv_lens[i])
        return total
