"""Continuous-batching decode engines: slot-ragged and block-paged KV caches.

The serving problem EXAQ targets (paper §4: attention-heavy decode) is only
won at the *runtime* level: many concurrent requests of different lengths
must share one jitted step, or the kernel savings drown in per-request
dispatch and padding waste (cf. QUIK/SoftmAP — low-bit inference pays off
when the surrounding runtime is batched and fused).

This module is the glue layer of the host/device split (DESIGN.md §9):

  * ``runtime/engine_core.py`` — every scheduling decision (slot table,
    BlockPool allocator, prefix cache, admission, preempt-and-recompute) as
    plain Python + numpy; imports no jax.
  * ``runtime/device_step.py`` — every jitted function, operating on an
    explicitly mesh-sharded cache/pool pytree.
  * here — ``Engine`` and ``PagedEngine`` wire core plans into device calls
    and absorb device results back into core state, and
    ``DataParallelEngine`` runs independent paged replicas over disjoint
    device subsets behind one shared admission queue.

``Engine`` — slot cache (PR 1 baseline, kept as the parity oracle):

  * Slot cache   — fixed (L, max_slots, KV, max_seq, Dh) K/V buffers plus a
                   per-slot ``kv_lens`` vector. Shapes never change, so the
                   decode step compiles exactly once; raggedness lives in the
                   lengths, and ``attention_decode_ragged`` masks/writes per
                   slot (DESIGN.md §Serving).
  * Scheduler    — requests queue up host-side; free slots are filled by a
                   bucketed single-request prefill (padded to a power-of-two
                   length; the true length picks the logits row), finished
                   slots (EOS / token budget / max_seq) are evicted and
                   immediately refilled.
  * Decode chunk — ``steps_per_sync`` decode steps run inside one jitted
                   ``lax.scan``; every step batches ALL active slots through
                   one ragged attention dispatch per layer and one batched
                   sampling dispatch (greedy / temperature / top-k / top-p
                   with per-slot params — runtime/sampling.py).

``PagedEngine`` — block-paged cache (DESIGN.md §3): the slot engine's memory
model scales as ``max_slots x max_seq`` regardless of live lengths and
re-prefills identical prefixes per request. The paged engine replaces the
rectangular buffers with a global block pool (``runtime/kv_pool.py``) plus
per-request block tables:

  * Block pool    — K/V live in (L, num_blocks, KV, block_size, Dh); a slot's
                    cache is the blocks its table names, so memory tracks the
                    sum of live lengths, not slots x max_seq.
  * Prefix reuse  — prompt blocks are published under a rolling chain hash;
                    later requests sharing a prefix retain the cached blocks
                    (refcounted, copy-on-write on append) and skip their
                    prefill entirely.
  * Chunked prefill — prompts prefill in fixed-size chunks interleaved with
                    decode chunks, so a long prompt never stalls the running
                    batch; chunking is bit-exact vs one-shot prefill because
                    the EXAQ histogram combine composes across partitions
                    (DESIGN.md §2/§3).

Families: dense / moe (token-only attention decoders) on both engines, and —
paged only — ssm / hybrid through the architecture-agnostic StatePool
(DESIGN.md §13): the pool pytree carries whatever per-layer plane groups the
model config declares (attention K/V blocks, Mamba2 conv-tail + SSM-state
planes checkpointed per block), the host scheduler treats blocks as blocks,
and MoE routing batches across live slots inside the jitted decode scan.
Audio caches aren't slot-ragged or block-paged; vlm decode would work (its
KV cache is regular) but the engines' prefill builds token-only batches —
admitting vlm needs per-request ``vision_embeds`` plumbing first.
``runtime.serve.generate`` keeps the rectangular loop for those.
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.kv_codec import kv_cache_is_quantized
from repro.runtime import sampling as smp
from repro.runtime.device_step import PagedDeviceStep, SlotDeviceStep
from repro.runtime.engine_core import (
    EngineConfig,
    EngineCore,
    Generation,
    HostCore,
    Request,
    _bucket,
    _PagedSlot,
    _Slot,
)
from repro.runtime.kv_pool import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    PoolStats,
    chain_hashes,
)
from repro.runtime.speculative import NgramDrafter, make_drafter

__all__ = [
    "DataParallelEngine",
    "Engine",
    "EngineConfig",
    "Generation",
    "PagedEngine",
    "Request",
    "resolve_kv_dtype",
]

# re-exported for existing importers; the host halves live in engine_core
_ = (BlockPool, PoolExhausted, chain_hashes, NULL_BLOCK, _Slot, _PagedSlot)

# families whose paged pool carries recurrent-state planes (conv tails + SSM
# heads) checkpointed at block granularity instead of / alongside KV blocks
# (DESIGN.md §13)
STATE_FAMILIES = ("ssm", "hybrid")

# "int4" has no jnp dtype: the string sentinel travels down to the pool
# builder as-is (payload dtype uint8 — DESIGN.md §10). ``runtime.serve``
# re-exports this map for its flag parsing.
KV_DTYPES = {
    "fp32": jnp.float32,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
    "int4": "int4",
}


def resolve_kv_dtype(name: str):
    """EngineConfig's string ``kv_dtype`` -> device cache dtype (or the
    "int4" string sentinel)."""
    try:
        return KV_DTYPES[name]
    except KeyError:
        raise ValueError(
            f"kv_dtype must be one of {sorted(KV_DTYPES)}, got {name!r}"
        ) from None


def kv_dtype_name(cache_dtype) -> str:
    """Reverse of ``resolve_kv_dtype`` for the legacy ``cache_dtype=`` shims:
    a device dtype (or the "int4" sentinel) -> the EngineConfig string key."""
    if isinstance(cache_dtype, str):
        if cache_dtype in KV_DTYPES:
            return cache_dtype
        raise ValueError(f"unknown cache dtype sentinel {cache_dtype!r}")
    d = jnp.dtype(cache_dtype)
    for name, dt in KV_DTYPES.items():
        if not isinstance(dt, str) and jnp.dtype(dt) == d:
            return name
    raise ValueError(f"unsupported KV cache dtype {cache_dtype!r}")


def _validate_engine_cfg(cfg, cache_dtype, *, paged: bool) -> None:
    if cfg.frontend is not None:
        raise ValueError(
            f"Engine supports token-only decoders, got frontend={cfg.frontend!r} "
            "(frontend models need per-request embeds at prefill)"
        )
    quantized = kv_cache_is_quantized(cache_dtype)
    if cfg.family in STATE_FAMILIES:
        if not paged:
            raise ValueError(
                f"family={cfg.family!r} serves through the paged StatePool "
                "(recurrent state checkpointed per block — DESIGN.md §13); the slot "
                "engine's rectangular cache has no state planes — use PagedEngine"
            )
        if quantized:
            raise ValueError(
                "int8/int4 pools are attention-only (per-block scales — DESIGN.md "
                f"§6/§10); family={cfg.family!r} state planes must stay full-precision"
            )
        if cfg.ssm_chunk != 1:
            raise ValueError(
                f"paged state serving needs ssm_chunk=1 (got {cfg.ssm_chunk}): the "
                "chunked SSD scan reassociates the recurrence, so block-granular "
                "checkpoints would not reproduce rectangular prefill bit-exactly "
                "(DESIGN.md §13) — rebuild the config with ssm_chunk=1"
            )
    elif cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"Engine supports dense/moe attention decoders and paged ssm/hybrid "
            f"state decoders, got family={cfg.family!r} (audio caches aren't "
            "slot-ragged or block-paged)"
        )
    if quantized and not paged:
        raise ValueError(
            "int8/int4 KV are paged-pool storage formats (per-block scales — DESIGN.md "
            "§6/§10); the slot engine's rectangular cache supports fp dtypes only"
        )


_LEGACY_ENGINE_KEYS = frozenset({
    "max_slots", "max_seq", "block_size", "prefill_chunk", "num_blocks",
    "eos_id", "steps_per_sync", "cache_dtype", "seed", "fused",
    "max_inflight", "admit_watermark", "spec_k", "drafter",
})


def _resolve_config(config: EngineConfig | None, legacy_kw: dict, *, cls: str) -> EngineConfig:
    """One construction surface, two spellings: either ``config=EngineConfig``
    (canonical) or the legacy per-field kwargs (deprecated shim). Mixing them
    is an error — a config is a complete statement of the engine shape, and
    silently overriding fields would make two call sites disagree about what
    was served."""
    if config is not None:
        if legacy_kw:
            raise TypeError(
                f"{cls}: pass either config=EngineConfig(...) or the legacy "
                f"per-field kwargs, not both (got {sorted(legacy_kw)})"
            )
        if not isinstance(config, EngineConfig):
            raise TypeError(f"{cls}: config must be an EngineConfig, got {type(config).__name__}")
        return config
    unknown = set(legacy_kw) - _LEGACY_ENGINE_KEYS
    if unknown:
        raise TypeError(f"{cls}: unexpected keyword arguments {sorted(unknown)}")
    if "max_slots" not in legacy_kw or "max_seq" not in legacy_kw:
        raise TypeError(f"{cls}: pass config=EngineConfig(...) (or legacy max_slots=/max_seq=)")
    warnings.warn(
        f"{cls}(max_slots=..., max_seq=..., ...) per-field construction is "
        "deprecated; build an EngineConfig and pass it as the config argument",
        DeprecationWarning, stacklevel=3,
    )
    kw = dict(legacy_kw)
    if "cache_dtype" in kw:
        kw["kv_dtype"] = kv_dtype_name(kw.pop("cache_dtype"))
    return EngineConfig(**kw)


class Engine(HostCore):
    """Continuous-batching serving engine for one model + qstate.

    Typical use::

        eng = Engine(cfg, params, EngineConfig(max_slots=8, max_seq=512, eos_id=2))
        eng.submit(Request([1, 5, 7], max_new=32))
        eng.submit([9, 9], max_new=16, sampling=SamplingParams(temperature=0.8))
        results = eng.run()          # {uid: Generation}

    or incrementally (arrival-driven traces): ``submit`` whenever requests
    arrive, ``step_chunk()`` to advance ``steps_per_sync`` decode steps.
    ``EngineConfig`` is the canonical construction surface; the legacy
    per-field kwargs (``max_slots=..., cache_dtype=...``) survive as a
    deprecated shim.
    """

    def __init__(
        self,
        cfg,
        params,
        config: EngineConfig | None = None,
        *,
        qstate=None,
        mesh=None,
        clock=None,
        **legacy_kw,
    ):
        config = _resolve_config(config, legacy_kw, cls=type(self).__name__)
        cache_dtype = resolve_kv_dtype(config.kv_dtype)
        _validate_engine_cfg(cfg, cache_dtype, paged=isinstance(self, PagedEngine))
        HostCore.__init__(self, max_slots=config.max_slots, max_seq=config.max_seq,
                          eos_id=config.eos_id, steps_per_sync=config.steps_per_sync,
                          clock=clock, max_inflight=config.max_inflight)
        self.config = config
        self._dev = SlotDeviceStep(
            cfg, params, qstate=qstate, max_slots=config.max_slots,
            max_seq=config.max_seq, eos_id=config.eos_id,
            cache_dtype=cache_dtype, mesh=mesh,
        )
        self._bind_device_step()
        self._key = jax.random.PRNGKey(config.seed)
        self._cache_k, self._cache_v = self._dev.init_cache()

    def _bind_device_step(self):
        """Expose the device step's resolved objects under the engine's
        long-standing attribute names (params is the *placed* copy)."""
        self.cfg = self._dev.cfg
        self.params = self._dev.params
        self.model = self._dev.model
        self.qstate = self._dev.qstate
        self.cache_dtype = self._dev.cache_dtype

    def submit(self, prompt, max_new: int | None = None,
               sampling: smp.SamplingParams = smp.GREEDY, *,
               priority: int = 0, deadline: float | None = None) -> int:
        """Submit a ``Request`` (canonical) or the legacy kwarg spread."""
        return super().submit(prompt, max_new, sampling, priority=priority, deadline=deadline)

    def _sample_first(self, slot: int, req: Request, logits) -> None:
        """Sample the first generated token from prefill logits (device) and
        hand the host transition to the core."""
        self._key, sub = jax.random.split(self._key)
        first = self._dev.sample_first(logits, req.sampling, sub)
        self._complete_first(slot, req, first)

    # ------------------------------------------------------------- scheduling

    def _admit(self) -> int:
        """Prefill queued requests into free slots; returns #admitted."""
        admitted = 0
        free = self._free_slots()
        while free and self._queue:
            req = self._queue.popleft()
            slot = free.pop(0)
            P = min(_bucket(len(req.prompt)), self.max_seq)
            padded = np.zeros((1, P), np.int32)
            padded[0, : len(req.prompt)] = req.prompt
            logits, ks, vs = self._dev.prefill(padded, [len(req.prompt)])
            self._cache_k, self._cache_v = self._dev.insert(
                self._cache_k, self._cache_v, ks, vs, slot
            )
            self.stats["prefills"] += 1
            self._sample_first(slot, req, logits)
            admitted += 1
        return admitted

    def step_chunk(self, steps: int | None = None) -> int:
        """Admit + run one jitted decode chunk; returns #tokens emitted."""
        self._admit()
        if self.num_active == 0:
            return 0
        steps = self._clamp_steps(steps)
        t0 = time.perf_counter()
        out = self._dev.decode_chunk(
            {"k": self._cache_k, "v": self._cache_v},
            self._tokens, self.kv_lens, self._active, self._budget,
            self._temperature, self._top_k, self._top_p, self._key,
            steps=steps, sampler=self._pick_sampler(),
        )
        kv, tokens, lens, active, budget, self._key, emitted, masks = out
        jax.block_until_ready(emitted)
        self.stats["decode_time"] += time.perf_counter() - t0
        self._cache_k, self._cache_v = kv["k"], kv["v"]
        was_active = self._active
        return self._absorb_chunk(tokens, lens, active, budget, emitted, masks, was_active)


# ===================================================================== paged


class PagedEngine(EngineCore, Engine):
    """Continuous batching over a block-paged KV cache (DESIGN.md §3).

    Same public surface as ``Engine`` (submit / step_chunk / run), same
    sampling and finish semantics, different memory model:

      * KV lives in a global pool of ``num_blocks`` blocks of ``block_size``
        tokens; each slot's cache is the blocks its table names
        (``runtime/kv_pool.BlockPool`` owns ids, refcounts, the prefix index
        and CoW adjudication — the core queues the device copies, the device
        step performs them).
      * Admission matches the prompt's rolling block hashes against the
        prefix index; hits retain cached blocks and skip their prefill. At
        least the prompt's last token is always re-prefilled so sampling has
        logits.
      * Remaining prompt tokens prefill in ``prefill_chunk``-token chunks —
        one chunk per prefilling slot per ``step_chunk``, *interleaved* with
        decode chunks for the already-active slots, so a long prompt never
        stalls the running batch.
      * Greedy outputs are bit-exact vs the slot engine on the same trace:
        chunking and paging both compose under the EXAQ histogram combine
        (§2), and the benchmark asserts it (benchmarks/bench_serving.py).

    ``num_blocks`` defaults to full provisioning (every slot can reach
    ``max_seq``), which makes pool exhaustion impossible; smaller pools are
    allowed (prefix sharing usually covers the gap) and exhaustion becomes
    back-pressure, never KV corruption: admission leaves requests queued,
    and decode growth preempts the newest active request — its blocks free
    up (prompt blocks stay parked in the prefix cache) and it is requeued
    for recompute with prompt+generated-so-far, which reproduces greedy
    output bit-exactly (chunked prefill is exact, DESIGN.md §3).

    ``cache_dtype=jnp.int8`` stores the pool quantized (DESIGN.md §6):
    int8 payloads plus per-(layer, block, kv-head) fp32 scale planes.
    Scatters quantize, reads dequantize (the fused kernel in VMEM, the
    gather during assembly), CoW copies carry the scales with the payload,
    and blocks freshly allocated off the free list / evicted have their
    scales host-reset to the "unset" sentinel before the next device write
    so recycled blocks can't inherit a stale quantization grid.

    ``cache_dtype="int4"`` (string sentinel — int4 has no jnp dtype) packs
    the pool two values per uint8 byte (DESIGN.md §10): the int8 machinery
    above plus per-(layer, block, kv-head, sub-block) 4-bit scale codes in
    "k_sub"/"v_sub" planes, reset alongside the block scales on recycle.
    Both fused kernels unpack the nibbles in VMEM after the block-table DMA
    — no dense dequantized copy in HBM.

    ``fused`` selects the paged attention path for BOTH halves of the
    serving loop (DESIGN.md §3 fused paged decode, §7 fused paged prefill):
    ``True`` dispatches the fused Pallas kernels — block-table-indexed K/V
    loads straight from the pool, no HBM gather copy on decode steps and no
    dense window copy per prefill chunk — requires ``softmax_impl="exaq"``;
    ``False`` forces the gather-then-dispatch references; ``None``
    (default) keeps whatever ``cfg.quant.use_fused_kernel`` says. All
    paths share the global-grid EXAQ combine, so greedy outputs agree
    under the default qstate (asserted by the tier-1 suite). Caveat: the
    fused kernels fold the default-sigma clip as a compile-time constant —
    a *calibrated* per-layer ``qstate`` only takes effect on the gather
    paths, so keep ``fused=False`` when serving calibrated clips.

    ``mesh`` shards the pool tensor-parallel (DESIGN.md §9): the kv-head dim
    of payloads and scale planes partitions over the mesh's 'model' axis
    when divisible (``block_pool_spec``/``block_scale_spec``; non-divisible
    head counts fall back to a replicated pool), block tables stay
    replicated, and the fused kernels run under shard_map with each shard
    DMAing only its local heads (kernels/ops.py). Params stay replicated so
    greedy decode is bit-exact against a single-shard run.
    """

    def __init__(
        self,
        cfg,
        params,
        config: EngineConfig | None = None,
        *,
        qstate=None,
        mesh=None,
        clock=None,
        **legacy_kw,
    ):
        config = _resolve_config(config, legacy_kw, cls="PagedEngine")
        cache_dtype = resolve_kv_dtype(config.kv_dtype)
        if config.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {config.spec_k}")
        if config.fused is not None:
            if config.fused and cfg.quant.softmax_impl != "exaq":
                raise ValueError(
                    f"fused=True needs softmax_impl='exaq' (static clip/LUT folded into the "
                    f"kernel), got {cfg.quant.softmax_impl!r}"
                )
            cfg = cfg.with_quant(use_fused_kernel=config.fused)
        _validate_engine_cfg(cfg, cache_dtype, paged=True)
        state_blocks = cfg.family in STATE_FAMILIES
        if state_blocks:
            if config.spec_k > 0:
                raise ValueError(
                    "speculative decoding needs CoW read-forks of the KV tail; state "
                    "planes checkpoint only at block boundaries, so spec_k must be 0 "
                    f"for family={cfg.family!r} (DESIGN.md §13)"
                )
            if config.prefill_chunk % config.block_size != 0:
                raise ValueError(
                    "state-pool prefill checkpoints at block boundaries inside each "
                    f"chunk: prefill_chunk ({config.prefill_chunk}) must be a multiple "
                    f"of block_size ({config.block_size}) (DESIGN.md §13)"
                )
        EngineCore.__init__(self, clock=clock, state_blocks=state_blocks,
                            **config.core_kwargs())
        self.config = config
        self._dev = PagedDeviceStep(
            cfg, params, qstate=qstate, num_blocks=self.num_blocks,
            block_size=config.block_size, max_seq=config.max_seq,
            eos_id=config.eos_id, cache_dtype=cache_dtype, mesh=mesh,
        )
        self._bind_device_step()
        self._key = jax.random.PRNGKey(config.seed)
        self._pool = self._dev.init_pool()
        # raw jitted (pool, src, dst) -> pool CoW copy; tests drive it directly
        self._jit_copy_block = self._dev.copy_block
        # speculative decoding (DESIGN.md §12): spec_k > 0 replaces decode
        # chunks with per-slot draft/verify rounds; drafter may be a name
        # from the registry ("ngram"), a Drafter instance, or None (ngram)
        self.spec_k = config.spec_k
        drafter = config.drafter
        if isinstance(drafter, str):
            drafter = make_drafter(drafter)
        if self.spec_k > 0 and drafter is None:
            drafter = NgramDrafter()
        self.drafter = drafter

    def submit(self, prompt, max_new: int | None = None,
               sampling: smp.SamplingParams = smp.GREEDY, *,
               priority: int = 0, deadline: float | None = None) -> int:
        samp = prompt.sampling if isinstance(prompt, Request) else sampling
        if self.spec_k > 0 and samp.temperature > 0:
            raise ValueError(
                "speculative decoding (spec_k > 0) is greedy-only: the accept rule "
                "compares exact argmaxes (DESIGN.md §12); submit with temperature=0"
            )
        return super().submit(prompt, max_new, sampling, priority=priority,
                              deadline=deadline)

    # -------------------------------------------------------------- block ops

    def _make_writable(self, slot: int, bi: int) -> None:
        """Core CoW adjudication + immediate device copy: the engine drains
        the copy queue as soon as it is planned, so the pool state callers
        observe (tests, telemetry) is never behind the host tables."""
        EngineCore._make_writable(self, slot, bi)
        self._drain_copies()

    def _drain_copies(self) -> None:
        copies = self.take_pending_copies()
        if copies:
            self._pool = self._dev.copy_blocks(self._pool, copies)

    def _flush_fresh_scales(self) -> None:
        """Reset the scale planes of blocks allocated since the last launch.
        Runs (bucketed, null-block padded — idempotent) before any jitted
        write so the first scatter into a recycled block seeds a fresh scale.
        Released-but-still-queued ids are harmless: a free block's scales
        may be zeroed; only fork destinations must escape (see
        ``EngineCore._make_writable``)."""
        fresh = self.take_fresh_scale_ids()
        if not fresh:
            return
        n = _bucket(len(fresh), 8)
        ids = np.full((n,), NULL_BLOCK, np.int32)
        ids[: len(fresh)] = fresh
        self._pool = self._dev.reset_fresh_scales(self._pool, ids)

    # ------------------------------------------------------------- scheduling

    def _prefill_step(self, slot: int) -> None:
        """Advance one ``prefill_chunk``-token chunk for a prefilling slot;
        on prompt completion, sample the first token and activate."""
        req = self._slots[slot].req
        plan = self.plan_prefill_chunk(slot)
        self._flush_fresh_scales()
        logits, self._pool = self._dev.prefill_chunk(
            self._pool, plan.tokens, self._tables[slot], plan.start, plan.n,
            plan.blk_t, plan.off_t,
        )
        if self.commit_prefill_chunk(slot, plan.n):
            self._sample_first(slot, req, logits)
            # a preempted-at-the-brink continuation can legally have
            # len(prompt) == max_seq: its first sampled token is also its
            # last (no cache room to decode further)
            if self._active[slot] and int(self.kv_lens[slot]) >= self.max_seq:
                self._finish(slot, "length")

    def step_chunk(self, steps: int | None = None) -> int:
        """Admit; advance one prefill chunk per prefilling slot; run one
        jitted decode chunk over the active slots. Returns #tokens emitted."""
        self._admit()
        for i, s in enumerate(self._slots):
            if not s.free and s.prefilling:
                self._prefill_step(i)
        if self.num_active == 0:
            return 0
        if self.spec_k > 0:
            return self._spec_chunk()
        steps = self._clamp_steps(steps)
        self._reserve_chunk_blocks(steps)  # may preempt slots under pool pressure
        if self.num_active == 0:
            return 0
        self._flush_fresh_scales()
        t0 = time.perf_counter()
        out = self._dev.decode_chunk(
            self._pool, self._tables, self._tokens, self.kv_lens, self._active,
            self._budget, self._temperature, self._top_k, self._top_p, self._key,
            steps=steps, sampler=self._pick_sampler(),
        )
        pool, tokens, lens, active, budget, self._key, emitted, masks = out
        jax.block_until_ready(emitted)
        self.stats["decode_time"] += time.perf_counter() - t0
        self._pool = pool
        was_active = self._active
        return self._absorb_chunk(tokens, lens, active, budget, emitted, masks, was_active)

    # ------------------------------------------------- speculative decoding

    def _spec_chunk(self) -> int:
        """One draft/verify round per active slot (DESIGN.md §12); replaces
        the decode chunk entirely when ``spec_k > 0``. Returns #tokens
        emitted. A slot deactivated mid-chunk (finished, or preempted by a
        sibling's pool-pressure retry) is skipped."""
        n_out = 0
        for slot in range(self.max_slots):
            if self._active[slot]:
                n_out += self._spec_round(slot)
        return n_out

    def _spec_round(self, slot: int) -> int:
        """Draft k tokens, fork a branch, verify the whole window in one
        fused paged-prefill call, accept/reject, absorb. Pool exhaustion
        first retries draft-free (k=0 needs at most one block — the round
        degrades to vanilla single-token decode), then preempts the least
        urgent *other* slot; a sole slot that cannot even grow by one block
        raises non-retryable, mirroring ``_reserve_chunk_blocks``."""
        s = self._slots[slot]
        L = int(self.kv_lens[slot])
        k_eff = max(0, min(self.spec_k, int(self._budget[slot]) - 1,
                           self.max_seq - 1 - L))
        drafts: list[int] = []
        if k_eff > 0:
            drafts = list(self.drafter.propose(list(s.req.prompt) + list(s.generated),
                                               k_eff))[:k_eff]
        while True:
            try:
                plan = self.plan_spec_round(slot, drafts)
                break
            except PoolExhausted:
                if drafts:
                    drafts = []  # degrade to k=0 before evicting anyone
                    continue
                victims = [j for j in range(self.max_slots)
                           if self._active[j] and j != slot]
                if not victims:
                    raise PoolExhausted(
                        f"cannot grow KV for the only active request (uid "
                        f"{s.uid}): pool of {self.pool.num_blocks - 1} usable "
                        f"blocks is too small for max_seq {self.max_seq}",
                        retryable=False, occupancy=self.pool.occupancy(),
                    ) from None
                self._preempt(max(victims, key=self._victim_rank))
        # branch fork copies must land before verify reads the window, and
        # fresh-block scale resets before verify's first quantized scatter
        self._drain_copies()
        self._flush_fresh_scales()
        t0 = time.perf_counter()
        verified, self._pool = self._dev.verify_chunk(
            self._pool, plan.tokens, plan.table, plan.start, plan.blk_t, plan.off_t,
        )
        verified = np.asarray(jax.device_get(verified))
        self.stats["decode_time"] += time.perf_counter() - t0
        res = self.commit_spec_round(plan, verified)
        if self._dev.int4 and res.trim_tail:
            # rejected rows seeded immutable sub-block codes in the kept tail
            # block; zero every sub-block wholly past the committed rows so
            # the next (vanilla-equivalent) write re-seeds it (DESIGN.md §12)
            n_sub = self._pool["k_sub"].shape[-1]
            sub_bs = self.block_size // n_sub
            keep_subs = (res.tail_rows - 1) // sub_bs + 1
            if keep_subs < n_sub:
                self._pool = self._dev.trim_sub_scales(self._pool, res.tail_block,
                                                       keep_subs)
        return self.absorb_spec_round(slot, res.emitted)

    # -------------------------------------------------------------- telemetry

    @property
    def kv_pool_bytes(self) -> int:
        """Device bytes of the whole pool (int8: payloads + scale planes)."""
        return self._dev.pool_bytes(self._pool)

    @property
    def pool_stats(self) -> PoolStats:
        """Allocator counters; same accessor shape as ``DataParallelEngine``."""
        return self.pool.stats


# ================================================================ data parallel


class DataParallelEngine:
    """Independent ``PagedEngine`` replicas over the 'data' axis behind one
    shared admission queue (DESIGN.md §9).

    The block pool is deliberately *not* sharded over 'data' — prefix sharing
    only pays within one pool, so each replica owns a full engine (scheduler
    + pool + tables) on its own device subset (``launch.mesh.
    make_replica_meshes``), and data parallelism is pure request-level
    scaling: submissions land in a shared host queue and are dispatched to
    the least-loaded replica at each ``step_chunk``. Dispatch is
    deterministic (load, then replica index), and greedy decode is
    batch-composition-independent (per-slot attention is masked to the slot;
    sampling is argmax), so a DP fleet reproduces a single engine's greedy
    tokens bit-exactly — the parity suite asserts it.

    Public surface mirrors the single engine: ``submit`` / ``step_chunk`` /
    ``run`` / ``has_work`` plus aggregated telemetry (``stats``,
    ``prefix_hit_rate``, ``mean_occupancy``) and ``per_replica_stats`` for
    bench_serving's per-replica reporting.
    """

    def __init__(self, cfg, params, config: EngineConfig | None = None, *,
                 replicas: int | None = None, meshes=None, **engine_kw):
        if replicas is None:
            replicas = config.replicas if config is not None else 2
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if meshes is not None and len(meshes) != replicas:
            raise ValueError(f"got {len(meshes)} meshes for {replicas} replicas")
        meshes = meshes if meshes is not None else [None] * replicas
        self.engines = [PagedEngine(cfg, params, config, mesh=m, **engine_kw)
                        for m in meshes]
        self.config = self.engines[0].config
        self._pending: list[Request] = []
        self._route: dict[int, tuple[int, int]] = {}  # global uid -> (replica, local uid)
        self._next_uid = 0
        self._results: dict[int, Generation] = {}

    def submit(self, prompt, max_new: int | None = None,
               sampling: smp.SamplingParams = smp.GREEDY, *,
               priority: int = 0, deadline: float | None = None) -> int:
        """Submit a ``Request`` (canonical) or the legacy kwarg spread."""
        if isinstance(prompt, Request):
            if max_new is not None:
                raise ValueError("pass either a Request or (prompt, max_new), not both")
            req = prompt
        else:
            if max_new is None:
                raise ValueError("max_new is required when submitting a raw prompt")
            req = Request(prompt, max_new, sampling, int(priority), deadline)
        toks = tuple(int(t) for t in np.asarray(req.prompt).reshape(-1))
        # validate against replica 0 (all replicas are configured identically)
        self.engines[0]._validate_request(toks, req.max_new)
        uid = self._next_uid
        self._next_uid += 1
        self._pending.append(dataclasses.replace(req, prompt=toks, uid=uid))
        return uid

    def _dispatch(self) -> None:
        """Hand queued requests to replicas with admission capacity, least
        loaded first (live slots + local backlog; ties break on index)."""
        while self._pending:
            loads = [
                (len([s for s in e._slots if not s.free]) + e.num_queued, i)
                for i, e in enumerate(self.engines)
            ]
            load, i = min(loads)
            if load >= self.engines[i].max_slots:
                break  # every replica is saturated; keep the shared backlog
            req = self._pending.pop(0)
            local = self.engines[i].submit(dataclasses.replace(req, uid=-1))
            self._route[req.uid] = (i, local)

    def has_work(self) -> bool:
        return bool(self._pending) or any(e.has_work() for e in self.engines)

    def step_chunk(self, steps: int | None = None) -> int:
        self._dispatch()
        return sum(e.step_chunk(steps) for e in self.engines if e.has_work())

    def run(self) -> dict[int, Generation]:
        while self.has_work():
            self.step_chunk()
        out = {}
        for uid, (i, local) in self._route.items():
            g = self.engines[i]._results.pop(local, None)
            if g is None:
                g = self._results.pop(uid, None)
            if g is not None:
                out[uid] = Generation(uid, g.tokens, g.finish_reason)
        self._route = {uid: r for uid, r in self._route.items() if uid not in out}
        return out

    # -------------------------------------------------------------- telemetry

    @property
    def num_active(self) -> int:
        return sum(e.num_active for e in self.engines)

    @property
    def num_queued(self) -> int:
        return len(self._pending) + sum(e.num_queued for e in self.engines)

    @property
    def per_replica_stats(self) -> list[dict]:
        out = []
        for e in self.engines:
            s = dict(e.stats)
            s["prefix_hit_rate"] = e.prefix_hit_rate
            s["mean_occupancy"] = e.mean_occupancy
            out.append(s)
        return out

    @property
    def stats(self) -> dict:
        """Replica stats summed (max_active is a max across replicas)."""
        agg: dict = {}
        for s in (e.stats for e in self.engines):
            for k, v in s.items():
                if k == "max_active":
                    agg[k] = max(agg.get(k, 0), v)
                else:
                    agg[k] = agg.get(k, 0) + v
        return agg

    @property
    def prefix_hit_rate(self) -> float:
        s = self.stats
        return s["prefix_hit_tokens"] / max(s["prompt_tokens"], 1)

    @property
    def mean_occupancy(self) -> float:
        """Mean live slots per decode step, summed over replicas (occupancy
        sums add; steps are the max so overlapping replicas don't divide
        each other's occupancy away)."""
        steps = max(max(e.stats["decode_steps"] for e in self.engines), 1)
        return sum(e.stats["occupancy_sum"] for e in self.engines) / steps

    @property
    def kv_pool_bytes(self) -> int:
        return sum(e.kv_pool_bytes for e in self.engines)

    @property
    def pool_stats(self) -> PoolStats:
        """Field-wise sum of every replica pool's allocator counters."""
        return PoolStats.merged([e.pool.stats for e in self.engines])
