"""Sharding rules: logical activation/param axes -> PartitionSpec.

Mesh axes (launch/mesh.py): ('pod', 'data', 'model') multi-pod or
('data', 'model') single-pod.

  * DP  — batch over ('pod', 'data')
  * TP  — heads / ffn / vocab over 'model'
  * EP  — MoE experts over 'model'
  * SP  — KV-cache sequence over 'model' when kv_heads don't divide TP
  * FSDP — in train mode, params/opt additionally sharded over 'data'
           (ZeRO-3 style; XLA inserts the all-gathers)

Models call ``shard_activation(x, name)`` — a no-op unless a mesh context is
active, so the same code runs single-device tests and 512-chip dry-runs.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict[str, Any] = {"mesh": None, "rules": {}}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def data_axis_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


@contextlib.contextmanager
def activation_rules(mesh: Mesh, rules: dict[str, P]):
    old = dict(_CTX)
    _CTX["mesh"], _CTX["rules"] = mesh, rules
    try:
        yield
    finally:
        _CTX.update(old)


def use_mesh(mesh: Mesh | None):
    """Activate ``mesh`` (no activation rules) for the duration of a call.

    The serving device layer wraps every jitted call in this so trace-time
    mesh discovery works: the shard_map dispatch around the fused paged
    kernels (kernels/ops.py) and ``shard_activation`` both read the ambient
    ``_CTX`` mesh while the function body is being traced. ``None`` is a
    no-op, so single-device engines pay nothing."""
    if mesh is None:
        return contextlib.nullcontext()
    return activation_rules(mesh, {})


def current_mesh() -> Mesh | None:
    """The ambient mesh (``activation_rules``/``use_mesh``), or None."""
    return _CTX["mesh"]


def shard_activation(x, name: str):
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = _CTX["rules"].get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def make_activation_rules(cfg, mesh: Mesh) -> dict[str, P]:
    dp = data_axes(mesh)
    tp = model_axis_size(mesh)
    rules = {
        "btd": P(dp, None, None),
        "btf": P(dp, None, "model"),
        "logits": P(dp, None, "model"),
    }
    if cfg.num_heads and _div(cfg.num_heads, tp):
        rules["heads"] = P(dp, "model", None, None)
    elif cfg.num_heads:
        # TP can't split the heads — shard attention q-block rows instead
        # (sequence-parallel attention; exact, softmax is row-wise)
        rules["qrows"] = P(dp, None, "model", None)
        rules["score_rows"] = P(dp, None, "model", None)
    if cfg.moe is not None and _div(cfg.moe.num_experts, tp):
        rules["experts"] = P("model", dp, None, None)  # (E, G, C, D)
    if cfg.ssm_state and _div(cfg.ssm_heads, tp):
        rules["ssm_heads"] = P(dp, None, "model", None)  # (B, S, nh, hd)
    return rules


# ------------------------------------------------------------- param specs

def validate_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axes don't divide evenly."""
    out = []
    for i, ax in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if _div(shape[i], size) else None)
    return P(*out)


def param_spec_fn(cfg, mesh: Mesh, mode: str = "train"):
    """Returns path->PartitionSpec for the param tree of ``build_model(cfg)``.

    Specs are tail-aligned (leading stacking dims — layer / group — are
    unsharded) and validated for divisibility, so the same rules cover flat,
    scan-stacked and doubly-stacked (hybrid) parameters.

    mode='train' adds FSDP sharding of the non-TP dim over 'data' (ZeRO-3;
    XLA inserts the all-gathers); mode='serve' keeps params replicated over
    data (bf16 fits; avoids per-token all-gathers on decode).
    """
    fsdp = "data" if (mode == "train" and "data" in mesh.axis_names) else None

    # tail specs: rightmost dims of the unstacked parameter
    TAIL: dict[str, tuple] = {
        # column parallel (in, out_tp)
        "wq": (fsdp, "model"), "wk": (fsdp, "model"), "wv": (fsdp, "model"), "wi": (fsdp, "model"),
        # row parallel (in_tp, out)
        "wo": ("model", fsdp),
        "router": (fsdp, None),
        "moe_wi": ("model", fsdp, None),   # (E, D, Fe): EP over experts
        "moe_wo": ("model", None, fsdp),   # (E, Fe, D)
        "in_proj": (fsdp, "model"),
        "out_proj": ("model", fsdp),
        "conv_w": (None, "model"),         # (w, channels_tp)
        "conv_b": ("model",),
        "A_log": ("model",), "D_skip": ("model",), "dt_bias": ("model",),
        "ssm_norm": ("model",),
        "tokens": ("model", fsdp),         # (V, D)
        "head": (fsdp, "model"),           # (D, V)
        "frontend_proj": (None, fsdp),
    }

    def spec(path: tuple[str, ...], leaf) -> P:
        name = path[-1]
        shape = leaf.shape
        tail = TAIL.get(name)
        if tail is None:
            return P()  # norms / biases / scalars: replicate
        pad = len(shape) - len(tail)
        if pad < 0:
            return P()
        full = (None,) * pad + tuple(tail)
        return validate_spec(P(*full), shape, mesh)

    return spec


def tree_shardings(tree, cfg, mesh: Mesh, mode: str = "train"):
    """NamedShardings matching ``tree`` (of arrays or ShapeDtypeStructs)."""
    fn = param_spec_fn(cfg, mesh, mode)

    def to_sharding(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path
        )
        return NamedSharding(mesh, fn(names, leaf))

    return jax.tree_util.tree_map_with_path(to_sharding, tree)


def batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(data_axes(mesh)))


def batch_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh))


def cache_spec(cfg, mesh: Mesh) -> P:
    """KV cache (L, B, KV, S, Dh): batch over data; kv-heads over 'model' when
    divisible, else sequence over 'model' (SP decode)."""
    tp = model_axis_size(mesh)
    dp = data_axes(mesh)
    if cfg.num_kv_heads and _div(cfg.num_kv_heads, tp):
        return P(None, dp, "model", None, None)
    return P(None, dp, None, "model", None)


def slot_cache_spec(cfg, mesh: Mesh) -> P:
    """Serving-engine slot cache (L, max_slots, KV, max_seq, Dh).

    The slot axis takes the batch position: requests land in slots, so DP
    shards *slots* over ('pod', 'data') — each data shard runs its own slice
    of the continuous batch. Within a shard the same TP policy as the
    rectangular cache applies: kv-heads over 'model' when divisible, else
    sequence over 'model' (SP decode; the EXAQ histogram combine composes the
    softmax across sequence shards — DESIGN.md §2/§Serving)."""
    tp = model_axis_size(mesh)
    dp = data_axes(mesh)
    if cfg.num_kv_heads and _div(cfg.num_kv_heads, tp):
        return P(None, dp, "model", None, None)
    return P(None, dp, None, "model", None)


def block_pool_spec(cfg, mesh: Mesh) -> P:
    """Paged-engine block pool (L, num_blocks, KV, block_size, Dh).

    Unlike the slot cache there is no batch-like axis to hand to DP: blocks
    are a *global* pool shared by every request (that sharing is the whole
    point — DESIGN.md §3), so the block axis stays unsharded and each data
    shard would run its own engine+pool instead. Within the pool the usual TP
    policy applies to kv-heads when divisible. The in-block sequence axis
    (block_size tokens) is too small to shard — sequence parallelism at the
    paged layer happens by *distributing whole blocks*, whose partial EXAQ
    histograms combine exactly (§2); that layout is future work and needs no
    new spec here."""
    tp = model_axis_size(mesh)
    if cfg.num_kv_heads and _div(cfg.num_kv_heads, tp):
        return P(None, None, "model", None, None)
    return P(None, None, None, None, None)


def block_scale_spec(cfg, mesh: Mesh) -> P:
    """Dequant scale planes of an int8 block pool, (L, num_blocks, KV)
    (DESIGN.md §6): same policy as ``block_pool_spec`` — the block axis is a
    global shared pool (unsharded); the kv-head axis follows the payload's
    'model' sharding when divisible so each TP shard holds exactly the
    scales of the heads it owns."""
    tp = model_axis_size(mesh)
    if cfg.num_kv_heads and _div(cfg.num_kv_heads, tp):
        return P(None, None, "model")
    return P(None, None, None)


def block_sub_scale_spec(cfg, mesh: Mesh) -> P:
    """Sub-block scale-code planes of a packed int4 pool,
    (L, num_blocks, KV, n_sub) (DESIGN.md §10): ``block_scale_spec`` with a
    trailing unsharded sub-block axis — the kv-head axis follows the
    payload's 'model' sharding when divisible so each TP shard holds exactly
    the sub codes of the heads it owns."""
    tp = model_axis_size(mesh)
    if cfg.num_kv_heads and _div(cfg.num_kv_heads, tp):
        return P(None, None, "model", None)
    return P(None, None, None, None)


def state_pool_specs(cfg, mesh: Mesh) -> dict[str, P]:
    """Paged state-pool planes (DESIGN.md §13): "conv" (L, N, w-1, ch) and
    "ssm" (L, N, nh, hd, ds). Like ``block_pool_spec``, the block axis is a
    *global* pool shared by every request, so it stays unsharded; conv
    channels / ssm heads go over 'model' when divisible, matching the
    activation sharding of the mamba stack (``make_activation_rules``)."""
    tp = model_axis_size(mesh)
    ch_ax = "model" if _div(cfg.d_inner + 2 * cfg.ssm_state, tp) else None
    heads_ax = "model" if _div(cfg.ssm_heads, tp) else None
    return {
        "conv": P(None, None, None, ch_ax),
        "ssm": P(None, None, heads_ax, None, None),
    }


def ssm_cache_specs(cfg, mesh: Mesh) -> dict[str, P]:
    dp = data_axes(mesh)
    tp = model_axis_size(mesh)
    heads_ax = "model" if _div(cfg.ssm_heads, tp) else None
    return {
        "conv": P(None, dp, None, "model" if _div(cfg.d_inner + 2 * cfg.ssm_state, tp) else None),
        "ssm": P(None, dp, heads_ax, None, None),
    }
