"""Jitted device steps for the serving engines: the other half of the
host/device split (DESIGN.md §9).

``runtime/engine_core.py`` makes every scheduling decision with plain Python
ints; this module owns everything that touches a jax array: cache/pool
construction (placed onto a mesh with the specs from ``runtime/sharding.py``),
the jitted prefill/decode/scatter functions, the CoW block copy, the int8
scale resets, and sampling. The engines in ``runtime/engine.py`` glue the two
layers together.

Sharding contract (the reason this layer exists):

  * The paged pool pytree is *explicitly sharded* at construction:
    ``block_pool_spec`` puts the kv-head dim over the 'model' mesh axis when
    divisible (scale planes follow via ``block_scale_spec``); block tables
    and the small per-slot vectors are replicated. Every jitted entry point
    takes and returns that same sharded pytree, so placement is decided once
    here and never re-negotiated inside the engines.
  * Params are placed **replicated** (``P()``), deliberately: sharding the
    matmuls would split their contractions and psum the partials, which
    reassociates fp addition — greedy decode would no longer be bit-exact
    against a single-shard run. Replicated params + head-sharded attention
    (each head's math is computed whole on exactly one shard) keeps the
    tensor-parallel engine bit-identical, which the parity suite asserts.
  * Calls run under ``sharding.use_mesh``, so the trace-time shard_map
    dispatch around the fused paged kernels (kernels/ops.py) sees the mesh.
  * Small host inputs (tokens, tables, lens, rng key) are placed replicated
    on the step's mesh per call — data-parallel replicas own disjoint device
    subsets, and uncommitted default-device arrays must not pin a replica's
    computation to device 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.kv_codec import kv_cache_is_int4
from repro.models import build_model, default_qstate
from repro.runtime import sampling as smp
from repro.runtime import sharding as shd


def decode_scan(step_kv, kv, tokens, lens, active, budget, temperature, top_k,
                top_p, key, *, steps, sampler, eos_id, max_seq):
    """``steps`` decode iterations under one jit: per step, one attention
    dispatch over all slots + one batched sampling dispatch. EOS/budget/
    max_seq transitions update the active mask *inside* the scan, so a slot
    that finishes mid-chunk stops consuming budget and its later emissions
    are masked. ``sampler`` (static, known host-side from the active slots'
    params) picks the cheapest variant: "greedy" is pure argmax,
    "temperature" is sort-free Gumbel-max, "full" is the general top-k/top-p
    sampler. ``step_kv(tokens, kv, lens, active)`` is the engine-specific
    model call (slot-ragged or paged); ``kv`` is the engine's cache pytree —
    {"k","v"} for the slot cache, plus "k_scale"/"v_scale" planes for an
    int8 paged pool."""
    eos = -1 if eos_id is None else eos_id

    def step(carry, _):
        kv, tokens, lens, active, budget, key = carry
        logits, kv = step_kv(tokens, kv, lens, active)
        key, sub = jax.random.split(key)
        if sampler == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        elif sampler == "temperature":
            nxt = smp.sample_temperature(logits, temperature, sub)
        else:
            nxt = smp.sample_tokens(logits, temperature, top_k, top_p, sub)
        emit_mask = active
        new_lens = jnp.where(active, lens + 1, lens)
        new_budget = jnp.where(active, budget - 1, budget)
        finished = (nxt == eos) | (new_budget <= 0) | (new_lens >= max_seq)
        new_active = active & ~finished
        new_tokens = jnp.where(active, nxt, tokens[:, 0])[:, None]
        emitted = jnp.where(emit_mask, nxt, -1)
        return (kv, new_tokens, new_lens, new_active, new_budget, key), (
            emitted,
            emit_mask,
        )

    init = (kv, tokens, lens, active, budget, key)
    (kv, tokens, lens, active, budget, key), (emitted, masks) = jax.lax.scan(
        step, init, None, length=steps
    )
    return kv, tokens, lens, active, budget, key, emitted, masks


class _DeviceStep:
    """Shared device-side scaffold: model/qstate/params placement + sampling."""

    def __init__(self, cfg, params, *, qstate, max_seq, eos_id, cache_dtype, mesh):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.qstate = qstate if qstate is not None else default_qstate(cfg)
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.cache_dtype = cache_dtype
        self.mesh = mesh
        if mesh is not None:
            # replicated on purpose — see the module docstring's contract
            params = jax.device_put(params, NamedSharding(mesh, P()))
        self.params = params
        self._jit_sample = jax.jit(smp.sample_tokens)

    def _put(self, x, dtype=None):
        """Host array -> device, replicated on this step's mesh (if any)."""
        a = jnp.asarray(x, dtype)
        if self.mesh is not None:
            a = jax.device_put(a, NamedSharding(self.mesh, P()))
        return a

    def sample_first(self, logits, sampling, key) -> int:
        """Sample one token from a (1, V) prefill logits row."""
        with shd.use_mesh(self.mesh):
            out = self._jit_sample(
                logits,
                self._put([sampling.temperature], jnp.float32),
                self._put([sampling.top_k], jnp.int32),
                self._put([sampling.top_p], jnp.float32),
                self._put(key),
            )
        return int(out[0])


class SlotDeviceStep(_DeviceStep):
    """Device half of the slot engine: rectangular (L, S, KV, max_seq, Dh)
    cache, bucketed single-request prefill + insert, scanned decode chunks."""

    def __init__(self, cfg, params, *, qstate=None, max_slots, max_seq,
                 eos_id=None, cache_dtype=jnp.bfloat16, mesh=None):
        super().__init__(cfg, params, qstate=qstate, max_seq=max_seq,
                         eos_id=eos_id, cache_dtype=cache_dtype, mesh=mesh)
        self.max_slots = max_slots
        # donate the K/V buffers on the hot paths: the engine rebinds them from
        # the outputs immediately, so XLA may update the cache in place instead
        # of copying the full (L, slots, KV, max_seq, Dh) arrays per chunk /
        # admission (CPU ignores donation; TPU/GPU halve peak cache memory)
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn, donate_argnums=(0, 1))
        self._jit_chunk = jax.jit(self._chunk_fn, static_argnames=("steps", "sampler"),
                                  donate_argnums=(1,))

    def init_cache(self):
        """Build the slot cache, sharded per ``slot_cache_spec`` on a mesh."""
        cache = self.model.init_cache(self.max_slots, self.max_seq, self.cache_dtype)
        if self.mesh is not None:
            spec = shd.slot_cache_spec(self.cfg, self.mesh)
            sh = NamedSharding(self.mesh, spec)
            cache["k"] = jax.device_put(cache["k"], sh)
            cache["v"] = jax.device_put(cache["v"], sh)
        return cache["k"], cache["v"]

    # ------------------------------------------------------------- jitted fns

    def _prefill_fn(self, params, tokens, length):
        """tokens (1, P) right-padded; length (1,) true prompt length."""
        cache = self.model.init_cache(1, tokens.shape[1], self.cache_dtype)
        logits, cache = self.model.prefill(
            params, {"tokens": tokens}, cache, self.qstate, lens=length
        )
        return logits, cache["k"], cache["v"]

    def _insert_fn(self, big_k, big_v, ks, vs, slot):
        """Write a (L, 1, KV, P, Dh) prefill cache into slot ``slot``."""
        start = (0, slot, 0, 0, 0)
        return (
            jax.lax.dynamic_update_slice(big_k, ks.astype(big_k.dtype), start),
            jax.lax.dynamic_update_slice(big_v, vs.astype(big_v.dtype), start),
        )

    def _chunk_fn(self, params, kv, tokens, lens, active, budget, temperature,
                  top_k, top_p, key, *, steps, sampler):
        def step_kv(tokens, kv, lens, active):
            logits, cache = self.model.decode_step_ragged(
                params, tokens, kv, lens, self.qstate
            )
            return logits, {"k": cache["k"], "v": cache["v"]}

        return decode_scan(step_kv, kv, tokens, lens, active, budget,
                           temperature, top_k, top_p, key, steps=steps,
                           sampler=sampler, eos_id=self.eos_id, max_seq=self.max_seq)

    # ---------------------------------------------------------------- wrappers

    def prefill(self, padded, length):
        with shd.use_mesh(self.mesh):
            return self._jit_prefill(self.params, self._put(padded),
                                     self._put(length, jnp.int32))

    def insert(self, big_k, big_v, ks, vs, slot):
        with shd.use_mesh(self.mesh):
            return self._jit_insert(big_k, big_v, ks, vs, slot)

    def decode_chunk(self, kv, tokens, lens, active, budget, temperature,
                     top_k, top_p, key, *, steps, sampler):
        with shd.use_mesh(self.mesh):
            return self._jit_chunk(
                self.params, kv, self._put(tokens), self._put(lens),
                self._put(active), self._put(budget), self._put(temperature),
                self._put(top_k), self._put(top_p), self._put(key),
                steps=steps, sampler=sampler,
            )


class PagedDeviceStep(_DeviceStep):
    """Device half of the paged engine: the sharded block-pool pytree and the
    jitted chunked-prefill / decode-chunk / CoW-copy / scale-reset functions
    that carry it (DESIGN.md §3/§6/§9)."""

    def __init__(self, cfg, params, *, qstate=None, num_blocks, block_size,
                 max_seq, eos_id=None, cache_dtype=jnp.bfloat16, mesh=None):
        super().__init__(cfg, params, qstate=qstate, max_seq=max_seq,
                         eos_id=eos_id, cache_dtype=cache_dtype, mesh=mesh)
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.int4 = kv_cache_is_int4(cache_dtype)
        self.quantized = self.int4 or jnp.dtype(cache_dtype) == jnp.int8
        self._jit_prefill_chunk = jax.jit(self._prefill_chunk_fn, donate_argnums=(1,))
        self._jit_verify_chunk = jax.jit(self._verify_chunk_fn, donate_argnums=(1,))
        self._jit_trim_sub = jax.jit(self._trim_sub_fn, donate_argnums=(0,))
        # raw jitted (pool, src, dst) -> pool; the engine exposes this as
        # ``_jit_copy_block`` (tests drive it directly on a loose pool dict)
        self.copy_block = jax.jit(self._copy_block_fn, donate_argnums=(0,))
        self.reset_scales = jax.jit(self._reset_scales_fn, donate_argnums=(0,))
        self._jit_chunk = jax.jit(self._chunk_fn, static_argnames=("steps", "sampler"),
                                  donate_argnums=(1,))

    def init_pool(self) -> dict:
        """Build the block pool, sharded over the mesh: payloads per
        ``block_pool_spec`` (kv-heads over 'model' when divisible), scale
        planes per ``block_scale_spec``, int4 sub-code planes per
        ``block_sub_scale_spec``."""
        return self.model.init_block_pool(self.num_blocks, self.block_size,
                                          self.cache_dtype, mesh=self.mesh)

    # ------------------------------------------------------------- jitted fns

    def _prefill_chunk_fn(self, params, pool, tokens, table, start, chunk_len, blk_t, off_t):
        return self.model.prefill_paged_chunk(
            params, tokens, pool, table, start, chunk_len, blk_t, off_t, self.qstate,
            block_size=self.block_size,
        )

    def _verify_chunk_fn(self, params, pool, tokens, table, start, blk_t, off_t):
        """Speculative verify (DESIGN.md §12): one fused paged-prefill call
        over the [start, start+C) window returns the target model's greedy
        token after every row — pending token + each draft position. Argmax
        runs in-jit so only (C,) int32 crosses back to the host."""
        logits, pool = self.model.verify_paged_chunk(
            params, tokens, pool, table, start, blk_t, off_t, self.qstate
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pool

    def _trim_sub_fn(self, pool, blk, keep_subs):
        """Zero block ``blk``'s int4 sub-scale codes at sub indices >=
        ``keep_subs`` (all layers, K and V). Rejected verify rows may have
        seeded sub codes past the accepted tail inside the kept block; sub
        codes are immutable once set (first-write-wins), so without this the
        next real token to reach that sub-block would quantize at a scale
        vanilla decode never saw (DESIGN.md §12)."""
        pool = dict(pool)
        n_sub = pool["k_sub"].shape[-1]
        drop = jnp.arange(n_sub) >= keep_subs  # (n_sub,)
        for key in ("k_sub", "v_sub"):
            plane = pool[key]  # (L, N, KV, n_sub)
            pool[key] = plane.at[:, blk].set(
                jnp.where(drop, jnp.zeros((), plane.dtype), plane[:, blk])
            )
        return pool

    def _copy_block_fn(self, pool, src, dst):
        """Copy-on-write device half: duplicate block ``src`` into ``dst``
        across all layers (the pool already moved the refcounts). For an int8
        pool the per-block scale planes travel with the payload — the fork
        must dequantize identically to the shared original (DESIGN.md §6)."""
        return {k: a.at[:, dst].set(a[:, src]) for k, a in pool.items()}

    def _reset_scales_fn(self, pool, ids):
        """Zero the scale planes of freshly allocated blocks: 0 is the
        "unset" sentinel the next scatter seeds from (DESIGN.md §6). Packed
        int4 pools also zero the sub-block scale-code planes (DESIGN.md §10)
        — a stale nonzero sub code would be immutable under first-write-wins
        and dequantize the new tenant's rows at the old tenant's scale."""
        pool = dict(pool)
        pool["k_scale"] = pool["k_scale"].at[:, ids].set(0.0)
        pool["v_scale"] = pool["v_scale"].at[:, ids].set(0.0)
        if "k_sub" in pool:
            pool["k_sub"] = pool["k_sub"].at[:, ids].set(0)
            pool["v_sub"] = pool["v_sub"].at[:, ids].set(0)
        return pool

    def _chunk_fn(self, params, pool, tables, tokens, lens, active, budget,
                  temperature, top_k, top_p, key, *, steps, sampler):
        def step_kv(tokens, pool, lens, active):
            return self.model.decode_step_paged(
                params, tokens, pool, tables, lens, active, self.qstate,
                block_size=self.block_size,
            )

        return decode_scan(step_kv, pool, tokens, lens, active, budget,
                           temperature, top_k, top_p, key, steps=steps,
                           sampler=sampler, eos_id=self.eos_id, max_seq=self.max_seq)

    # ---------------------------------------------------------------- wrappers

    def prefill_chunk(self, pool, tokens, table, start, n, blk_t, off_t):
        with shd.use_mesh(self.mesh):
            return self._jit_prefill_chunk(
                self.params, pool, self._put(tokens), self._put(table),
                self._put(np.int32(start)), self._put(np.int32(n)),
                self._put(blk_t), self._put(off_t),
            )

    def verify_chunk(self, pool, tokens, table, start, blk_t, off_t):
        """-> (verified (C,) int32 greedy tokens, new_pool). Compiles once
        per distinct window length C (k is fixed per engine, so in practice
        two shapes: k+1 and the k=0 fallback row)."""
        with shd.use_mesh(self.mesh):
            return self._jit_verify_chunk(
                self.params, pool, self._put(tokens), self._put(table),
                self._put(np.int32(start)), self._put(blk_t), self._put(off_t),
            )

    def trim_sub_scales(self, pool, blk, keep_subs) -> dict:
        """Drop rejected-row sub-scale codes past ``keep_subs`` in ``blk``."""
        with shd.use_mesh(self.mesh):
            return self._jit_trim_sub(pool, self._put(np.int32(blk)),
                                      self._put(np.int32(keep_subs)))

    def copy_blocks(self, pool, copies) -> dict:
        """Drain queued CoW copies (in order — sources may be recycled and
        re-targeted later in the same queue)."""
        with shd.use_mesh(self.mesh):
            for src, dst in copies:
                pool = self.copy_block(pool, self._put(np.int32(src)),
                                       self._put(np.int32(dst)))
        return pool

    def reset_fresh_scales(self, pool, ids) -> dict:
        """Zero the scale planes of blocks ``ids`` ((n,) int32, null-padded)."""
        with shd.use_mesh(self.mesh):
            return self.reset_scales(pool, self._put(ids))

    def decode_chunk(self, pool, tables, tokens, lens, active, budget,
                     temperature, top_k, top_p, key, *, steps, sampler):
        with shd.use_mesh(self.mesh):
            return self._jit_chunk(
                self.params, pool, self._put(tables), self._put(tokens),
                self._put(lens), self._put(active), self._put(budget),
                self._put(temperature), self._put(top_k), self._put(top_p),
                self._put(key), steps=steps, sampler=sampler,
            )

    def pool_bytes(self, pool) -> int:
        """Device bytes of the whole pool (int8: payloads + scale planes)."""
        return sum(a.nbytes for a in pool.values())
