"""Paged KV-cache block pool: allocator, prefix index, copy-on-write rules.

Host-side bookkeeping for the paged serving engine (DESIGN.md §3). The
device side is one global pair of K/V arrays shaped
``(L, num_blocks, KV, block_size, Dh)``; everything here manipulates *block
ids* — small integers indexing that pool — and never touches device memory.

Three cooperating pieces:

  * ``BlockPool``   — free-list allocator with per-block reference counts and
                      an LRU of *evictable* blocks (refcount 0 but still
                      registered in the prefix index). ``alloc`` prefers the
                      free list and falls back to evicting the
                      least-recently-used cached block; blocks referenced by
                      a live request are never evicted (DESIGN.md §3,
                      block-table invariants I1–I4).
  * prefix hashing  — ``chain_hashes`` folds a prompt into a rolling hash per
                      token block: ``h_i = H(h_{i-1}, tokens_i)``. Chaining
                      makes a block's hash identify the *entire prefix*
                      through that block, so an index hit guarantees the
                      cached KV is byte-for-byte what a fresh prefill would
                      produce (DESIGN.md §3, prefix-hash scheme). The partial
                      tail block is hashed too (over its actual tokens), so
                      fully identical prompts share everything.
  * copy-on-write   — the pool never writes; it adjudicates. Engines call
                      ``writable(block)`` before appending KV into a block:
                      a block with ``refcount > 1`` must be copied first
                      (another request may append to the same offsets), a
                      block with ``refcount == 1`` may be appended in place
                      even when it is registered in the prefix index —
                      appends land at offsets *beyond* the hashed token
                      count, so the cached prefix stays intact (DESIGN.md §3,
                      copy-on-write rules).

Quantized pools (DESIGN.md §6/§10) change the *payload encoding only*: a
block may hold int8 codes plus a per-(block, kv-head) scale, or packed int4
nibbles with 4-bit per-sub-block scale codes on top, but ids, refcounts,
hashing and CoW adjudication are encoding-blind, so nothing here changes.
The two encoding-specific duties live with the engine, which owns device
memory: CoW copies must carry the scale (and sub-code) planes with the
payload, and a block re-issued by ``alloc`` must have all its scale planes
reset before first write (``PagedEngine._copy_block_fn`` /
``_flush_fresh_scales``). The published-bytes invariant I2 is what forces a
block's scales to be immutable once seeded — requantizing on append would
rewrite hashed prefix bytes.
"""

from __future__ import annotations

import zlib
from collections import OrderedDict, deque
from dataclasses import dataclass, fields

import numpy as np

# Block id 0 is reserved as the *null block*: a garbage sink for gated device
# writes (inactive slots, padded prefill rows) and the padding value of block
# tables. It is never allocated, never registered, never read unmasked.
NULL_BLOCK = 0


@dataclass(frozen=True)
class PoolOccupancy:
    """Point-in-time allocator census, shipped inside ``PoolExhausted`` and
    shed-load ``Rejected`` responses (DESIGN.md §11) so callers can size
    their backoff against how full the pool actually is. ``num_blocks``
    counts usable blocks (the reserved null block excluded); the three
    states partition it (BlockPool invariant I1)."""

    num_blocks: int
    num_free: int
    num_evictable: int
    num_live: int

    @property
    def live_fraction(self) -> float:
        return self.num_live / max(self.num_blocks, 1)


class PoolExhausted(RuntimeError):
    """No free block and nothing evictable — every block is held by a live
    request. The engine surfaces this instead of silently corrupting KV.

    Structured, not just a message (DESIGN.md §11): ``retryable`` tells the
    serving front whether waiting can help — True when live requests will
    release blocks as they finish (shed-load territory), False when the
    demand can *never* fit (a sole request larger than the pool — a bug or a
    misconfiguration, which the chaos harness must not mistake for load).
    ``occupancy`` carries the ``PoolOccupancy`` census at raise time.
    """

    def __init__(self, msg: str, *, retryable: bool = True,
                 occupancy: "PoolOccupancy | None" = None):
        super().__init__(msg)
        self.retryable = retryable
        self.occupancy = occupancy


def hash_block(prev_hash: int, tokens) -> int:
    """Rolling block hash: fold ``tokens`` (one block's ids) onto the chain.

    crc32 over the little-endian int64 bytes, seeded with the previous link —
    deterministic across processes (unlike ``hash()``), cheap, and collision
    risk is acceptable for a cache *index* whose payload is re-derivable.
    """
    buf = np.asarray(tokens, np.int64).tobytes()
    return zlib.crc32(buf, prev_hash & 0xFFFFFFFF)


def chain_hashes(prompt, block_size: int) -> list[tuple[int, int]]:
    """Prompt -> [(chain_hash, tokens_in_block), ...] per block (tail included).

    Full blocks carry ``block_size`` tokens; a trailing partial block carries
    ``len(prompt) % block_size``. Two prompts produce the same hash at block i
    iff they agree on every token through block i.
    """
    prompt = np.asarray(prompt, np.int64).reshape(-1)
    out, h = [], 0
    for start in range(0, len(prompt), block_size):
        chunk = prompt[start : start + block_size]
        h = hash_block(h, chunk)
        out.append((h, len(chunk)))
    return out


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    evictions: int = 0
    cow_copies: int = 0
    hash_hits: int = 0
    hash_misses: int = 0

    @classmethod
    def merged(cls, parts: "list[PoolStats] | tuple[PoolStats, ...]") -> "PoolStats":
        """Field-wise sum across data-parallel replica pools (DESIGN.md §9)."""
        out = cls()
        for p in parts:
            for f in fields(cls):
                setattr(out, f.name, getattr(out, f.name) + getattr(p, f.name))
        return out


class BlockPool:
    """Reference-counted block allocator with a prefix-cache index.

    Invariants (DESIGN.md §3):
      I1  every block is in exactly one of: free list, LRU (evictable), or
          live (refcount >= 1);
      I2  a block in the prefix index maps hash -> block with the hashed KV
          materialized at offsets [0, hashed_tokens);
      I3  eviction only takes refcount-0 blocks, LRU first;
      I4  block 0 (NULL_BLOCK) is permanently reserved.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null block), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.refcount = np.zeros(num_blocks, np.int32)
        self._free: deque[int] = deque(range(1, num_blocks))
        # hash -> block id (full and partial prefix blocks)
        self._index: dict[int, int] = {}
        # block id -> hash (reverse map, for eviction / invalidation)
        self._hash_of: dict[int, int] = {}
        # evictable cached blocks, least-recently-used first
        self._lru: OrderedDict[int, None] = OrderedDict()
        self.stats = PoolStats()

    # ------------------------------------------------------------ allocation

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_evictable(self) -> int:
        return len(self._lru)

    @property
    def num_live(self) -> int:
        return int((self.refcount > 0).sum())

    def occupancy(self) -> PoolOccupancy:
        """Allocator census for structured back-pressure (DESIGN.md §11)."""
        return PoolOccupancy(self.num_blocks - 1, self.num_free,
                             self.num_evictable, self.num_live)

    def alloc(self) -> int:
        """One exclusive (refcount-1) block; evicts the LRU cached block when
        the free list is empty. Raises ``PoolExhausted`` (retryable, with the
        occupancy census attached) when every block is live — callers must
        treat that as back-pressure, not corruption."""
        if self._free:
            blk = self._free.popleft()
        elif self._lru:
            blk, _ = self._lru.popitem(last=False)  # least recently used
            self._forget(blk)
            self.stats.evictions += 1
        else:
            raise PoolExhausted(
                f"all {self.num_blocks - 1} usable blocks are referenced by live requests",
                retryable=True, occupancy=self.occupancy(),
            )
        assert blk != NULL_BLOCK and self.refcount[blk] == 0
        self.refcount[blk] = 1
        self.stats.allocs += 1
        return blk

    def retain(self, blk: int) -> None:
        assert blk != NULL_BLOCK
        assert self.refcount[blk] >= 1, f"retain of dead block {blk}"
        self.refcount[blk] += 1

    def release(self, blk: int) -> None:
        """Drop one reference. At refcount 0 a registered block parks on the
        LRU (still serving prefix hits); an unregistered one frees."""
        assert blk != NULL_BLOCK
        assert self.refcount[blk] >= 1, f"release of dead block {blk}"
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            if blk in self._hash_of:
                self._lru[blk] = None
                self._lru.move_to_end(blk)
            else:
                self._free.append(blk)
                self.stats.frees += 1

    # ---------------------------------------------------------- prefix index

    def lookup(self, h: int) -> int | None:
        """Prefix-cache probe: on hit, retains the block (resurrecting it from
        the LRU if it was parked) and returns its id; None on miss."""
        blk = self._index.get(h)
        if blk is None:
            self.stats.hash_misses += 1
            return None
        self.stats.hash_hits += 1
        if self.refcount[blk] == 0:
            self._lru.pop(blk, None)
            self.refcount[blk] = 1
        else:
            self.refcount[blk] += 1
        return blk

    def register(self, h: int, blk: int) -> None:
        """Publish a (live) block under its chain hash. First writer wins —
        re-registering an existing hash is a no-op so a published block is
        never silently swapped out from under earlier sharers."""
        assert self.refcount[blk] >= 1, "only live blocks can be registered"
        if h in self._index:
            return
        # a block re-used after eviction may carry a stale reverse entry
        old = self._hash_of.get(blk)
        if old is not None and self._index.get(old) == blk:
            del self._index[old]
        self._index[h] = blk
        self._hash_of[blk] = h

    def _forget(self, blk: int) -> None:
        h = self._hash_of.pop(blk, None)
        if h is not None and self._index.get(h) == blk:
            del self._index[h]

    # --------------------------------------------------------- copy-on-write

    def writable(self, blk: int) -> bool:
        """True when the engine may append into ``blk`` in place: exactly one
        reference. Shared blocks (refcount > 1) must be copied first —
        ``fork()`` hands out the replacement id; the engine performs the
        device copy."""
        assert self.refcount[blk] >= 1
        return self.refcount[blk] == 1

    def fork(self, blk: int) -> int:
        """Copy-on-write bookkeeping: allocate a private replacement for the
        shared block ``blk`` and drop our reference to the original. The
        caller must copy the device payload old -> new before writing."""
        assert self.refcount[blk] > 1, f"fork of unshared block {blk}"
        new = self.alloc()
        self.release(blk)
        self.stats.cow_copies += 1
        return new
