"""Self-drafting for speculative decoding (DESIGN.md §12).

Speculative decoding attacks the one-step-per-token serial bottleneck: a
cheap *drafter* proposes ``k`` continuation tokens for a slot, the target
model verifies all of them (plus the slot's pending token) in ONE fused
paged-prefill call over the window ``[L, L+k]``, and greedy accept/reject
keeps whichever prefix the target model agrees with. Everything here is
jax-free host code — the engine core plans branches with it, and the only
device work stays the single verify chunk.

Drafters implement one method::

    propose(context: Sequence[int], k: int) -> list[int]

``context`` is the request's full token history (prompt + everything
generated so far, ending with the slot's *pending* token — sampled but not
yet written to KV); the proposal continues it. Returning fewer than ``k``
tokens is legal (the verify window just shrinks); proposals must be
deterministic functions of ``(context, k)`` so dp replicas and reruns stay
bit-reproducible.
"""

from __future__ import annotations

from typing import Protocol, Sequence

__all__ = [
    "Drafter",
    "FnDrafter",
    "NgramDrafter",
    "greedy_accept_length",
    "make_drafter",
]


class Drafter(Protocol):
    """Anything with a deterministic ``propose(context, k) -> list[int]``."""

    def propose(self, context: Sequence[int], k: int) -> list[int]: ...


def greedy_accept_length(drafts: Sequence[int], verified: Sequence[int]) -> int:
    """Greedy accept rule: longest prefix of ``drafts`` the target agrees with.

    ``verified[i]`` is the target model's argmax *after* consuming draft
    position ``i`` context (``verified[0]`` follows the pending token alone),
    so draft ``drafts[i]`` survives iff every earlier draft survived and
    ``drafts[i] == verified[i]``. Returns ``a`` in ``[0, len(drafts)]``; the
    caller emits ``drafts[:a]`` then the correction token ``verified[a]`` —
    exactly the ``a + 1`` tokens vanilla greedy decode would have produced,
    bit-for-bit (the chunked verify attends with the same two-pass global-max
    histogram combine as single-token decode, DESIGN.md §5/§12).
    """
    a = 0
    for d, v in zip(drafts, verified):
        if int(d) != int(v):
            break
        a += 1
    return a


class NgramDrafter:
    """Suffix-match self-drafter: no draft model, just the request's own
    history. For each proposed token it finds the most recent earlier
    occurrence of the longest current suffix (order ``n`` down to
    ``min_order``) and proposes whatever followed it — free accuracy on
    repetitive continuations (code, templated text, the bench's periodic
    trace) and harmless on novel text, where rejections cost one verify
    round that vanilla decode would have spent anyway."""

    def __init__(self, order: int = 3, min_order: int = 1):
        assert 1 <= min_order <= order
        self.order = order
        self.min_order = min_order

    def propose(self, context: Sequence[int], k: int) -> list[int]:
        ctx = [int(t) for t in context]
        out: list[int] = []
        for _ in range(max(k, 0)):
            nxt = self._match(ctx)
            if nxt is None:
                break
            out.append(nxt)
            ctx.append(nxt)
        return out

    def _match(self, ctx: list[int]) -> int | None:
        for n in range(self.order, self.min_order - 1, -1):
            if len(ctx) <= n:
                continue
            pat = ctx[-n:]
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i : i + n] == pat:
                    return ctx[i + n]
        return None


class FnDrafter:
    """Wrap a plain ``fn(context, k) -> Sequence[int]`` as a Drafter — the
    test suites use it to script exact accept-length edges (all-accepted,
    all-rejected, every split in between)."""

    def __init__(self, fn):
        self._fn = fn

    def propose(self, context: Sequence[int], k: int) -> list[int]:
        return [int(t) for t in self._fn(context, k)][: max(k, 0)]


_DRAFTERS = {
    "ngram": NgramDrafter,
}


def make_drafter(name: str) -> Drafter:
    """Resolve a ``--drafter`` flag value to a Drafter instance."""
    try:
        return _DRAFTERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown drafter {name!r}; available: {sorted(_DRAFTERS)}"
        ) from None
