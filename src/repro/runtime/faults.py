"""Deterministic fault injection for the serving stack (DESIGN.md §11).

Chaos testing only means something when a failure replays: every fault
schedule here is driven by a caller-provided ``numpy`` Generator, which the
test layer seeds through the PYTEST_SEED machinery (tests/conftest.py) — a
chaos counterexample reproduces with one env var, exactly like the fuzzers.

Three pieces, all jax-free (they exercise the pure-host ``EngineCore`` from
DESIGN.md §9 directly, and wrap real engines without touching device state):

  * ``audit_block_invariants`` — the full allocator + scheduler audit
    (BlockPool I1-I4, refcount-vs-table equality, device-mirror agreement,
    reset/copy ordering). Shared by the fuzzers, the chaos suite, and the
    frontend tests; the ``held`` parameter accounts for blocks the harness
    itself has pinned, so pool-exhaustion injection doesn't read as a leak.
  * ``HostDeviceEmulator`` — a numpy emulation of ``PagedEngine.step_chunk``
    honoring decode_scan's visible semantics (emission masks, budget / EOS /
    max_seq finish transitions), so scheduler policy and fault recovery are
    testable at fuzz speed with no jax in the process.
  * ``ChaosHarness`` — the injection surface: pool exhaustion (pin blocks
    until the allocator starves), mid-stream client disconnects (cancel),
    malformed requests (must shed as non-retryable ``Rejected``, never
    enqueue), and stalled device steps (``slow_steps`` wraps an engine's
    ``step_chunk`` with a delay — the async frontend must keep accepting
    submissions and cancellations while a step drags).

Invariant contract the chaos suite enforces (ISSUE acceptance): after every
injected event, no block leaks (audit passes), every non-shed request
finishes with bit-exact greedy parity against a fault-free run, and every
shed request receives a structured retryable ``Rejected``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.runtime.engine_core import EngineConfig, EngineCore, Rejected
from repro.runtime.kv_pool import NULL_BLOCK, PoolExhausted

__all__ = [
    "ChaosHarness",
    "EmulatedEngine",
    "HostDeviceEmulator",
    "audit_block_invariants",
    "slow_steps",
]


# ------------------------------------------------------------ invariant audit


def audit_block_invariants(core: EngineCore, held=()) -> None:
    """Audit the full allocator + scheduler state (BlockPool I1-I4 plus the
    engine-core bookkeeping that rides on them). Cheap enough to run after
    every fuzz/chaos step. ``held`` lists block ids pinned by a harness
    (one entry per reference), accounted alongside slot-table references."""
    pool = core.pool
    n = pool.num_blocks
    ref = np.asarray(pool.refcount)
    free = list(pool._free)
    lru = list(pool._lru)

    # I4: the null block is permanently reserved
    assert NULL_BLOCK not in free and NULL_BLOCK not in lru
    assert ref[NULL_BLOCK] == 0

    # I1: free / evictable(LRU) / live partition the usable ids exactly
    assert len(set(free)) == len(free), "duplicate ids on the free list"
    assert len(set(lru)) == len(lru), "duplicate ids on the LRU"
    live = {b for b in range(1, n) if ref[b] > 0}
    assert live.isdisjoint(free), f"live blocks on the free list: {live & set(free)}"
    assert live.isdisjoint(lru), f"live blocks on the LRU: {live & set(lru)}"
    assert set(free).isdisjoint(lru)
    assert live | set(free) | set(lru) == set(range(1, n)), "pool partition leak"

    # I3: evictable blocks are refcount-0 AND published (else they'd be free)
    for b in lru:
        assert ref[b] == 0 and b in pool._hash_of

    # I2 bookkeeping: index and reverse map agree
    for h, b in pool._index.items():
        assert pool._hash_of.get(b) == h, f"index/hash_of disagree on block {b}"

    # refcount accounting: every reference is exactly one slot-table entry,
    # one in-flight speculative branch-table entry (DESIGN.md §12), or a
    # harness-held pin
    expected = np.zeros(n, np.int64)
    for b in held:
        expected[b] += 1
    for i, s in enumerate(core._slots):
        if s.free:
            continue
        for b in s.table:
            assert b != NULL_BLOCK
            expected[b] += 1
        # the device mirror matches host truth
        t = core._tables[i]
        assert list(t[: len(s.table)]) == list(s.table)
        assert (t[len(s.table):] == NULL_BLOCK).all()
    for slot, branches in getattr(core, "_branches", {}).items():
        assert branches, f"slot {slot} keeps an empty branch list"
        assert not core._slots[slot].free, f"free slot {slot} owns spec branches"
        for br in branches:
            assert br.uid == core._slots[slot].uid, "branch outlived its request"
            for b in br.table:
                assert b != NULL_BLOCK
                expected[b] += 1
    np.testing.assert_array_equal(
        ref[1:], expected[1:],
        err_msg="refcounts drifted from slot/branch-table references",
    )

    # queued CoW destinations must not be pending a scale reset (the copy
    # delivers their valid grid; a later reset would zero it)
    for _, dst in core.pending_copies:
        assert dst not in core._fresh_blocks

    # StatePool cores (ssm/hybrid — DESIGN.md §13): decode overwrites a
    # partial tail block's state planes in place, so only *full* blocks may
    # ever be published to the prefix index — a partial-chain hash in the
    # index would let a later request read state through more tokens than
    # the hash names
    if getattr(core, "state_blocks", False):
        bs = core.block_size
        for s in core._slots:
            if s.free:
                continue
            for h, ntok in getattr(s, "hashes", ()):
                if ntok < bs:
                    assert h not in pool._index, (
                        f"partial-tail hash published on a state pool "
                        f"(ntok={ntok} < block_size={bs})"
                    )


# --------------------------------------------------------- host-side emulator


class HostDeviceEmulator:
    """Numpy stand-in for ``PagedEngine``'s device half: drives an
    ``EngineCore`` through admit / prefill-chunk / decode-chunk transitions
    with rng-sampled tokens, honoring decode_scan's visible semantics
    (emission masks, budget / EOS / max_seq finish transitions). The policy
    layer under test — priorities, deadlines, preemption, cancellation,
    shedding — is identical to production; only the token values differ."""

    def __init__(self, rng: np.random.Generator, *, vocab: int, eos: int | None):
        self.rng = rng
        self.vocab = vocab
        self.eos = eos

    def step_chunk(self, core: EngineCore, steps: int | None = None) -> None:
        """One emulated ``PagedEngine.step_chunk``. May raise PoolExhausted
        exactly where the real engine would (terminal sole-request growth)."""
        core._admit()
        for i, s in enumerate(core._slots):
            if not s.free and s.prefilling:
                plan = core.plan_prefill_chunk(i)
                core.take_pending_copies()
                core.take_fresh_scale_ids()
                if core.commit_prefill_chunk(i, plan.n):
                    core._complete_first(i, s.req, int(self.rng.integers(0, self.vocab)))
        if core.num_active == 0:
            return
        if steps is None:
            steps = int(self.rng.integers(1, core.steps_per_sync + 1))
        steps = core._clamp_steps(steps)
        core._reserve_chunk_blocks(steps)
        if core.num_active == 0:
            return
        core.take_pending_copies()
        core.take_fresh_scale_ids()
        S = core.max_slots
        lens = core.kv_lens.copy()
        active = core._active.copy()
        budget = core._budget.copy()
        tokens = core._tokens.copy()
        emitted = np.full((steps, S), -1, np.int64)
        masks = np.zeros((steps, S), bool)
        was_active = core._active.copy()
        for t in range(steps):
            for b in range(S):
                if not active[b]:
                    continue
                nxt = int(self.rng.integers(0, self.vocab))
                masks[t, b] = True
                emitted[t, b] = nxt
                tokens[b, 0] = nxt
                lens[b] += 1
                budget[b] -= 1
                if nxt == self.eos or budget[b] <= 0 or lens[b] >= core.max_seq:
                    active[b] = False
        core._absorb_chunk(tokens, lens, active, budget, emitted, masks, was_active)

    def spec_round(self, core: EngineCore, slot: int, k: int | None = None) -> int:
        """One emulated draft/verify/accept round on ``slot`` (DESIGN.md
        §12): rng drafts, the full branch fork through ``plan_spec_round``,
        an emulated verify whose agreement with the drafts is rng-chosen so
        every accept length 0..k occurs, then commit + absorb. Returns
        tokens emitted; 0 when the pool cannot fund the branch (the plan
        rolled itself back — the fuzzer audits that claim)."""
        if not core._active[slot]:
            return 0
        L = int(core.kv_lens[slot])
        if k is None:
            k = int(self.rng.integers(0, 5))
        k = max(0, min(k, int(core._budget[slot]) - 1, core.max_seq - 1 - L))
        drafts = [int(t) for t in self.rng.integers(0, self.vocab, size=k)]
        try:
            plan = core.plan_spec_round(slot, drafts)
        except PoolExhausted:
            return 0
        core.take_pending_copies()
        core.take_fresh_scale_ids()
        a = int(self.rng.integers(0, k + 1))
        verified = []
        for i in range(k + 1):
            if i < a:
                verified.append(drafts[i])
            else:
                t = int(self.rng.integers(0, self.vocab))
                if i < k and t == drafts[i]:
                    t = (t + 1) % self.vocab  # force the accept length to a
                verified.append(t)
        res = core.commit_spec_round(plan, verified)
        return core.absorb_spec_round(slot, res.emitted)


class EmulatedEngine(EngineCore):
    """``EngineCore`` fused with the emulator into a steppable engine exposing
    the ``PagedEngine`` serving surface (``step_chunk`` / ``run`` /
    ``has_work`` / the SLA methods) — what the async frontend and the chaos
    suite drive when no jax belongs in the process. Scheduling is production
    code; only token values come from the rng."""

    def __init__(self, rng: np.random.Generator, config: EngineConfig | None = None,
                 *, vocab: int = 40, eos: int | None = None,
                 state_blocks: bool = False, **core_kw):
        if config is not None:
            if core_kw:
                raise TypeError(
                    "pass either config=EngineConfig(...) or per-field core "
                    f"kwargs, not both (got {sorted(core_kw)})"
                )
            core_kw = config.core_kwargs()
            if eos is None:
                eos = config.eos_id
            core_kw["eos_id"] = eos
        else:
            core_kw.setdefault("eos_id", eos)
        super().__init__(state_blocks=state_blocks, **core_kw)
        self._emu = HostDeviceEmulator(rng, vocab=vocab, eos=eos)

    def step_chunk(self, steps: int | None = None) -> int:
        before = self.stats["tokens_out"]
        self._emu.step_chunk(self, steps)
        return self.stats["tokens_out"] - before


# ------------------------------------------------------------ fault injection


def slow_steps(engine, delay_s: float, *, every: int = 1):
    """Wrap ``engine.step_chunk`` so every ``every``-th call stalls
    ``delay_s`` seconds before running — a slow/hung device step. Returns an
    undo callable. Deterministic in *which* steps stall; the delay is wall
    clock, which only the online frontend observes."""
    orig = engine.step_chunk
    count = [0]

    def stalled(steps=None):
        count[0] += 1
        if count[0] % every == 0:
            time.sleep(delay_s)
        return orig(steps)

    engine.step_chunk = stalled

    def undo():
        engine.step_chunk = orig

    return undo


class ChaosHarness:
    """Seeded fault injector over one core/engine (DESIGN.md §11 fault
    matrix). Faults mutate real scheduler state through public entry points
    only, so anything the harness breaks is a bug the serving front could
    hit. ``audit()`` accounts for the harness's own pinned blocks."""

    def __init__(self, core: EngineCore, rng: np.random.Generator):
        self.core = core
        self.rng = rng
        self.held: list[int] = []
        self.counters = {"exhaust": 0, "disconnect": 0, "malformed": 0, "release": 0}

    # --- pool exhaustion: pin blocks until the allocator starves -----------

    def exhaust_pool(self, n: int | None = None) -> int:
        """Pin up to ``n`` blocks (default: drain everything allocatable) so
        admissions/growth hit PoolExhausted. Pinned blocks are accounted by
        ``audit`` and returned by ``release_held`` — never leaked."""
        grabbed = 0
        while n is None or grabbed < n:
            try:
                self.held.append(self.core.pool.alloc())
            except PoolExhausted as e:
                assert e.retryable and e.occupancy is not None  # structured terminal
                break
            grabbed += 1
        self.counters["exhaust"] += grabbed
        return grabbed

    def release_held(self, k: int | None = None) -> int:
        """Release ``k`` (default: all) pinned blocks back to the pool —
        the 'live requests finished' half of an exhaustion episode."""
        k = len(self.held) if k is None else min(k, len(self.held))
        for _ in range(k):
            self.core.pool.release(self.held.pop())
        self.counters["release"] += k
        return k

    # --- client faults ------------------------------------------------------

    def disconnect(self, uid: int) -> bool:
        """Mid-stream client disconnect: cancel ``uid`` wherever it lives.
        The core must release its blocks and absorb the cancel silently."""
        self.counters["disconnect"] += 1
        return self.core.cancel(uid)

    def disconnect_random(self) -> int | None:
        """Disconnect one uniformly-chosen in-flight request (slot or queue);
        None when nothing is in flight."""
        uids = [s.uid for s in self.core._slots if not s.free]
        uids += [r.uid for r in self.core._queue]
        if not uids:
            return None
        uid = int(self.rng.choice(uids))
        self.disconnect(uid)
        return uid

    def submit_malformed(self) -> list[Rejected]:
        """Fire the malformed-request battery through ``try_submit``: every
        payload must come back as a *non-retryable* structured ``Rejected``
        (shed-load must stay distinguishable from garbage), and none may
        enqueue or touch the pool."""
        before = self.core._in_system()
        battery = [
            ([], 4),                                   # empty prompt
            (list(range(self.core.max_seq + 1)), 4),   # prompt >= max_seq
            ([3, 5, 7], 0),                            # max_new < 1
            (["not", "tokens"], 4),                    # non-integer payload
        ]
        out = []
        for prompt, max_new in battery:
            r = self.core.try_submit(prompt, max_new)
            assert isinstance(r, Rejected), f"malformed payload admitted: {prompt!r}"
            assert r.reason == "invalid" and not r.retryable
            out.append(r)
        assert self.core._in_system() == before
        self.counters["malformed"] += len(out)
        return out

    # --- audit --------------------------------------------------------------

    def audit(self) -> None:
        audit_block_invariants(self.core, held=self.held)
