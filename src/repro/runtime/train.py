"""Training step factory: mixed precision (fp32 masters, bf16 compute),
remat, microbatch gradient accumulation, MoE aux losses, and pjit shardings.

The gradient all-reduce over ('pod','data') is XLA-generated from the SPMD
shardings; FSDP all-gathers come from the param specs in runtime/sharding.py.
Optional cross-pod int8 gradient compression lives in optim/compression.py
(hierarchical sync — see its docstring).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import build_model, default_qstate
from repro.optim.adamw import AdamW, apply_updates
from repro.runtime import sharding as shd


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE, sharding-aware: all vocab-axis work is expressed as
    fused iota/compare reductions so a model-sharded V never gets all-gathered
    (take_along_axis on a sharded axis would force a full fp32 logits gather
    — at 92k vocab that alone is ~24 GB/step)."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def make_loss_fn(cfg, qstate=None, compute_dtype=jnp.bfloat16):
    model = build_model(cfg)
    qstate = qstate if qstate is not None else default_qstate(cfg)

    def loss_fn(params, batch):
        compute = jax.tree.map(
            lambda p: p.astype(compute_dtype) if p.dtype == jnp.float32 and p.ndim >= 2 else p, params
        )
        logits, aux = model.forward_train(compute, batch, qstate)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce
        metrics = {"ce": ce}
        if "moe_lb" in aux:
            loss = loss + 0.01 * aux["moe_lb"] + 1e-3 * aux["moe_z"]
            metrics.update(moe_lb=aux["moe_lb"], moe_z=aux["moe_z"])
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def init_train_state(cfg, optimizer: AdamW, key, dtype=jnp.float32) -> dict:
    model = build_model(cfg)
    params = model.init(key, dtype)
    return {"params": params, "opt": optimizer.init(params), "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg, optimizer: AdamW, qstate=None, microbatches: int = 1,
                    compute_dtype=jnp.bfloat16):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(cfg, qstate, compute_dtype)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc(carry, one):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(params, one)
                g_acc = jax.tree.map(jnp.add, g_acc, jax.tree.map(lambda g: g.astype(jnp.float32), grads))
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce": 0.0, "loss": 0.0}
            if cfg.moe is not None:
                m0.update(moe_lb=0.0, moe_z=0.0)
            (grads, metrics), _ = jax.lax.scan(acc, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        else:
            (_, metrics), grads = grad_fn(params, batch)

        updates, opt_state, opt_metrics = optimizer.update(grads, state["opt"], params)
        new_params = apply_updates(params, updates)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": opt_state, "step": state["step"] + 1}, metrics

    return train_step


def state_shardings(cfg, mesh, state_struct) -> Any:
    """NamedShardings for the full train state (params + adam moments)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    param_sh = shd.tree_shardings(state_struct["params"], cfg, mesh, mode="train")
    return {
        "params": param_sh,
        "opt": {
            "m": shd.tree_shardings(state_struct["opt"]["m"], cfg, mesh, mode="train"),
            "v": shd.tree_shardings(state_struct["opt"]["v"], cfg, mesh, mode="train"),
            "count": NamedSharding(mesh, P()),
        },
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(mesh, batch_struct):
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = shd.data_axes(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, shd.validate_spec(P(dp, *([None] * (len(s.shape) - 1))), s.shape, mesh)
        ),
        batch_struct,
    )
