"""Serving runtime: prefill + decode step factories with sharded KV caches
and the EXAQ seq-parallel decode combine. ``generate`` is a thin wrapper over
the continuous-batching engine (``runtime.engine``) for attention token
decoders, falling back to the rectangular loop for cache-stateful families.

Cache sharding policy (runtime/sharding.py): batch over ('pod','data'),
kv-heads over 'model' when divisible, else sequence over 'model' (SP decode —
the softmax max/denominator combine across sequence shards is where EXAQ's
integer-histogram composition pays off; see DESIGN.md §2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, default_qstate
from repro.runtime import sharding as shd


def make_serve_fns(cfg, qstate=None):
    model = build_model(cfg)
    qstate = qstate if qstate is not None else default_qstate(cfg)

    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache, qstate)
        return logits, cache

    def decode_step(params, tokens, cache):
        """tokens (B,1) -> (next_tokens (B,1), new_cache, logits)."""
        logits, cache = model.decode_step(params, tokens, cache, qstate)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache, logits

    return prefill_step, decode_step


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return build_model(cfg).init_cache(batch, max_seq, dtype)


def cache_shardings(cfg, mesh, cache_struct):
    """NamedShardings for the cache pytree."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    kv_spec = shd.cache_spec(cfg, mesh)
    ssm_specs = shd.ssm_cache_specs(cfg, mesh) if cfg.ssm_state else {}
    dp = shd.data_axes(mesh)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):
            s = kv_spec
        elif name == "conv":
            s = ssm_specs["conv"]
        elif name == "ssm":
            s = ssm_specs["ssm"]
        else:
            s = P(None, dp)
        if nd != len(s):
            s = P(*((None,) * (nd - len(s)) + tuple(s)))
        return shd.validate_spec(s, leaf.shape, mesh)

    def to_sh(path, leaf):
        return NamedSharding(mesh, spec_for(path, leaf))

    return jax.tree_util.tree_map_with_path(to_sh, cache_struct)


# "int4" has no jnp dtype: the string sentinel travels down to the pool
# builder as-is (payload dtype uint8 — DESIGN.md §10). Canonical map lives
# with the engines; re-exported here for flag parsing and older importers.
from repro.runtime.engine import KV_DTYPES  # noqa: E402


def generate(params, cfg, prompt_tokens, max_new: int, cache=None, qstate=None,
             sampling=None, eos_id=None, seed: int = 0, paged: bool = False,
             block_size: int = 16, prefill_chunk: int = 32,
             fused: bool | None = None, kv_dtype: str = "bf16",
             config=None):
    """Batched generation driver (example/tests scale).

    Attention token decoders (dense/moe) route through the continuous-batching
    engine (``runtime.engine``): each prompt row becomes a request, all rows
    decode through one jitted ragged step, and ``sampling`` (a
    ``sampling.SamplingParams`` or a per-row list of them) selects greedy /
    temperature / top-k / top-p per request. ``paged=True`` swaps in the
    block-paged engine (``runtime.engine.PagedEngine``): shared-prefix rows
    reuse cached KV blocks and long prompts prefill in ``prefill_chunk``-token
    chunks (DESIGN.md §3) — greedy outputs are identical to the slot engine;
    ``fused`` picks the paged attention path for decode steps AND prefill
    chunks (True = fused Pallas paged-decode + paged-prefill kernels,
    False = gather references, None = per cfg — DESIGN.md §3/§7);
    ``kv_dtype`` ("fp32" | "bf16" | "int8" | "int4") picks the KV storage
    format — "int8" (paged only) stores the pool as int8 codes with
    per-block per-kv-head scales, dequantized inside the read paths
    (DESIGN.md §6); "int4" (paged only) packs two values per byte with
    4-bit per-sub-block scale codes on top (DESIGN.md §10).
    ``paged=True`` additionally admits ssm/hybrid families through the
    architecture-agnostic StatePool (DESIGN.md §13; requires
    ``cfg.ssm_chunk == 1`` so block-granular state checkpoints reproduce the
    rectangular scan). ``config`` (an ``engine.EngineConfig``) overrides the
    per-field engine knobs wholesale — the canonical construction path.
    Other families keep the rectangular greedy loop — audio caches are
    neither slot-ragged nor block-paged, and vlm needs per-request
    vision_embeds plumbing the engine's prefill doesn't have yet.

    Returns (B, <= max_new) int32; rows are right-padded with ``eos_id`` (or 0)
    when EOS ends a row early, so the legacy rectangular contract holds.
    The fallback loop is greedy-only: passing ``sampling`` or ``eos_id`` for a
    family it can't honor raises rather than silently ignoring them (but it
    does honor fp ``kv_dtype`` values for the rectangular cache dtype).
    """
    B, S = prompt_tokens.shape
    engine_families = ("dense", "moe") + (("ssm", "hybrid") if paged else ())
    if cfg.family in engine_families and cfg.frontend is None and cache is None:
        from repro.runtime.engine import Engine, EngineConfig, PagedEngine
        from repro.runtime.sampling import GREEDY, SamplingParams

        if fused is not None and not paged:
            raise ValueError(
                "fused= selects the paged attention kernels (decode + prefill); pass "
                "paged=True (the slot engine would silently ignore it)"
            )
        if kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {sorted(KV_DTYPES)}, got {kv_dtype!r}")
        if kv_dtype in ("int8", "int4") and not paged:
            raise ValueError(
                f"kv_dtype={kv_dtype!r} is a paged-pool storage format (per-block "
                "scales — DESIGN.md §6/§10); pass paged=True"
            )
        if sampling is None:
            sampling = GREEDY
        per_row = list(sampling) if isinstance(sampling, (list, tuple)) else [sampling] * B
        if len(per_row) != B:
            raise ValueError(f"sampling list has {len(per_row)} entries for batch of {B}")
        if not all(isinstance(p, SamplingParams) for p in per_row):
            raise ValueError("sampling entries must be SamplingParams")
        if config is None:
            config = EngineConfig(
                max_slots=B, max_seq=S + max_new, block_size=block_size,
                prefill_chunk=prefill_chunk, eos_id=eos_id, kv_dtype=kv_dtype,
                fused=fused, seed=seed,
            )
        cls = PagedEngine if paged else Engine
        eng = cls(cfg, params, config, qstate=qstate)
        from repro.runtime.engine_core import Request

        uids = [eng.submit(Request(np.asarray(prompt_tokens[b]), max_new, per_row[b]))
                for b in range(B)]
        results = eng.run()
        pad = eos_id if eos_id is not None else 0
        out = np.full((B, max_new), pad, np.int32)
        for b, uid in enumerate(uids):
            toks = results[uid].tokens
            out[b, : len(toks)] = toks
        return jnp.asarray(out)

    if (sampling is not None or eos_id is not None or paged or fused is not None
            or kv_dtype in ("int8", "int4")):
        raise ValueError(
            f"sampling/eos_id/paged/fused/quantized kv_dtype require the engine path "
            f"(no explicit cache); the rectangular loop for family={cfg.family!r} is "
            f"greedy-only and unpaged"
        )
    prefill, decode = make_serve_fns(cfg, qstate)
    if cache is None:
        cache = init_cache(cfg, B, S + max_new, KV_DTYPES[kv_dtype])
    batch = {"tokens": prompt_tokens}
    if cfg.frontend == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.zeros((B, cfg.enc_seq, cfg.frontend_dim), jnp.float32)
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    for _ in range(max_new - 1):
        tok, cache, _ = decode(params, tok, cache)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)
