"""Serving runtime: prefill + decode step factories with sharded KV caches,
greedy/temperature sampling, and the EXAQ seq-parallel decode combine.

Cache sharding policy (runtime/sharding.py): batch over ('pod','data'),
kv-heads over 'model' when divisible, else sequence over 'model' (SP decode —
the softmax max/denominator combine across sequence shards is where EXAQ's
integer-histogram composition pays off; see DESIGN.md §2)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import build_model, default_qstate
from repro.runtime import sharding as shd


def make_serve_fns(cfg, qstate=None):
    model = build_model(cfg)
    qstate = qstate if qstate is not None else default_qstate(cfg)

    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache, qstate)
        return logits, cache

    def decode_step(params, tokens, cache):
        """tokens (B,1) -> (next_tokens (B,1), new_cache, logits)."""
        logits, cache = model.decode_step(params, tokens, cache, qstate)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache, logits

    return prefill_step, decode_step


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return build_model(cfg).init_cache(batch, max_seq, dtype)


def cache_shardings(cfg, mesh, cache_struct):
    """NamedShardings for the cache pytree."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    kv_spec = shd.cache_spec(cfg, mesh)
    ssm_specs = shd.ssm_cache_specs(cfg, mesh) if cfg.ssm_state else {}
    dp = shd.data_axes(mesh)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):
            s = kv_spec
        elif name == "conv":
            s = ssm_specs["conv"]
        elif name == "ssm":
            s = ssm_specs["ssm"]
        else:
            s = P(None, dp)
        if nd != len(s):
            s = P(*((None,) * (nd - len(s)) + tuple(s)))
        return shd.validate_spec(s, leaf.shape, mesh)

    def to_sh(path, leaf):
        return NamedSharding(mesh, spec_for(path, leaf))

    return jax.tree_util.tree_map_with_path(to_sh, cache_struct)


def generate(params, cfg, prompt_tokens, max_new: int, cache=None, qstate=None):
    """Simple batched greedy generation driver (example/tests scale)."""
    prefill, decode = make_serve_fns(cfg, qstate)
    B, S = prompt_tokens.shape
    if cache is None:
        cache = init_cache(cfg, B, S + max_new)
    batch = {"tokens": prompt_tokens}
    if cfg.frontend == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.zeros((B, cfg.enc_seq, cfg.frontend_dim), jnp.float32)
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    for _ in range(max_new - 1):
        tok, cache, _ = decode(params, tok, cache)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)
