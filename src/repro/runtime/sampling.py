"""Per-request token sampling for the serving engine.

One jitted ``sample_tokens`` call handles a whole slot batch with *per-row*
sampling parameters (temperature / top-k / top-p packed as arrays), so
heterogeneous requests share a single dispatch — the sampling analogue of the
ragged decode-attention step. Greedy is temperature == 0 (exact argmax, no
RNG consumed), which keeps exact-vs-EXAQ greedy parity checks deterministic.

Filtering order follows the common serving convention: temperature scale ->
top-k rank cut -> top-p nucleus cut (on the renormalized top-k distribution)
-> Gumbel-max draw over the surviving tokens.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# the speculative-decoding accept rule lives with the (jax-free) drafting
# layer so the host scheduler can use it without importing jax; re-exported
# here because sampling owns the "which token comes next" contract
from repro.runtime.speculative import greedy_accept_length  # noqa: F401

_NEG_BIG = -1e30


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0 => greedy (argmax); > 0 => softmax temperature.
    top_k: keep only the k highest-probability tokens (0 => disabled).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
           distribution with cumulative probability >= top_p (1.0 => disabled).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


def sample_temperature(
    logits: jnp.ndarray,
    temperature: jnp.ndarray,
    key: jax.Array,
) -> jnp.ndarray:
    """Temperature-only sampling: Gumbel-max over scaled logits, no sort.

    The filterless fast path for batches where no row uses top-k/top-p —
    O(B·V) instead of the full-vocab sort + cumsum of ``sample_tokens``.
    Rows with temperature == 0 still take the exact argmax.
    """
    logits = logits.astype(jnp.float32)
    greedy = temperature <= 0.0
    t = jnp.maximum(temperature, 1e-6)[:, None]
    gumbel = jax.random.gumbel(key, logits.shape, jnp.float32)
    sampled = jnp.argmax(logits / t + gumbel, axis=-1)
    return jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled).astype(jnp.int32)


def sample_tokens(
    logits: jnp.ndarray,
    temperature: jnp.ndarray,
    top_k: jnp.ndarray,
    top_p: jnp.ndarray,
    key: jax.Array,
) -> jnp.ndarray:
    """Sample one token per row. logits: (B, V); params: (B,) each -> (B,) int32.

    Rows with temperature == 0 take the exact argmax (ties break to the lowest
    index, matching ``jnp.argmax``); other rows draw via Gumbel-max over the
    top-k/top-p-filtered, temperature-scaled distribution.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy = temperature <= 0.0
    t = jnp.maximum(temperature, 1e-6)[:, None]

    # Sort once (descending); all filters become rank/cumsum predicates.
    sorted_logits, sorted_idx = jax.lax.top_k(logits, V)
    scaled = sorted_logits / t
    ranks = jnp.arange(V, dtype=jnp.int32)[None, :]
    k_eff = jnp.where(top_k > 0, top_k, V)[:, None]
    keep = ranks < k_eff
    probs = jax.nn.softmax(jnp.where(keep, scaled, _NEG_BIG), axis=-1)
    # nucleus: keep tokens whose *preceding* cumulative mass is < top_p, so the
    # boundary token is included and rank 0 always survives
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    keep = keep & (cum_before < top_p[:, None])
    masked = jnp.where(keep, scaled, _NEG_BIG)
    gumbel = jax.random.gumbel(key, (B, V), jnp.float32)
    choice = jnp.argmax(masked + gumbel, axis=-1)
    sampled = jnp.take_along_axis(sorted_idx, choice[:, None], axis=1)[:, 0]
    return jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled).astype(jnp.int32)
