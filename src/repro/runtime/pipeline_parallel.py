"""Pipeline parallelism over the 'pod' axis (GPipe schedule, shard_map).

At multi-pod scale the inter-pod DCN link is much slower than ICI; pipelining
layer stages across pods moves only the (B_micro, S, D) activation per tick
instead of synchronizing gradients for the whole model. This module provides
the forward GPipe schedule used for pipelined inference / as the building
block for interleaved training schedules:

  * stage s holds layers [s*L/P, (s+1)*L/P)  (params sharded over 'pod')
  * microbatches flow stage->stage via collective_permute (ppermute)
  * total ticks = n_micro + n_stages - 1 (the usual bubble)

All devices execute every tick (SPMD); off-schedule stages compute on garbage
and their results are masked — the standard single-program formulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, stage_params, x, mesh, axis: str = "pod", n_micro: int | None = None):
    """Run ``y = stage_{P-1}(...stage_0(x))`` pipelined over ``axis``.

    stage_fn(params_slice, h) -> h   (one stage's computation)
    stage_params: pytree with leading dim = n_stages (sharded over `axis`)
    x: (n_micro, B_micro, ...) microbatched input (replicated over `axis`)
    Returns y with x's shape.
    """
    n_stages = mesh.shape[axis]
    n_micro = n_micro or x.shape[0]
    assert x.shape[0] == n_micro

    def body(params_local, xs):
        # params_local: leading dim 1 (this stage's slice); xs: full microbatches
        params_me = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        h0 = jnp.zeros_like(xs[0])
        out = jnp.zeros_like(xs)

        def tick(carry, t):
            recv, out = carry
            # stage 0 ingests microbatch t (when on schedule)
            mb_in = jax.lax.dynamic_index_in_dim(xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            h = jnp.where(stage == 0, mb_in, recv)
            h = stage_fn(params_me, h)
            # last stage emits microbatch t - (n_stages - 1)
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(out, emit_idx, axis=0, keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(emit, h, cur), emit_idx, axis=0
            )
            nxt = jax.lax.ppermute(h, axis, perm)
            return (nxt, out), None

        (_, out), _ = jax.lax.scan(tick, (h0, out), jnp.arange(n_ticks))
        # only the last stage holds valid outputs; broadcast them to all stages
        out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(out, axis)

    other = tuple(a for a in mesh.axis_names if a != axis)
    del other
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)


def stack_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/P, ...) stage-stacked."""
    def re(p):
        L = p.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return p.reshape((n_stages, L // n_stages) + p.shape[1:])

    return jax.tree.map(re, layer_params)
