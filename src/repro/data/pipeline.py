"""Deterministic, restartable synthetic LM data pipeline.

Produces a reproducible token stream (Zipf-ish unigram mixture + local n-gram
structure so models actually have something to learn) keyed only by
(seed, step) — any worker can regenerate any batch, which is what makes
checkpoint/restart and elastic re-sharding trivial: the pipeline state is one
integer. Shards by (host, batch-slice) for multi-process launches.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


class SyntheticLMData:
    """Batches of (tokens, labels) with structure: a hidden Markov-ish chain
    over `n_clusters` latent states, each emitting from its own Zipf slice."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0,
                 n_clusters: int = 16):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.state = DataState(seed, 0)
        self.n_clusters = min(n_clusters, vocab_size)
        rng = np.random.default_rng(seed)
        # fixed emission tables (part of the "dataset", not the stream state)
        ranks = np.arange(1, vocab_size + 1)
        base = 1.0 / ranks**1.1
        self.emissions = np.stack([
            np.roll(base, rng.integers(0, vocab_size)) for _ in range(self.n_clusters)
        ])
        self.emissions /= self.emissions.sum(axis=1, keepdims=True)
        self.trans = rng.dirichlet(np.ones(self.n_clusters) * 0.3, size=self.n_clusters)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.state.seed, step))
        B, S = self.global_batch, self.seq_len
        z = rng.integers(0, self.n_clusters, size=B)
        toks = np.empty((B, S + 1), np.int32)
        # vectorized-ish: resample cluster every 32 tokens
        span = 32
        for s0 in range(0, S + 1, span):
            w = min(span, S + 1 - s0)
            probs = self.emissions[z]  # (B, V)
            cum = probs.cumsum(axis=1)
            u = rng.random((B, w))
            toks[:, s0 : s0 + w] = (u[..., None] > cum[:, None, :]).sum(-1)
            nz = np.empty_like(z)
            for c in range(self.n_clusters):
                m = z == c
                if m.any():
                    nz[m] = rng.choice(self.n_clusters, size=m.sum(), p=self.trans[c])
            z = nz
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def next_batch(self) -> dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # ----- checkpointable state -----
    def state_dict(self):
        return self.state.to_dict()

    def load_state_dict(self, d):
        self.state = DataState.from_dict(d)


def make_global_batch(host_batches: dict[str, np.ndarray], mesh, sharding) -> dict[str, jnp.ndarray]:
    """Place host arrays as globally-sharded jax arrays (single-host: device_put)."""
    return {k: jax.device_put(v, sharding) for k, v in host_batches.items()}
