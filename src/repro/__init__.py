"""repro: EXAQ (Exponent Aware Quantization) — production JAX/Pallas framework."""

__version__ = "0.1.0"
