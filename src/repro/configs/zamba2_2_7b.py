"""Zamba2-2.7B: Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; hf] 54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, hybrid_period=6,
    source="arXiv:2411.15242; hf",
))
