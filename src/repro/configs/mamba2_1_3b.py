"""Mamba2-1.3B: attention-free SSD. [arXiv:2405.21060] 48L d_model=2048 ssm_state=128.
EXAQ is inapplicable (no softmax on the hot path) — see DESIGN.md §4."""
from repro.configs.base import ModelConfig, QuantConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64,
    quant=QuantConfig(softmax_impl="exact"),
    source="arXiv:2405.21060",
))
