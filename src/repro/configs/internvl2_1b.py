"""InternVL2-1B: InternViT frontend (stub) + InternLM2-chat-1.8B-ish backbone.
[arXiv:2404.16821; hf] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    frontend="vlm", frontend_tokens=256, frontend_dim=1024,
    source="arXiv:2404.16821; hf",
))
