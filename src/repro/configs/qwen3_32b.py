"""Qwen3-32B (qk_norm, GQA). [hf:Qwen] 64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128, qk_norm=True,
    source="hf:Qwen/Qwen3-32B",
))
