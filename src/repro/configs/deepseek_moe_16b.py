"""DeepSeekMoE-16B: fine-grained experts, 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf] 28L d_model=2048 16H d_ff(expert)=1408 vocab=102400."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    source="arXiv:2401.06066; hf",
))
