"""Architecture registry: importing this package registers all configs."""

from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    internlm2_1_8b,
    internvl2_1b,
    llama1_7b,
    mamba2_1_3b,
    phi35_moe,
    qwen3_32b,
    stablelm_12b,
    whisper_large_v3,
    yi_6b,
    zamba2_2_7b,
)
from repro.configs.base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    QuantConfig,
    ShapeConfig,
    get_config,
    list_configs,
    shape_applicable,
)

__all__ = [
    "SHAPES", "ModelConfig", "MoEConfig", "QuantConfig", "ShapeConfig",
    "get_config", "list_configs", "shape_applicable",
]
