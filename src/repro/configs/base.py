"""Model / shape configuration system.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; the registry resolves ``--arch <id>``. Shapes are the four
assigned input-shape cells with applicability rules (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_expert: int = 0           # expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.25
    group_size: int = 256       # dispatch group (bounds the one-hot temp)


@dataclass(frozen=True)
class QuantConfig:
    """EXAQ as a first-class feature (paper §3-§4)."""

    softmax_impl: str = "exaq"   # exact | exaq | naive
    bits: int = 2
    clip_rule: str = "paper"     # paper (Table 1) | analytic (Eq. 14 re-derivation)
    sigma_default: float = 2.0   # fallback before calibration (Fig. 6 mid-range)
    use_fused_kernel: bool = False  # fused flash-EXAQ Pallas kernel (via shard_map under a mesh)
    sp_decode: bool = False      # seq-parallel decode: EXAQ integer-count combine over 'model'


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int               # 0 => attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // num_heads
    qk_norm: bool = False
    moe: MoEConfig | None = None
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128         # SSD scan chunk; 1 = per-token recurrence
                                 # (paged state serving requires 1, DESIGN.md §13)
    hybrid_period: int = 0       # zamba2: shared attn block every N mamba blocks
    enc_layers: int = 0          # whisper: encoder depth (enc-dec when > 0)
    enc_seq: int = 1500          # whisper: encoder frames (stub frontend)
    frontend: str | None = None  # vlm | audio
    frontend_tokens: int = 256   # vlm: patch embeddings replacing the prefix
    frontend_dim: int = 1024     # stub embedding dim before projection
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    quant: QuantConfig = field(default_factory=QuantConfig)
    remat: str = "full"          # none | full | dots
    pad_vocab_to: int = 1        # pad embed/head vocab dim (TP-divisibility; Megatron-style)
    attn_block_q: int = 512      # q-block size of the streamed attention scan
    attn_scores_bf16: bool = False  # materialize attention scores in bf16 (EXAQ makes this ~free)
    source: str = ""             # provenance note

    @property
    def padded_vocab(self) -> int:
        p = max(self.pad_vocab_to, 1)
        return -(-self.vocab_size // p) * p

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 4 if self.hybrid_period == 0 else 2 * self.hybrid_period),
            d_model=128,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.num_heads else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=16 if self.enc_layers else 1500,
            frontend_tokens=8 if self.frontend == "vlm" else self.frontend_tokens,
            frontend_dim=32 if self.frontend else self.frontend_dim,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
        )
        if self.moe is not None:
            small["moe"] = MoEConfig(
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                num_shared=min(self.moe.num_shared, 1),
                d_expert=64,
                capacity_factor=2.0,
                group_size=32,
            )
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def with_quant(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, quant=dataclasses.replace(self.quant, **kw))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k skipped: pure full-attention arch (quadratic at 0.5M)"
    return True, ""


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
