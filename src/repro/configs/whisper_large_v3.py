"""Whisper-large-v3: enc-dec, conv/mel frontend stubbed as precomputed frame embeddings.
[arXiv:2212.04356] 32L(enc)+32L(dec) d_model=1280 20H d_ff=5120 vocab=51866."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, head_dim=64,
    enc_layers=32, enc_seq=1500, frontend="audio", frontend_dim=128,
    source="arXiv:2212.04356",
))
