"""LLaMA-1-7B — the paper's own evaluation model (Table 2 reference arch)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama1-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000, head_dim=128,
    source="arXiv:2302.13971 (paper's eval model)",
))
