"""Phi-3.5-MoE-instruct (41.9B total / 6.6B active).
[hf:microsoft/Phi-3.5-MoE-instruct] 32L d_model=4096 32H (GQA kv=8) d_ff=6400, 16e top-2."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_expert=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
