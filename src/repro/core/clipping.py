"""Optimal clipping for exponent-aware quantization (paper §3).

Implements, in closed form, the paper's Eq. 14 objective

    MSE(C) = (Delta^2/12) * int_C^0 e^{2x} f(x) dx
           + int_{-inf}^C (e^C - e^x)^2 f(x) dx ,     Delta = -C / 2^M

for f = N(mu, sigma^2), using the exact Gaussian exponential-moment identity

    int_a^b e^{kx} N(x; mu, s^2) dx
        = e^{k*mu + k^2 s^2 / 2} * [Phi((b - mu - k s^2)/s) - Phi((a - mu - k s^2)/s)].

Two clip rules are exposed:

* ``paper``    — the paper's published Table-1 linear fits (production default;
                 faithful to the deployed method):
                     M=2:  C* = -1.66*sigma - 1.85
                     M=3:  C* = -1.75*sigma - 2.06
* ``analytic`` — exact minimization of Eq. 14 (our re-derivation; see DESIGN.md §1
                 for the documented discrepancy with Table 1).

Everything here is plain numpy (host-side, calibration-time); results feed the
quantizer as compile-time constants.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import numpy as np

# Paper Table 1 linear approximations: bits -> (slope, intercept).
PAPER_CLIP_COEFFS: dict[int, tuple[float, float]] = {
    2: (-1.66, -1.85),
    3: (-1.75, -2.06),
}

# Our closed-form re-derivation of Eq. 14 (mu=0), fitted over sigma in [0.9, 3.4].
# Regenerate with ``fit_linear_rule`` / benchmarks/bench_clipping.py.
REDERIVED_CLIP_COEFFS: dict[int, tuple[float, float]] = {
    2: (-0.494, -1.058),
    3: (-0.583, -1.276),
    4: (-0.661, -1.468),
}

SIGMA_FIT_RANGE = (0.9, 3.4)  # paper: "where most standard deviations occur" (Fig. 6)


def _phi(z: np.ndarray | float) -> np.ndarray | float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + np.vectorize(math.erf)(np.asarray(z) / math.sqrt(2.0)))


def gaussian_exp_moment(k: float, a: float, b: float, mu: float, sigma: float) -> float:
    """int_a^b e^{k x} N(x; mu, sigma^2) dx, exact."""
    pref = math.exp(k * mu + 0.5 * k * k * sigma * sigma)
    m = mu + k * sigma * sigma
    hi = _phi((b - m) / sigma)
    lo = 0.0 if a == -np.inf else _phi((a - m) / sigma)
    return float(pref * (hi - lo))


def exaq_mse(C: float, sigma: float, bits: int, mu: float = 0.0) -> float:
    """Paper Eq. 14, exact closed form. C must be < 0."""
    if C >= 0:
        return float("inf")
    delta = -C / (2**bits)
    quant = (delta**2 / 12.0) * gaussian_exp_moment(2.0, C, 0.0, mu, sigma)
    # clip term: e^{2C} P(x<C) - 2 e^C E[e^x; x<C] + E[e^{2x}; x<C]
    p_below = float(_phi((C - mu) / sigma))
    clip = (
        math.exp(2.0 * C) * p_below
        - 2.0 * math.exp(C) * gaussian_exp_moment(1.0, -np.inf, C, mu, sigma)
        + gaussian_exp_moment(2.0, -np.inf, C, mu, sigma)
    )
    return quant + clip


def optimal_clip_analytic(
    sigma: float, bits: int, mu: float = 0.0, *, grid: int = 2048, refine: int = 48
) -> float:
    """Numerically minimize Eq. 14 over C (coarse grid + golden-section refine)."""
    lo = mu - 12.0 * sigma - 8.0
    hi = -1e-4
    Cs = np.linspace(lo, hi, grid)
    vals = np.array([exaq_mse(float(c), sigma, bits, mu) for c in Cs])
    i = int(np.argmin(vals))
    a = Cs[max(i - 1, 0)]
    b = Cs[min(i + 1, grid - 1)]
    # golden-section refinement
    gr = (math.sqrt(5.0) - 1.0) / 2.0
    c = b - gr * (b - a)
    d = a + gr * (b - a)
    for _ in range(refine):
        if exaq_mse(c, sigma, bits, mu) < exaq_mse(d, sigma, bits, mu):
            b = d
        else:
            a = c
        c = b - gr * (b - a)
        d = a + gr * (b - a)
    return float(0.5 * (a + b))


def fit_linear_rule(
    bits: int,
    mu: float = 0.0,
    sigma_range: tuple[float, float] = SIGMA_FIT_RANGE,
    n: int = 26,
) -> tuple[float, float]:
    """Linear fit C*(sigma) ~= slope*sigma + intercept over the practical range."""
    sigmas = np.linspace(sigma_range[0], sigma_range[1], n)
    cstars = np.array([optimal_clip_analytic(float(s), bits, mu) for s in sigmas])
    A = np.vstack([sigmas, np.ones_like(sigmas)]).T
    slope, intercept = np.linalg.lstsq(A, cstars, rcond=None)[0]
    return float(slope), float(intercept)


def simulate_optimal_clip(
    sigma: float,
    bits: int,
    *,
    n: int = 1000,
    trials: int = 64,
    seed: int = 0,
    subtract_max: bool = False,
) -> float:
    """Monte-Carlo cross-check of the analytic solver (paper Fig. 3 procedure)."""
    rng = np.random.default_rng(seed)
    Cs = np.linspace(-10.0 * sigma - 8.0, -0.05, 400)
    tot = np.zeros_like(Cs)
    levels = 2**bits
    for _ in range(trials):
        x = rng.normal(0.0, sigma, n)
        if subtract_max:
            x = x - x.max()
        else:
            x = np.minimum(x, 0.0)  # model only the x<=0 region, as in Eq. 14
        ex = np.exp(x)
        for i, C in enumerate(Cs):
            delta = -C / levels
            codes = np.clip(np.floor((np.maximum(x, C) - C) / delta), 0, levels - 1)
            xq = C + (codes + 0.5) * delta
            tot[i] += np.mean((np.exp(xq) - ex) ** 2)
    return float(Cs[int(np.argmin(tot))])


@dataclass(frozen=True)
class ClipRule:
    """A resolved clipping rule: sigma -> C."""

    kind: str  # "paper" | "analytic" | "naive"
    bits: int

    def __call__(self, sigma: float, *, mu: float = 0.0) -> float:
        if self.kind == "paper":
            if self.bits in PAPER_CLIP_COEFFS:
                s, i = PAPER_CLIP_COEFFS[self.bits]
            else:  # paper only publishes M=2,3; fall back to analytic beyond
                return optimal_clip_analytic(sigma, self.bits, mu)
            return s * sigma + i
        if self.kind == "analytic":
            return optimal_clip_analytic(sigma, self.bits, mu)
        raise ValueError(f"unknown clip rule {self.kind!r}")


@functools.lru_cache(maxsize=None)
def get_clip_rule(kind: str, bits: int) -> ClipRule:
    return ClipRule(kind, bits)


def naive_clip_from_minmax(xmin: float, xmax: float) -> float:
    """Paper's NAIVE baseline: clip = average of tensor min and max.

    With max-subtracted inputs xmax == 0, so C = xmin/2.
    """
    return 0.5 * (xmin + xmax)
