"""Calibration: collect softmax-input statistics (paper §5.1.1).

The paper calibrates on ~100 samples (25 iters x batch 4), collecting the
standard deviation of each softmax-input tensor; Table 1 then maps sigma -> C.

We implement a Welford-style streaming collector keyed by site name
(layer index / attention kind). Masked positions are excluded — a -inf row
tail would otherwise destroy sigma. Stats are computed on the max-subtracted
tensor (shift-invariant: per-row max subtraction changes the mean, not the
within-row spread; we track both the global std and the mean row-std).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import QuantParams, exaq_params, naive_params


@dataclass
class SiteStats:
    """Streaming moments for one softmax site."""

    count: float = 0.0
    mean: float = 0.0
    m2: float = 0.0
    min: float = float("inf")
    max: float = float("-inf")

    def update(self, values: np.ndarray) -> None:
        v = np.asarray(values, dtype=np.float64).ravel()
        if v.size == 0:
            return
        n_b = float(v.size)
        mean_b = float(v.mean())
        m2_b = float(((v - mean_b) ** 2).sum())
        n_a, mean_a, m2_a = self.count, self.mean, self.m2
        n = n_a + n_b
        d = mean_b - mean_a
        self.mean = mean_a + d * n_b / n
        self.m2 = m2_a + m2_b + d * d * n_a * n_b / n
        self.count = n
        self.min = min(self.min, float(v.min()))
        self.max = max(self.max, float(v.max()))

    @property
    def std(self) -> float:
        return float(np.sqrt(self.m2 / max(self.count, 1.0)))


@dataclass
class Calibrator:
    """Collects per-site sigma; emits QuantParams for EXAQ / NAIVE."""

    stats: dict[str, SiteStats] = field(default_factory=dict)

    def observe(self, site: str, x: jnp.ndarray, where: jnp.ndarray | None = None) -> None:
        """x: softmax input logits (pre max-subtraction ok; we subtract)."""
        x = jnp.asarray(x, dtype=jnp.float32)
        if where is not None:
            big_neg = jnp.full_like(x, -1e30)
            x = jnp.where(where, x, big_neg)
        shifted = x - jnp.max(x, axis=-1, keepdims=True)
        arr = np.asarray(jax.device_get(shifted), dtype=np.float64)
        if where is not None:
            marr = np.asarray(jax.device_get(where)).astype(bool)
            arr = arr[marr]
        self.stats.setdefault(site, SiteStats()).update(arr)

    def sigma(self, site: str) -> float:
        return self.stats[site].std

    def exaq_params(self, site: str, bits: int, rule: str = "paper") -> QuantParams:
        return exaq_params(self.sigma(site), bits, rule=rule)

    def naive_params(self, site: str, bits: int) -> QuantParams:
        s = self.stats[site]
        return naive_params(s.min, bits, xmax=min(s.max, 0.0))

    # --- persistence (part of the serving config artifact) ---
    def to_json(self) -> str:
        return json.dumps(
            {
                k: {"count": v.count, "mean": v.mean, "m2": v.m2, "min": v.min, "max": v.max}
                for k, v in self.stats.items()
            }
        )

    @classmethod
    def from_json(cls, text: str) -> "Calibrator":
        c = cls()
        for k, d in json.loads(text).items():
            c.stats[k] = SiteStats(**d)
        return c

    def summary(self) -> dict[str, dict[str, float]]:
        return {k: {"sigma": v.std, "min": v.min, "count": v.count} for k, v in self.stats.items()}
