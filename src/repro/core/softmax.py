"""Softmax variants (paper Algo. 1 / Algo. 2), pure-jnp reference semantics.

These are the *functional* definitions used across the framework; the Pallas
kernels in ``repro.kernels`` implement the same math with explicit VMEM tiling
and are verified against these references.

Masking: the paper does not treat masked (=-inf) positions; clipping would map
them to C and leak weight. We zero masked lanes after the LUT (DESIGN.md §5.4).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantizer import QuantParams, encode, histogram_denominator, lut_lookup

_NEG_BIG = -1e30


def exact_softmax(x: jnp.ndarray, axis: int = -1, where: jnp.ndarray | None = None) -> jnp.ndarray:
    """Paper Algo. 1 (numerically stable softmax), optional boolean mask."""
    if where is not None:
        x = jnp.where(where, x, _NEG_BIG)
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    if where is not None:
        e = jnp.where(where, e, 0.0)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def quantized_softmax(
    x: jnp.ndarray,
    params: QuantParams,
    axis: int = -1,
    where: jnp.ndarray | None = None,
    use_histogram: bool = True,
) -> jnp.ndarray:
    """Paper Algo. 2: quantize -> LUT exp -> (histogram) accumulate -> normalize.

    Works for both EXAQ and NAIVE — they differ only in how ``params.clip`` was
    chosen. ``use_histogram=True`` exercises the LUT_sum-equivalent accumulation
    path; False sums the LUT outputs directly (identical result, different
    op mix — kept for ablation).
    """
    if where is not None:
        x = jnp.where(where, x, _NEG_BIG)
    x = x - jnp.max(x, axis=axis, keepdims=True)
    codes = encode(x, params)
    lut = params.lut(dtype=x.dtype)
    e = lut_lookup(codes, lut)
    if where is not None:
        e = jnp.where(where, e, 0.0)
    if use_histogram:
        denom = histogram_denominator(codes, lut, axis=axis, where=where)
        denom = jnp.expand_dims(denom, axis)
    else:
        denom = jnp.sum(e, axis=axis, keepdims=True)
    return e / denom


def softmax(
    x: jnp.ndarray,
    impl: str = "exact",
    params: QuantParams | None = None,
    axis: int = -1,
    where: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Dispatch: impl in {"exact", "exaq", "naive"}. exaq/naive need params."""
    if impl == "exact":
        return exact_softmax(x, axis=axis, where=where)
    if impl in ("exaq", "naive"):
        assert params is not None, f"{impl} softmax requires QuantParams"
        return quantized_softmax(x, params, axis=axis, where=where)
    raise ValueError(f"unknown softmax impl {impl!r}")
