"""Uniform input-domain quantizer for softmax inputs (paper §3/§4, Algo. 2).

The quantizer maps max-subtracted logits x <= 0 into M-bit codes over [C, 0]:

    Delta = -C / 2^M
    code(x) = clip( floor((x - C)/Delta), 0, 2^M - 1 )
    level_k = C + (k + 1/2) * Delta            (mid-rise; see DESIGN.md §8)
    LUT_exp[k] = exp(level_k)

Because softmax is shift-invariant, only the partition {C, Delta} affects the
normalized output — the mid-rise level placement matches the paper's uniform
noise model exp(x + eps), eps ~ U[-Delta/2, Delta/2].

All parameters are static (calibration-time) scalars so XLA folds them; the
runtime cost is one FMA + floor + clamp per element.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp
import numpy as np

from repro.core import clipping


@dataclass(frozen=True)
class QuantParams:
    """Static quantization parameters for one softmax site."""

    bits: int
    clip: float  # C < 0

    @property
    def levels(self) -> int:
        return 2**self.bits

    @property
    def delta(self) -> float:
        return -self.clip / self.levels

    def lut(self, dtype=jnp.float32) -> jnp.ndarray:
        """LUT_exp: exp of each mid-rise level, ascending in code order."""
        k = np.arange(self.levels, dtype=np.float64)
        vals = np.exp(self.clip + (k + 0.5) * (-self.clip / self.levels))
        return jnp.asarray(vals, dtype=dtype)

    def lut_np(self) -> np.ndarray:
        k = np.arange(self.levels, dtype=np.float64)
        return np.exp(self.clip + (k + 0.5) * (-self.clip / self.levels))


def exaq_params(sigma: float, bits: int, rule: str = "paper") -> QuantParams:
    """EXAQ parameters from calibrated sigma (paper Table 1 / Eq. 14)."""
    C = clipping.get_clip_rule(rule, bits)(float(sigma))
    return QuantParams(bits=bits, clip=float(C))


def naive_params(xmin: float, bits: int, xmax: float = 0.0) -> QuantParams:
    """Paper's NAIVE baseline: C = (min + max)/2 (== min/2 after max-subtract)."""
    C = clipping.naive_clip_from_minmax(float(xmin), float(xmax))
    C = min(C, -1e-6)  # keep the range non-degenerate
    return QuantParams(bits=bits, clip=float(C))


def encode(x: jnp.ndarray, params: QuantParams) -> jnp.ndarray:
    """x (already max-subtracted, x<=0) -> int32 codes in [0, 2^M)."""
    inv_delta = 1.0 / params.delta
    codes = jnp.floor((x - params.clip) * inv_delta)
    return jnp.clip(codes, 0, params.levels - 1).astype(jnp.int32)


def decode(codes: jnp.ndarray, params: QuantParams) -> jnp.ndarray:
    """codes -> mid-rise dequantized input values (for analysis / oracles)."""
    return params.clip + (codes.astype(jnp.float32) + 0.5) * params.delta


def lut_lookup(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """e^Q(x) via the tiny LUT. jnp.take lowers to a gather; on TPU with a
    4/8-entry table XLA emits vector selects (no transcendental unit)."""
    return jnp.take(lut, codes, axis=0)


def histogram_denominator(
    codes: jnp.ndarray, lut: jnp.ndarray, axis: int = -1, where: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Denominator accumulation via the histogram trick (TPU analogue of LUT_sum).

    sum_i e^{Q(x_i)} == sum_k count_k * LUT_exp[k]; counting 2-bit codes is
    integer compare+add (VPU lanes), the final contraction is 2^M FMAs per row.
    """
    levels = lut.shape[0]
    one_hot = codes[..., None] == jnp.arange(levels, dtype=codes.dtype)
    if where is not None:
        one_hot = one_hot & where[..., None]
    counts = jnp.sum(one_hot, axis=axis if axis >= 0 else axis - 1, dtype=jnp.int32)
    return jnp.einsum("...k,k->...", counts.astype(lut.dtype), lut)


def with_clip(params: QuantParams, clip: float) -> QuantParams:
    return replace(params, clip=float(clip))
