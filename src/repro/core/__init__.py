"""EXAQ core: the paper's contribution (clipping, quantizer, softmax, calibration)."""

from repro.core.calibration import Calibrator
from repro.core.clipping import (
    PAPER_CLIP_COEFFS,
    REDERIVED_CLIP_COEFFS,
    exaq_mse,
    fit_linear_rule,
    get_clip_rule,
    optimal_clip_analytic,
    simulate_optimal_clip,
)
from repro.core.quantizer import (
    QuantParams,
    decode,
    encode,
    exaq_params,
    histogram_denominator,
    lut_lookup,
    naive_params,
)
from repro.core.softmax import exact_softmax, quantized_softmax, softmax

__all__ = [
    "Calibrator",
    "PAPER_CLIP_COEFFS",
    "REDERIVED_CLIP_COEFFS",
    "QuantParams",
    "decode",
    "encode",
    "exaq_mse",
    "exaq_params",
    "exact_softmax",
    "fit_linear_rule",
    "get_clip_rule",
    "histogram_denominator",
    "lut_lookup",
    "naive_params",
    "optimal_clip_analytic",
    "quantized_softmax",
    "simulate_optimal_clip",
    "softmax",
]
