"""AdamW with decoupled weight decay, global-norm clipping and fp32 master
moments — self-contained (no optax dependency), pytree-generic."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def _lr(self, count):
        return self.lr(count) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) if self.clip_norm else 1.0
        count = state["count"] + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(g, m, v, p):
            g = g * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * g * g
            step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + self.eps)
            # decoupled weight decay on matrices only (dim >= 2)
            if p.ndim >= 2 and self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        new_state = {"m": m, "v": v, "count": count}
        return updates, new_state, {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
