"""Gradient compression for cross-pod sync: int8 quantization with error
feedback (EF-SGD style).

At 1000+ nodes the inter-pod (DCN) links are the gradient-sync bottleneck;
int8 + EF cuts those bytes 4x with provably-vanishing bias. Integration point:
the hierarchical sync in runtime/train.py — XLA handles the fast intra-pod
psum; the explicit shard_map all-reduce over the 'pod' axis goes through
``compressed_psum``. Pure-DP small-scale usage is demonstrated in
tests/test_compression.py and examples/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, key=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 with optional stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, x.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jnp.ndarray, err: jnp.ndarray, key=None):
    """Error-feedback compression: returns (q, scale, new_err)."""
    corrected = g + err
    q, scale = quantize_int8(corrected, key)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str, key=None):
    """All-reduce a gradient over `axis_name` in int8 with error feedback.

    int32 accumulation of int8 payloads avoids overflow up to 2^24 members;
    scales are all-reduced in fp32 (one scalar). Must run inside shard_map.
    Returns (mean_gradient, new_err).
    """
    q, scale, new_err = ef_compress(g, err, key)
    n = jax.lax.psum(1, axis_name)
    summed = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)  # int32 wire format
    # each member has its own scale; reconstruct with the mean scale after
    # normalizing payloads to a shared scale (max over members).
    smax = jax.lax.pmax(scale, axis_name)
    rescaled = jax.lax.psum(jnp.round(q.astype(jnp.float32) * (scale / smax)), axis_name)
    mean = rescaled * smax / n
    del summed
    return mean, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
