"""Fault-tolerant checkpointing: atomic writes, keep-k GC, async writer,
mesh-agnostic restore (params are saved as logical host arrays and re-sharded
on load, so a job can resume on a different mesh — the elastic path).

Format: one directory per step containing
  meta.json           (step, config name, data state, rng, tree structure)
  arrays.npz          (flat leaf arrays keyed by path)
Atomicity: write to `<dir>.tmp`, fsync, rename. A `latest` symlink is updated
last, so a crash mid-write can never corrupt the restore point.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            p.key if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in leaves_paths:
        key = "/".join(
            p.key if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p) for p in path
        )
        arr = flat[key]
        if hasattr(tmpl, "dtype"):
            arr = arr.astype(tmpl.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state, extra_meta: dict | None = None, block: bool = False):
        """state: pytree (params/opt/whatever). extra_meta: json-serializable."""
        flat = _flatten(state)  # device_get happens on the caller thread
        meta = {"step": int(step), "time": time.time(), **(extra_meta or {})}
        if self.async_write and not block:
            self.wait()
            self._thread = threading.Thread(target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest = os.path.join(self.dir, "latest")
        tmp_link = latest + ".tmp"
        if os.path.lexists(tmp_link):
            os.remove(tmp_link)
        os.symlink(os.path.basename(final), tmp_link)
        os.replace(tmp_link, latest)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "latest")
        if not os.path.exists(latest):
            return None
        name = os.path.basename(os.path.realpath(latest))
        return int(name.split("_")[1])

    def restore(self, template, step: int | None = None, shardings=None):
        """template: pytree of arrays/ShapeDtypeStructs with the right structure.
        shardings: optional matching pytree of NamedSharding for elastic
        re-placement onto the current mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        d = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        state = _unflatten(template, flat)
        if shardings is not None:
            state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, shardings)
        return state, meta
