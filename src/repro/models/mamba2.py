"""Mamba2 (SSD — state-space duality, arXiv:2405.21060), chunked JAX form.

Train/prefill: chunked algorithm — intra-chunk quadratic term + inter-chunk
state recurrence via lax.scan (never materializes the (S, S) kernel).
Decode: O(1) recurrent state update. ng=1 (single B/C group), as in the
released 1.3B model. EXAQ is inapplicable here (no softmax) — DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, silu, truncated_normal_init
from repro.runtime.sharding import shard_activation


def conv_channels(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba(key, cfg, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    ch = conv_channels(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * din + 2 * ds + nh  # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
    return {
        "in_proj": truncated_normal_init(ks[0], (d, proj_out), d**-0.5, dtype),
        "conv_w": truncated_normal_init(ks[1], (cfg.ssm_conv_width, ch), 0.3, dtype),
        "conv_b": jnp.zeros((ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "D_skip": jnp.ones((nh,), jnp.float32),
        "ssm_norm": jnp.ones((din,), dtype),
        "out_proj": truncated_normal_init(ks[3], (din, d), din**-0.5, dtype),
    }


def _causal_conv_window(cat: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over a window with explicit history rows.

    cat: (B, w-1+C, ch) = [history rows | C chunk rows] -> (B, C, ch). The
    shifted-add order is the ONE conv summation in the codebase — train,
    prefill, paged chunk prefill, and decode all reduce to it, so the paged
    state planes reproduce the rectangular path bitwise (DESIGN.md §13).
    """
    width = w.shape[0]
    C = cat.shape[1] - (width - 1)
    out = cat[:, width - 1 :] * w[-1][None, None, :]
    for i in range(1, width):
        out = out + cat[:, width - 1 - i : width - 1 - i + C] * w[-1 - i][None, None, :]
    return out + b[None, None, :]


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds (width <= 4 — fuses on the VPU)."""
    width = w.shape[0]
    cat = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    return _causal_conv_window(cat, w, b)


def _split_proj(proj: jnp.ndarray, cfg):
    din, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din : 2 * din + 2 * ds]
    dt_raw = proj[..., 2 * din + 2 * ds :]
    return z, xbc, dt_raw


def _ssd_chunk(h, xs, dt, a, Bm, Cm):
    """One chunk. h: (b, nh, hd, ds); xs: (b, Q, nh, hd); dt/a: (b, Q, nh);
    Bm/Cm: (b, Q, ds). Returns (h_new, y)."""
    cum = jnp.cumsum(a, axis=1)  # (b, Q, nh)
    # inter-chunk: contribution of the carried state
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bqs,bnhs->bqnh", Cm, h)
    # intra-chunk quadratic part
    L = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (b, i, j, nh)
    ii = jnp.arange(cum.shape[1])
    L = jnp.where((ii[:, None] >= ii[None, :])[None, :, :, None], L, 0.0)
    CB = jnp.einsum("bis,bjs->bij", Cm, Bm)
    M = CB[..., None] * L * dt[:, None, :, :]  # (b, i, j, nh)
    y_intra = jnp.einsum("bijn,bjnh->binh", M, xs)
    # state update
    decay_end = jnp.exp(cum[:, -1])  # (b, nh)
    w = dt * jnp.exp(cum[:, -1:, :] - cum)  # (b, Q, nh)
    h_add = jnp.einsum("bqn,bqs,bqnh->bnhs", w, Bm, xs)
    h_new = decay_end[:, :, None, None] * h + h_add
    return h_new, y_inter + y_intra


def ssd_scan(xs, dt, a, Bm, Cm, h0, chunk: int):
    """Full sequence via scan over chunks. xs: (b, S, nh, hd). Returns y, h_T."""
    b, S, nh, hd = xs.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

    def body(h, xs_t):
        return _ssd_chunk(h, *xs_t)

    h_T, ys = jax.lax.scan(body, h0, (to_chunks(xs), to_chunks(dt), to_chunks(a), to_chunks(Bm), to_chunks(Cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, nh, hd)[:, :S]
    return y, h_T


def _ssd_scan_with_states(xs, dt, a, Bm, Cm, h0):
    """Per-token (chunk=1) SSD scan that also stacks the state after every
    step — bitwise the same per-step math as ``ssd_scan(..., chunk=1)``, which
    is what the block-granular checkpoints of the paged state pool need
    (DESIGN.md §13). xs: (b, S, nh, hd). Returns (y, h_T, hs) with hs of
    shape (S, b, nh, hd, ds)."""
    b, S, nh, hd = xs.shape

    def to_steps(t):
        return jnp.moveaxis(t.reshape((b, S, 1) + t.shape[2:]), 1, 0)

    def body(h, xs_t):
        h_new, y = _ssd_chunk(h, *xs_t)
        return h_new, (y, h_new)

    h_T, (ys, hs) = jax.lax.scan(
        body, h0, (to_steps(xs), to_steps(dt), to_steps(a), to_steps(Bm), to_steps(Cm))
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, S, nh, hd)
    return y, h_T, hs


def mamba_forward(
    params: dict,
    x: jnp.ndarray,
    cfg,
    *,
    mode: str = "train",
    cache: dict | None = None,
    chunk: int = 128,
):
    """x: (B, S, D). mode train/prefill returns (out, cache|None);
    mode decode expects S==1 and a cache {'conv': (B,w-1,ch), 'ssm': (B,nh,hd,ds)}."""
    din, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, S, _ = x.shape
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)

    if mode == "decode":
        conv_prev = cache["conv"]  # (B, w-1, ch)
        full = jnp.concatenate([conv_prev.astype(xbc.dtype), xbc], axis=1)  # (B, w, ch)
        # same shifted-add summation order as the prefill conv, so a decode
        # step is bitwise one more row of the chunked path (DESIGN.md §13)
        xbc_t = silu(_causal_conv_window(full, params["conv_w"].astype(xbc.dtype),
                                         params["conv_b"].astype(xbc.dtype)))  # (B, 1, ch)
        new_conv = full[:, 1:]
    else:
        xbc_t = silu(_causal_conv(xbc, params["conv_w"].astype(xbc.dtype), params["conv_b"].astype(xbc.dtype)))
        new_conv = xbc[:, -(cfg.ssm_conv_width - 1) :] if S >= cfg.ssm_conv_width - 1 else jnp.pad(
            xbc, ((0, 0), (cfg.ssm_conv_width - 1 - S, 0), (0, 0))
        )

    xs = xbc_t[..., :din].reshape(B, -1, nh, hd).astype(jnp.float32)
    Bm = xbc_t[..., din : din + ds].astype(jnp.float32)
    Cm = xbc_t[..., din + ds :].astype(jnp.float32)
    xs = shard_activation(xs, "ssm_heads")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])  # (B,S,nh)
    a = -jnp.exp(params["A_log"])[None, None, :] * dt  # log-decay <= 0

    if mode == "decode":
        h = cache["ssm"].astype(jnp.float32)  # (B, nh, hd, ds)
        # one _ssd_chunk step (Q=1): bitwise identical to ssd_scan(chunk=1),
        # so decode, chunked prefill, and preempt-recompute all walk the same
        # per-token trajectory (DESIGN.md §13)
        h_T, y = _ssd_chunk(h, xs, dt, a, Bm, Cm)  # y: (B, 1, nh, hd)
    else:
        h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
        y, h_T = ssd_scan(xs, dt, a, Bm, Cm, h0, chunk)

    y = y + params["D_skip"][None, None, :, None] * xs[:, : y.shape[1]]
    y = y.reshape(B, -1, din).astype(x.dtype)
    y = rmsnorm(y * silu(z), params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,dp->bsp", y, params["out_proj"].astype(x.dtype))
    new_cache = {"conv": new_conv, "ssm": h_T.astype(jnp.float32)}
    return out, new_cache


def mamba_paged_prefill_chunk(params, x, cfg, conv_prev, h0, n, *, block_size):
    """One paged prefill chunk of a Mamba2 layer with block-granular state
    checkpoints (DESIGN.md §13).

    x: (1, C, D) right-padded activations for global positions
    [start, start+C); conv_prev: (1, w-1, ch) raw (pre-silu, pre-conv)
    tail rows through position start-1 (zeros when start == 0); h0:
    (1, nh, hd, ds) SSD state through start-1; n: live rows of the chunk
    (rows >= n are pads beyond the prompt).

    The chunk runs the per-token (chunk=1) SSD recurrence, so its math is
    bitwise identical to both the decode path and ``ssd_scan(chunk=1)``.
    Pad rows are masked via dt = 0: their decay is exp(0) = 1 and their
    input weight is 0, so the carried state passes through them bitwise.

    Returns (out (1, C, D), conv_ckpts (C//bs, w-1, ch), ssm_ckpts
    (C//bs, nh, hd, ds)); checkpoint cb holds the conv tail / SSD state
    through the last live position <= start + (cb+1)*bs - 1 — i.e. the
    state a resume or prefix hit at that block boundary must see.
    """
    din, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, C, _ = x.shape
    width = cfg.ssm_conv_width
    bs = block_size
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)

    cat = jnp.concatenate([conv_prev.astype(xbc.dtype), xbc], axis=1)  # (1, w-1+C, ch)
    xbc_t = silu(_causal_conv_window(cat, params["conv_w"].astype(xbc.dtype),
                                     params["conv_b"].astype(xbc.dtype)))

    xs = xbc_t[..., :din].reshape(B, -1, nh, hd).astype(jnp.float32)
    Bm = xbc_t[..., din : din + ds].astype(jnp.float32)
    Cm = xbc_t[..., din + ds :].astype(jnp.float32)
    xs = shard_activation(xs, "ssm_heads")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
    live = (jnp.arange(C) < n)[None, :, None]
    dt = jnp.where(live, dt, 0.0)  # pads: exp(0)=1 decay, zero input weight
    a = -jnp.exp(params["A_log"])[None, None, :] * dt

    y, _, hs = _ssd_scan_with_states(xs, dt, a, Bm, Cm, h0.astype(jnp.float32))

    # block-granular checkpoints (C // bs of them; C % bs == 0 is enforced by
    # the engine's prefill_chunk % block_size gate)
    ends = (jnp.arange(C // bs) + 1) * bs - 1
    ssm_ckpts = hs[ends, 0]                                  # (C//bs, nh, hd, ds)
    e_cb = jnp.minimum((jnp.arange(C // bs) + 1) * bs, n)    # last live row + 1
    rows = e_cb[:, None] + jnp.arange(width - 1)[None, :]    # cat row indices
    conv_ckpts = cat[0][rows]                                # (C//bs, w-1, ch)

    y = y + params["D_skip"][None, None, :, None] * xs
    y = y.reshape(B, -1, din).astype(x.dtype)
    y = rmsnorm(y * silu(z), params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,dp->bsp", y, params["out_proj"].astype(x.dtype))
    return out, conv_ckpts, ssm_ckpts
