"""Mamba2 (SSD — state-space duality, arXiv:2405.21060), chunked JAX form.

Train/prefill: chunked algorithm — intra-chunk quadratic term + inter-chunk
state recurrence via lax.scan (never materializes the (S, S) kernel).
Decode: O(1) recurrent state update. ng=1 (single B/C group), as in the
released 1.3B model. EXAQ is inapplicable here (no softmax) — DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, silu, truncated_normal_init
from repro.runtime.sharding import shard_activation


def conv_channels(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba(key, cfg, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    ch = conv_channels(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * din + 2 * ds + nh  # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
    return {
        "in_proj": truncated_normal_init(ks[0], (d, proj_out), d**-0.5, dtype),
        "conv_w": truncated_normal_init(ks[1], (cfg.ssm_conv_width, ch), 0.3, dtype),
        "conv_b": jnp.zeros((ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "D_skip": jnp.ones((nh,), jnp.float32),
        "ssm_norm": jnp.ones((din,), dtype),
        "out_proj": truncated_normal_init(ks[3], (din, d), din**-0.5, dtype),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds (width <= 4 — fuses on the VPU)."""
    width = w.shape[0]
    out = xbc * w[-1][None, None, :]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[-1 - i][None, None, :]
    return out + b[None, None, :]


def _split_proj(proj: jnp.ndarray, cfg):
    din, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din : 2 * din + 2 * ds]
    dt_raw = proj[..., 2 * din + 2 * ds :]
    return z, xbc, dt_raw


def _ssd_chunk(h, xs, dt, a, Bm, Cm):
    """One chunk. h: (b, nh, hd, ds); xs: (b, Q, nh, hd); dt/a: (b, Q, nh);
    Bm/Cm: (b, Q, ds). Returns (h_new, y)."""
    cum = jnp.cumsum(a, axis=1)  # (b, Q, nh)
    # inter-chunk: contribution of the carried state
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bqs,bnhs->bqnh", Cm, h)
    # intra-chunk quadratic part
    L = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (b, i, j, nh)
    ii = jnp.arange(cum.shape[1])
    L = jnp.where((ii[:, None] >= ii[None, :])[None, :, :, None], L, 0.0)
    CB = jnp.einsum("bis,bjs->bij", Cm, Bm)
    M = CB[..., None] * L * dt[:, None, :, :]  # (b, i, j, nh)
    y_intra = jnp.einsum("bijn,bjnh->binh", M, xs)
    # state update
    decay_end = jnp.exp(cum[:, -1])  # (b, nh)
    w = dt * jnp.exp(cum[:, -1:, :] - cum)  # (b, Q, nh)
    h_add = jnp.einsum("bqn,bqs,bqnh->bnhs", w, Bm, xs)
    h_new = decay_end[:, :, None, None] * h + h_add
    return h_new, y_inter + y_intra


def ssd_scan(xs, dt, a, Bm, Cm, h0, chunk: int):
    """Full sequence via scan over chunks. xs: (b, S, nh, hd). Returns y, h_T."""
    b, S, nh, hd = xs.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

    def body(h, xs_t):
        return _ssd_chunk(h, *xs_t)

    h_T, ys = jax.lax.scan(body, h0, (to_chunks(xs), to_chunks(dt), to_chunks(a), to_chunks(Bm), to_chunks(Cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, nh, hd)[:, :S]
    return y, h_T


def mamba_forward(
    params: dict,
    x: jnp.ndarray,
    cfg,
    *,
    mode: str = "train",
    cache: dict | None = None,
    chunk: int = 128,
):
    """x: (B, S, D). mode train/prefill returns (out, cache|None);
    mode decode expects S==1 and a cache {'conv': (B,w-1,ch), 'ssm': (B,nh,hd,ds)}."""
    din, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, S, _ = x.shape
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)

    if mode == "decode":
        conv_prev = cache["conv"]  # (B, w-1, ch)
        full = jnp.concatenate([conv_prev.astype(xbc.dtype), xbc], axis=1)  # (B, w, ch)
        conv_out = jnp.einsum("bwc,wc->bc", full, params["conv_w"].astype(xbc.dtype)) + params["conv_b"].astype(xbc.dtype)
        xbc_t = silu(conv_out)[:, None, :]  # (B, 1, ch)
        new_conv = full[:, 1:]
    else:
        xbc_t = silu(_causal_conv(xbc, params["conv_w"].astype(xbc.dtype), params["conv_b"].astype(xbc.dtype)))
        new_conv = xbc[:, -(cfg.ssm_conv_width - 1) :] if S >= cfg.ssm_conv_width - 1 else jnp.pad(
            xbc, ((0, 0), (cfg.ssm_conv_width - 1 - S, 0), (0, 0))
        )

    xs = xbc_t[..., :din].reshape(B, -1, nh, hd).astype(jnp.float32)
    Bm = xbc_t[..., din : din + ds].astype(jnp.float32)
    Cm = xbc_t[..., din + ds :].astype(jnp.float32)
    xs = shard_activation(xs, "ssm_heads")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])  # (B,S,nh)
    a = -jnp.exp(params["A_log"])[None, None, :] * dt  # log-decay <= 0

    if mode == "decode":
        h = cache["ssm"].astype(jnp.float32)  # (B, nh, hd, ds)
        da = jnp.exp(a[:, 0])  # (B, nh)
        h_new = da[:, :, None, None] * h + jnp.einsum("bn,bs,bnh->bnhs", dt[:, 0], Bm[:, 0], xs[:, 0])
        y = jnp.einsum("bs,bnhs->bnh", Cm[:, 0], h_new)[:, None]  # (B,1,nh,hd)
        h_T = h_new
    else:
        h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
        y, h_T = ssd_scan(xs, dt, a, Bm, Cm, h0, chunk)

    y = y + params["D_skip"][None, None, :, None] * xs[:, : y.shape[1]]
    y = y.reshape(B, -1, din).astype(x.dtype)
    y = rmsnorm(y * silu(z), params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,dp->bsp", y, params["out_proj"].astype(x.dtype))
    new_cache = {"conv": new_conv, "ssm": h_T.astype(jnp.float32)}
    return out, new_cache
