"""Model zoo: all assigned architecture families, pure-functional JAX."""

from repro.models.model import Model, build_model, default_qstate, qstate_from_calibrator

__all__ = ["Model", "build_model", "default_qstate", "qstate_from_calibrator"]
