"""GQA attention with EXAQ softmax as a first-class implementation choice.

Three softmax paths:
  * ``exact``       — jax.nn-style stable softmax (paper Algo. 1).
  * ``exaq/naive``  — paper Algo. 2 with a *traced* per-layer clip value, so a
                      scan over stacked layers can carry per-layer calibrated
                      sigmas. Global-grid semantics (quantize after the full
                      row max), shardable by XLA SPMD — this is the lowering
                      used by the multi-pod dry-run.
  * fused Pallas kernel (repro.kernels) — single-chip hot path; opted in via
                      QuantConfig.use_fused_kernel (not shardable by SPMD).

The train/prefill path scans over query blocks: softmax is row-wise, so
q-blocking is exact (no online rescale) while keeping the score tile
(B, H, bq, Skv) bounded — the pure-jnp analogue of flash attention's memory
behaviour, differentiable and remat-friendly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import repeat_kv as _repeat_kv
from repro.models.layers import apply_rope, rmsnorm, truncated_normal_init
from repro.runtime.sharding import shard_activation

_NEG_BIG = -1e30


class AttnStatics(NamedTuple):
    impl: str          # exact | exaq | naive
    bits: int
    use_fused_kernel: bool


def quantized_weights(s: jnp.ndarray, clip, bits: int, valid, ste: bool = False) -> jnp.ndarray:
    """Paper Algo. 2 with traced clip: s -> normalized attention weights.

    s: (..., n) fp32 logits; clip: traced scalar (< 0); valid: bool mask or None.
    ste=True uses a straight-through estimator (exact-softmax backward) so the
    quantized forward stays trainable — the paper leaves training to future
    work (§7.2); this is our documented extension.
    """
    e, denom = quantized_weights_unnormalized(s, clip, bits, valid)
    w = e / denom
    if ste:
        w_exact = exact_weights(s, valid)
        w = w_exact + jax.lax.stop_gradient(w - w_exact)
    return w


def quantized_weights_unnormalized(s: jnp.ndarray, clip, bits: int, valid):
    """(e, denom) with e = LUT[codes] unnormalized — callers can fold the
    row-constant normalization into the PV epilogue ((e@V)/denom), removing a
    score-sized divide materialization."""
    levels = 2**bits
    # Fold the mask into the max reduction ONLY (where->reduce fuses without
    # materializing); codes come from the RAW scores — invalid lanes produce
    # garbage codes that the select chain zeroes. Masking the scores first
    # feeds two consumers (max + quantize) and forces XLA to materialize a
    # score-sized select per block (measured ~1.1 TB/step on yi-6b prefill).
    m = jnp.max(jnp.where(valid, s, _NEG_BIG) if valid is not None else s, axis=-1, keepdims=True)
    delta = -clip / levels
    codes = jnp.clip(jnp.floor((s - m - clip) / delta), 0, levels - 1).astype(jnp.int32)
    # LUT lookup as a select chain: jnp.take lowers to a gather, which BREAKS
    # XLA fusion and materializes a score-sized tensor per layer. Selects over
    # 2^M scalars fuse into one elementwise pass — the same form the Pallas
    # kernel uses on the VPU.
    lut = jnp.exp(clip + (jnp.arange(levels, dtype=jnp.float32) + 0.5) * delta)  # (levels,)
    e = jnp.full(codes.shape, 1.0, jnp.float32) * lut[0]
    for k in range(1, levels):
        e = jnp.where(codes == k, lut[k], e)
    if valid is not None:
        e = jnp.where(valid, e, 0.0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return e, denom


def exact_weights(s: jnp.ndarray, valid) -> jnp.ndarray:
    # mask folded into the max reduce only (fuses); exp of raw invalid lanes
    # may overflow to +inf but the select replaces them before use
    m = jnp.max(jnp.where(valid, s, _NEG_BIG) if valid is not None else s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    if valid is not None:
        e = jnp.where(valid, e, 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def _weights(s, statics: AttnStatics, clip, valid, ste: bool = False):
    if statics.impl == "exact":
        return exact_weights(s, valid)
    return quantized_weights(s, clip, statics.bits, valid, ste=ste)


# ------------------------------------------------------------------ module

def init_attention(key, cfg, d_in: int | None = None, dtype=jnp.float32) -> dict:
    """cfg: ModelConfig-like (num_heads, num_kv_heads, resolved_head_dim, qk_norm)."""
    d_in = d_in or cfg.d_model
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": truncated_normal_init(ks[0], (d_in, cfg.num_heads * dh), d_in**-0.5, dtype),
        "wk": truncated_normal_init(ks[1], (d_in, cfg.num_kv_heads * dh), d_in**-0.5, dtype),
        "wv": truncated_normal_init(ks[2], (d_in, cfg.num_kv_heads * dh), d_in**-0.5, dtype),
        "wo": truncated_normal_init(ks[3], (cfg.num_heads * dh, cfg.d_model), (cfg.num_heads * dh) ** -0.5, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(params, x, cfg, positions, rope: bool):
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, dh)
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def blocked_attention(
    q, k, v, statics: AttnStatics, clip, *, causal: bool, block_q: int = 512, ste: bool = True,
    scores_bf16: bool = False
):
    """Exact q-blocked attention: q (B,H,Sq,Dh); k,v (B,H,Skv,Dh) -> (B,H,Sq,Dh).

    Row-wise softmax over the full kv length per q block (global grid — exact
    Algo. 2 semantics). Scans q blocks to bound the live score tile. When the
    head count doesn't divide TP, the 'qrows' rule shards the q-block rows
    over 'model' instead (sequence-parallel attention — softmax is row-wise,
    so this is exact and collective-free). scores_bf16 halves the score
    traffic; with 2-bit EXAQ quantization downstream the extra rounding is
    far below the quantization step.
    """
    B, H, Sq, Dh = q.shape
    Skv = k.shape[2]
    scale = Dh**-0.5
    offset = Skv - Sq
    nblk = -(-Sq // block_q)
    pad = nblk * block_q - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qb = q.reshape(B, H, nblk, block_q, Dh)
    kv_ids = jnp.arange(Skv, dtype=jnp.int32)
    sdt = jnp.bfloat16 if scores_bf16 else jnp.float32

    def body(carry, xs):
        (qi, idx) = xs
        qi = shard_activation(qi, "qrows")  # (B, H, block_q, Dh)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi, k, preferred_element_type=jnp.float32)
        s = shard_activation((s * scale).astype(sdt), "score_rows").astype(jnp.float32)
        if causal:
            row = idx * block_q + jnp.arange(block_q, dtype=jnp.int32) + offset
            valid = kv_ids[None, None, None, :] <= row[None, None, :, None]
        else:
            valid = None
        if not ste and statics.impl in ("exaq", "naive"):
            # normalization folded into the PV epilogue: (e @ V) / denom —
            # the normalized-weights tensor never materializes
            e, denom = quantized_weights_unnormalized(s, clip, statics.bits, valid)
            o = jnp.einsum("bhqk,bhkd->bhqd", e.astype(v.dtype), v) / denom.astype(v.dtype)
        else:
            w = _weights(s, statics, clip, valid, ste=ste)
            o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)
        o = shard_activation(o, "qrows")
        return carry, o

    _, outs = jax.lax.scan(body, None, (jnp.moveaxis(qb, 2, 0), jnp.arange(nblk)))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, nblk * block_q, Dh)
    return out[:, :, :Sq]


def attention_score_stats(params, x, cfg):
    """Calibration probe (paper §5.1.1): sigma and min of the max-subtracted
    causal attention logits for this layer. x: (B, S, D)."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    del v
    qh, kh = jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2)
    group = cfg.num_heads // cfg.num_kv_heads
    kh = _repeat_kv(kh, group)
    dh = cfg.resolved_head_dim
    s = jnp.einsum("bhqd,bhkd->bhqk", qh.astype(jnp.float32), kh.astype(jnp.float32)) * dh**-0.5
    valid = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    s = jnp.where(valid[None, None], s, jnp.nan)
    m = jnp.nanmax(s, axis=-1, keepdims=True)
    sh = s - m
    # masked streaming moments
    cnt = jnp.sum(valid) * B * cfg.num_heads
    mean = jnp.nansum(sh) / cnt
    var = jnp.nansum(jnp.where(jnp.isnan(sh), 0.0, (sh - mean) ** 2)) / cnt
    return jnp.sqrt(var), jnp.nanmin(sh)


def attention_train(params, x, cfg, statics: AttnStatics, clip, *, causal=True, block_q=512):
    """Full-sequence attention (training / encoder). x: (B, S, D_in)."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions, rope=not cfg.enc_dec or causal)
    q, k, v = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))  # (B, N, S, Dh)
    group = cfg.num_heads // cfg.num_kv_heads
    k, v = _repeat_kv(k, group), _repeat_kv(v, group)
    q = shard_activation(q, "heads")
    k = shard_activation(k, "heads")
    v = shard_activation(v, "heads")
    o = blocked_attention(q, k, v, statics, clip, causal=causal,
                          block_q=max(block_q, cfg.attn_block_q), ste=True,
                          scores_bf16=cfg.attn_scores_bf16)
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, -1).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))


def _fused_prefill_attention(qh, kh, vh, cfg, statics: AttnStatics):
    """Fused flash-EXAQ Pallas kernel for prefill — scores never leave VMEM.

    Under a mesh: shard_map over (data=batch, model=heads); each shard slices
    the kv heads its query group needs (kv replicated over 'model' — with
    few kv heads this is cheap and avoids the GQA repeat materialization).
    Static clip from the calibrated/default sigma (the kernel's LUT is a
    compile-time constant; per-layer traced clips stay on the jnp path)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.quantizer import exaq_params
    from repro.runtime import sharding as shd

    p = exaq_params(cfg.quant.sigma_default, statics.bits, rule=cfg.quant.clip_rule)
    dh = cfg.resolved_head_dim
    scale = dh**-0.5
    mesh = shd._CTX["mesh"]
    if mesh is None or "model" not in mesh.axis_names:
        return ops.exaq_attention(qh, kh, vh, p, scale, block_q=256, block_kv=512)
    tp = mesh.shape["model"]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    group = H // KV
    if H % tp != 0:
        return ops.exaq_attention(qh, _repeat_kv(kh, group), _repeat_kv(vh, group), p, scale,
                                  use_kernel=False)
    hl = H // tp
    assert group % hl == 0 or hl % group == 0, (group, hl)
    dp = shd.data_axes(mesh)

    def local(q, k, v):
        i = jax.lax.axis_index("model")
        if hl <= group:
            kl = jax.lax.dynamic_slice_in_dim(k, (i * hl) // group, 1, axis=1)
            vl = jax.lax.dynamic_slice_in_dim(v, (i * hl) // group, 1, axis=1)
        else:
            cnt = hl // group
            kl = jax.lax.dynamic_slice_in_dim(k, i * cnt, cnt, axis=1)
            vl = jax.lax.dynamic_slice_in_dim(v, i * cnt, cnt, axis=1)
        return ops.exaq_attention(q, kl, vl, p, scale, block_q=256, block_kv=512)

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, "model", None, None), P(dp, None, None, None), P(dp, None, None, None)),
        out_specs=P(dp, "model", None, None),
        check_rep=False,
    )
    return fn(qh, kh, vh)


def attention_prefill(params, x, cfg, statics: AttnStatics, clip, *, block_q=512):
    """Causal attention that also returns the (pre-repeat) KV cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    group = cfg.num_heads // cfg.num_kv_heads
    if statics.use_fused_kernel and statics.impl == "exaq":
        o = _fused_prefill_attention(qh, kh, vh, cfg, statics)
    else:
        o = blocked_attention(
            qh, _repeat_kv(kh, group), _repeat_kv(vh, group),
            statics, clip, causal=True, block_q=block_q, ste=False,
            scores_bf16=cfg.attn_scores_bf16,
        )
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, -1).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))
    return out, (kh, vh)  # cache layout (B, KV, S, Dh)


def attention_decode(params, x, cfg, statics: AttnStatics, clip, cache_k, cache_v, pos,
                     sp: bool = False):
    """One-token decode. x: (B, 1, D); cache_{k,v}: (B, KV, Smax, Dh); pos scalar.

    Returns (out, new_k, new_v). EXAQ global-grid softmax over the live cache
    prefix; the denominator is the histogram-composable form (DESIGN.md §2).
    sp=True takes the shard_map sequence-parallel path (integer-count combine).
    """
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    if sp:
        from repro.runtime import sharding as shd

        if shd._CTX["mesh"] is not None and "model" in shd._CTX["mesh"].axis_names:
            qh = jnp.swapaxes(q, 1, 2)
            o, new_k, new_v = sp_decode_attention(
                qh, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), cache_k, cache_v, pos, cfg, statics, clip
            )
            o = jnp.swapaxes(o, 1, 2).reshape(B, 1, -1).astype(x.dtype)
            out = jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))
            return out, new_k, new_v
    # write the new kv at index pos
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, jnp.swapaxes(k, 1, 2).astype(cache_k.dtype), pos, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, jnp.swapaxes(v, 1, 2).astype(cache_v.dtype), pos, axis=2)
    qh = jnp.swapaxes(q, 1, 2)  # (B, H, 1, Dh)
    group = cfg.num_heads // cfg.num_kv_heads
    kk = _repeat_kv(new_k, group)
    vv = _repeat_kv(new_v, group)
    dh = cfg.resolved_head_dim
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kk).astype(jnp.float32) * dh**-0.5
    Smax = cache_k.shape[2]
    valid = ops.window_valid_mask(Smax, jnp.reshape(pos + 1, (1, 1)))
    w = _weights(s, statics, clip, valid)
    o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(vv.dtype), vv)
    o = jnp.swapaxes(o, 1, 2).reshape(B, 1, -1).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))
    return out, new_k, new_v


def attention_decode_ragged(params, x, cfg, statics: AttnStatics, clip, cache_k, cache_v, lens):
    """Slot-batched one-token decode over a *ragged* KV cache (serving engine).

    Unlike ``attention_decode`` (one scalar ``pos`` for the whole batch), every
    slot carries its own live length: the new token is RoPE-rotated at
    ``lens[b]``, written at cache index ``lens[b]``, and attends to the
    ``lens[b]+1`` live positions of its slot — so requests of different lengths
    share one jitted step and one attention dispatch.

    x: (S, 1, D); cache_{k,v}: (S, KV, Smax, Dh); lens: (S,) int32.
    Returns (out (S, 1, D), new_k, new_v).
    """
    B = x.shape[0]
    positions = lens.astype(jnp.int32)[:, None]  # (S, 1) per-slot rope position
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    kn = jnp.swapaxes(k, 1, 2)  # (S, KV, 1, Dh)
    vn = jnp.swapaxes(v, 1, 2)
    Smax = cache_k.shape[2]
    upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice_in_dim(c, u, p, axis=1))
    new_k = upd(cache_k, kn.astype(cache_k.dtype), positions[:, 0])
    new_v = upd(cache_v, vn.astype(cache_v.dtype), positions[:, 0])
    qh = jnp.swapaxes(q, 1, 2)  # (S, H, 1, Dh)
    kv_lens = lens.astype(jnp.int32) + 1
    dh = cfg.resolved_head_dim
    if statics.use_fused_kernel and statics.impl == "exaq":
        # single Pallas dispatch over all slots (static clip from default sigma,
        # like the fused prefill path — traced per-layer clips stay on jnp)
        from repro.core.quantizer import exaq_params

        p = exaq_params(cfg.quant.sigma_default, statics.bits, rule=cfg.quant.clip_rule)
        o = ops.decode_attention(qh, new_k, new_v, kv_lens, p, dh**-0.5)
    else:
        group = cfg.num_heads // cfg.num_kv_heads
        kk = _repeat_kv(new_k, group)
        vv = _repeat_kv(new_v, group)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kk).astype(jnp.float32) * dh**-0.5
        valid = ops.window_valid_mask(Smax, kv_lens[:, None])
        w = _weights(s, statics, clip, valid)
        o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(vv.dtype), vv)
    o = jnp.swapaxes(o, 1, 2).reshape(B, 1, -1).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))
    return out, new_k, new_v


def attention_decode_paged(params, x, cfg, statics: AttnStatics, clip, pool_k, pool_v,
                           block_tables, lens, active, k_scale=None, v_scale=None,
                           k_sub=None, v_sub=None):
    """Slot-batched one-token decode over a *block-paged* KV cache (DESIGN.md §3).

    The paged sibling of ``attention_decode_ragged``: per-slot raggedness still
    lives in ``lens``, but KV now resides in a global block pool shared by all
    slots — each slot's window is the blocks its table names. The new token is
    RoPE-rotated at ``lens[b]`` and scattered into block
    ``block_tables[b, lens[b] // bs]`` at offset ``lens[b] % bs``; inactive
    slots scatter to the reserved null block (id 0) so a freed slot can never
    corrupt blocks that were recycled to another request.

    For an int8 pool (DESIGN.md §6) the scatter *quantizes*: the new token's
    per-kv-head codes land at the block's scale — seeding it
    (``ops.kv_write_scales``) when this is the block's first write — and the
    read paths dequantize, so fp values never reach HBM. ``k_scale``/
    ``v_scale`` are the per-layer (N, KV) scale planes; None means an fp pool.
    A packed int4 pool (DESIGN.md §10) additionally carries the
    ``k_sub``/``v_sub`` (N, KV, n_sub) sub-block scale-code planes: the token
    seeds its target sub-block's code (immutable once set, like the block
    scale) and its row lands as packed nibbles at the effective scale
    ``block_scale * sub_code / 15``. Head-dim-adjacent packing keeps the
    one-token scatter whole-byte, so no neighbour row is read-modify-written.

    Attention dispatch (DESIGN.md §3, fused paged decode): with
    ``use_fused_kernel`` + exaq the fused Pallas kernel reads K/V blocks
    straight from the pool via the scalar-prefetched block table — the dense
    per-slot KV copy the gather materializes never exists. Otherwise the
    gather-then-dispatch reference runs: assemble each slot's live blocks
    (``kernels.ops.gather_block_kv`` with ``kv_lens`` clamping dead tails to
    the null block, dequantizing when scales are given) and apply the EXAQ
    histogram softmax. Both anchor the quantization grid at the global row
    max, so per-block partial counts add exactly (§2 combine; block
    boundaries are invisible to the softmax) and the two paths agree to fp32
    roundoff — under the same clip: the fused kernel folds the default-sigma
    clip as a compile-time constant, so a *calibrated* per-layer qstate is
    honored by the gather path only.

    x: (S, 1, D); pool_{k,v}: (N, KV, bs, Dh); block_tables: (S, MB) int32;
    lens: (S,) int32; active: (S,) bool; k_scale/v_scale: (N, KV) fp32 or
    None; k_sub/v_sub: (N, KV, n_sub) uint8 or None.
    Returns (out (S, 1, D), new_kv) where new_kv is (pool_k, pool_v) for fp
    pools, (pool_k, pool_v, k_scale, v_scale) for int8 pools, and
    (pool_k, pool_v, k_scale, v_scale, k_sub, v_sub) for int4 pools.
    """
    B = x.shape[0]
    bs = pool_k.shape[2]
    quantized = k_scale is not None
    int4 = k_sub is not None
    positions = lens.astype(jnp.int32)[:, None]  # (S, 1) per-slot rope position
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    kn, vn = k[:, 0], v[:, 0]  # (S, KV, Dh)
    blk = jnp.take_along_axis(block_tables, (lens // bs)[:, None], axis=1)[:, 0]
    blk = jnp.where(active, blk, 0)  # gate writes of inactive slots to the null block
    off = lens % bs
    if int4:
        # §6's immutable-scale scatter at the int4 range (DESIGN.md §10): the
        # block scale seeds at margin*amax/7 iff unset, the token's sub-block
        # code seeds iff unset, and the row quantizes at the effective scale
        # block * code / 15 into packed nibbles. Advanced index [blk, :, sub]
        # selects each slot's one touched sub-block as an (S, KV) plane.
        sub_bs = bs // k_sub.shape[-1]
        sub = off // sub_bs  # (S,) the one sub-block this token lands in
        amax_k = jnp.max(jnp.abs(kn), axis=-1)  # (S, KV)
        amax_v = jnp.max(jnp.abs(vn), axis=-1)
        ks_new = ops.kv4_write_block_scales(amax_k, k_scale[blk])
        vs_new = ops.kv4_write_block_scales(amax_v, v_scale[blk])
        kc_new = ops.kv4_write_sub_scales(amax_k[..., None], ks_new,
                                          k_sub[blk, :, sub][..., None])[..., 0]  # (S, KV)
        vc_new = ops.kv4_write_sub_scales(amax_v[..., None], vs_new,
                                          v_sub[blk, :, sub][..., None])[..., 0]
        se_k = ops.kv4_effective_scale(ks_new, kc_new[..., None])[..., 0]
        se_v = ops.kv4_effective_scale(vs_new, vc_new[..., None])[..., 0]
        new_pool_k = pool_k.at[blk, :, off].set(ops.kv4_quantize(kn, se_k))
        new_pool_v = pool_v.at[blk, :, off].set(ops.kv4_quantize(vn, se_v))
        k_scale = k_scale.at[blk].set(ks_new)
        v_scale = v_scale.at[blk].set(vs_new)
        k_sub = k_sub.at[blk, :, sub].set(kc_new)
        v_sub = v_sub.at[blk, :, sub].set(vc_new)
    elif quantized:
        # per-slot per-kv-head amax seeds the target block's scale iff unset;
        # a set scale is immutable (saturating append) so published prefix
        # bytes never change (DESIGN.md §6). Inactive slots land on the null
        # block, whose scale/payload are garbage sinks, never read unmasked.
        ks_new = ops.kv_write_scales(jnp.max(jnp.abs(kn), axis=-1), k_scale[blk])  # (S, KV)
        vs_new = ops.kv_write_scales(jnp.max(jnp.abs(vn), axis=-1), v_scale[blk])
        new_pool_k = pool_k.at[blk, :, off].set(ops.kv_quantize(kn, ks_new[..., None]))
        new_pool_v = pool_v.at[blk, :, off].set(ops.kv_quantize(vn, vs_new[..., None]))
        k_scale = k_scale.at[blk].set(ks_new)
        v_scale = v_scale.at[blk].set(vs_new)
    else:
        new_pool_k = pool_k.at[blk, :, off].set(kn.astype(pool_k.dtype))
        new_pool_v = pool_v.at[blk, :, off].set(vn.astype(pool_v.dtype))
    qh = jnp.swapaxes(q, 1, 2)  # (S, H, 1, Dh)
    kv_lens = lens.astype(jnp.int32) + 1
    dh = cfg.resolved_head_dim
    if statics.use_fused_kernel and statics.impl == "exaq":
        # static clip from the default sigma, like the fused ragged/prefill
        # paths: the kernel's clip/LUT are compile-time immediates, so
        # calibrated per-layer *traced* clips stay on the gather/jnp path —
        # fused-vs-gather parity holds for the default qstate only
        from repro.core.quantizer import exaq_params

        p = exaq_params(cfg.quant.sigma_default, statics.bits, rule=cfg.quant.clip_rule)
        o = ops.paged_decode_attention(qh, new_pool_k, new_pool_v, block_tables, kv_lens,
                                       p, dh**-0.5, k_scale=k_scale, v_scale=v_scale,
                                       k_sub=k_sub, v_sub=v_sub)
    else:
        kg, vg = ops.gather_block_kv(new_pool_k, new_pool_v, block_tables, kv_lens,
                                     k_scale, v_scale, k_sub, v_sub)  # (S, KV, W, Dh)
        group = cfg.num_heads // cfg.num_kv_heads
        kk = _repeat_kv(kg, group)
        vv = _repeat_kv(vg, group)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kk).astype(jnp.float32) * dh**-0.5
        valid = ops.window_valid_mask(kk.shape[2], kv_lens[:, None])
        w = _weights(s, statics, clip, valid)
        o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(vv.dtype), vv)
    o = jnp.swapaxes(o, 1, 2).reshape(B, 1, -1).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))
    new_kv = ((new_pool_k, new_pool_v)
              + ((k_scale, v_scale) if quantized else ())
              + ((k_sub, v_sub) if int4 else ()))
    return out, new_kv


def attention_prefill_chunk(params, x, cfg, statics: AttnStatics, clip, pool_k, pool_v,
                            block_table, start, blk_t, off_t, k_scale=None, v_scale=None,
                            k_sub=None, v_sub=None, seed_first_row=False):
    """One chunk of chunked prefill against a paged cache (DESIGN.md §3).

    Processes ``C`` prompt tokens at global positions ``start + i`` for one
    request: projects chunk K/V, scatters them into the pool at the host-
    computed targets (``blk_t[i]``, ``off_t[i]``; padded rows target the null
    block), then attends causally by *global position*
    (``key_pos <= start + row``) against the request's whole window — which
    now includes this chunk's keys. Because the EXAQ grid anchors at each
    row's global max, chunking the prefill leaves the softmax bit-identical
    to a one-shot prefill of the same prompt (§2: partial histograms add
    exactly).

    Attention dispatch (DESIGN.md §7, fused paged prefill): with
    ``use_fused_kernel`` + exaq the fused Pallas kernel
    (``kernels/exaq_paged_prefill.py``) reads the window's K/V blocks
    straight from the pool via the scalar-prefetched block table — the dense
    per-chunk window copy the gather materializes (the O(prompt²) bytes term
    of chunked prefill) never exists. Otherwise the gather-then-attend
    reference runs. Both anchor at the global row max so the paths agree to
    fp32 roundoff — under the same clip: like the fused decode path, the
    kernel folds the default-sigma clip as a compile-time constant, so a
    *calibrated* per-layer qstate is honored by the gather path only.

    For an int8 pool (DESIGN.md §6) the scatter quantizes: a scatter-max
    collects each *target block's* per-kv-head amax over the rows this chunk
    writes into it, seeds still-unset block scales from that, and the rows
    quantize at their block's (now fixed) scale. The read paths dequantize
    (the fused kernel in VMEM, the gather during assembly), so
    chunked-prefill attention still runs in fp.

    A packed int4 pool (DESIGN.md §10) extends the same shape to two scale
    tiers: a scatter-max per *target sub-block* seeds still-unset sub codes
    against the (just-seeded) block scales, then each row packs to nibbles
    at its sub-block's effective scale ``block_scale * sub_code / 15``.

    ``seed_first_row`` (speculative verify, DESIGN.md §12) restricts which
    rows may *seed* scales: only the window's first row and rows landing at a
    block boundary (offset 0; sub-block boundary for int4 sub codes) feed the
    scatter-max. That is exactly the row one-token-at-a-time decode would
    have seeded each block/sub-block from, so a verify window whose tail rows
    get rejected leaves every scale bit-identical to the vanilla decode that
    never saw them — scales stay immutable once set, and the rejected rows'
    payload codes sit past ``kv_lens``, where no read path looks.

    x: (1, C, D) chunk embeddings (right-padded); block_table: (MB,) int32;
    start: scalar int32 (tokens already cached); blk_t/off_t: (C,) int32;
    k_scale/v_scale: (N, KV) fp32 or None; k_sub/v_sub: (N, KV, n_sub)
    uint8 or None.
    Returns (out (1, C, D), new_kv) where new_kv is (pool_k, pool_v) for fp
    pools, (pool_k, pool_v, k_scale, v_scale) for int8 pools, and
    (pool_k, pool_v, k_scale, v_scale, k_sub, v_sub) for int4 pools.
    """
    B, C, _ = x.shape
    quantized = k_scale is not None
    int4 = k_sub is not None
    positions = (start + jnp.arange(C, dtype=jnp.int32))[None, :]  # (1, C)
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    if int4:
        bs_pool = pool_k.shape[2]
        sub_bs = bs_pool // k_sub.shape[-1]
        sub_t = off_t // sub_bs  # (C,) each row's target sub-block
        tok_amax_k = jnp.max(jnp.abs(k[0]), axis=-1)  # (C, KV)
        tok_amax_v = jnp.max(jnp.abs(v[0]), axis=-1)
        blk_amax_k = sub_amax_k = tok_amax_k
        blk_amax_v = sub_amax_v = tok_amax_v
        if seed_first_row:
            # sequential-seeding mask (§12): only the row vanilla decode
            # would have seeded each block / sub-block from may contribute
            row = jnp.arange(C, dtype=jnp.int32)
            first_blk = ((row == 0) | (off_t == 0))[:, None]
            first_sub = ((row == 0) | (off_t % sub_bs == 0))[:, None]
            blk_amax_k = jnp.where(first_blk, tok_amax_k, 0.0)
            blk_amax_v = jnp.where(first_blk, tok_amax_v, 0.0)
            sub_amax_k = jnp.where(first_sub, tok_amax_k, 0.0)
            sub_amax_v = jnp.where(first_sub, tok_amax_v, 0.0)
        amax_k = jnp.zeros_like(k_scale).at[blk_t].max(blk_amax_k)
        amax_v = jnp.zeros_like(v_scale).at[blk_t].max(blk_amax_v)
        k_scale = ops.kv4_write_block_scales(amax_k, k_scale)
        v_scale = ops.kv4_write_block_scales(amax_v, v_scale)
        amax_sub_k = jnp.zeros(k_sub.shape, jnp.float32).at[blk_t, :, sub_t].max(sub_amax_k)
        amax_sub_v = jnp.zeros(v_sub.shape, jnp.float32).at[blk_t, :, sub_t].max(sub_amax_v)
        k_sub = ops.kv4_write_sub_scales(amax_sub_k, k_scale, k_sub)
        v_sub = ops.kv4_write_sub_scales(amax_sub_v, v_scale, v_sub)
        se_k = ops.kv4_effective_scale(k_scale, k_sub)[blk_t, :, sub_t]  # (C, KV)
        se_v = ops.kv4_effective_scale(v_scale, v_sub)[blk_t, :, sub_t]
        new_pool_k = pool_k.at[blk_t, :, off_t].set(ops.kv4_quantize(k[0], se_k))
        new_pool_v = pool_v.at[blk_t, :, off_t].set(ops.kv4_quantize(v[0], se_v))
    elif quantized:
        # group the chunk's rows by target block: scatter-max their per-head
        # amax onto the (N, KV) scale plane, seed unset scales, then quantize
        # each row at its block's scale. Padded rows target the null block.
        tok_amax_k = jnp.max(jnp.abs(k[0]), axis=-1)  # (C, KV)
        tok_amax_v = jnp.max(jnp.abs(v[0]), axis=-1)
        if seed_first_row:
            # sequential-seeding mask (§12): see the int4 branch above
            first_blk = ((jnp.arange(C, dtype=jnp.int32) == 0) | (off_t == 0))[:, None]
            tok_amax_k = jnp.where(first_blk, tok_amax_k, 0.0)
            tok_amax_v = jnp.where(first_blk, tok_amax_v, 0.0)
        amax_k = jnp.zeros_like(k_scale).at[blk_t].max(tok_amax_k)
        amax_v = jnp.zeros_like(v_scale).at[blk_t].max(tok_amax_v)
        k_scale = ops.kv_write_scales(amax_k, k_scale)
        v_scale = ops.kv_write_scales(amax_v, v_scale)
        new_pool_k = pool_k.at[blk_t, :, off_t].set(ops.kv_quantize(k[0], k_scale[blk_t][..., None]))
        new_pool_v = pool_v.at[blk_t, :, off_t].set(ops.kv_quantize(v[0], v_scale[blk_t][..., None]))
    else:
        new_pool_k = pool_k.at[blk_t, :, off_t].set(k[0].astype(pool_k.dtype))  # (C, KV, Dh) targets
        new_pool_v = pool_v.at[blk_t, :, off_t].set(v[0].astype(pool_v.dtype))

    qh = jnp.swapaxes(q, 1, 2)  # (1, H, C, Dh)
    dh = cfg.resolved_head_dim
    if statics.use_fused_kernel and statics.impl == "exaq":
        # static clip from the default sigma, like the fused decode path:
        # the kernel's clip/LUT are compile-time immediates, so calibrated
        # per-layer *traced* clips stay on the gather/jnp path
        from repro.core.quantizer import exaq_params

        p = exaq_params(cfg.quant.sigma_default, statics.bits, rule=cfg.quant.clip_rule)
        o = ops.paged_prefill_attention(qh, new_pool_k, new_pool_v, block_table, start,
                                        p, dh**-0.5, k_scale=k_scale, v_scale=v_scale,
                                        k_sub=k_sub, v_sub=v_sub)
    else:
        # window live length: everything cached before this chunk plus the
        # chunk itself — entries past ceil((start+C)/bs) clamp to null
        kg, vg = ops.gather_block_kv(new_pool_k, new_pool_v, block_table[None],
                                     start + C, k_scale, v_scale,
                                     k_sub, v_sub)  # (1, KV, W, Dh)
        group = cfg.num_heads // cfg.num_kv_heads
        kk = _repeat_kv(kg, group)
        vv = _repeat_kv(vg, group)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kk).astype(jnp.float32) * dh**-0.5
        rows = start + jnp.arange(C, dtype=jnp.int32)
        valid = ops.window_valid_mask(kk.shape[2], (rows + 1)[None, :])
        w = _weights(s, statics, clip, valid)
        o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(vv.dtype), vv)
    o = jnp.swapaxes(o, 1, 2).reshape(B, C, -1).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))
    new_kv = ((new_pool_k, new_pool_v)
              + ((k_scale, v_scale) if quantized else ())
              + ((k_sub, v_sub) if int4 else ()))
    return out, new_kv


def sp_decode_attention(qh, k_new, v_new, cache_k, cache_v, pos, cfg, statics: AttnStatics, clip):
    """Sequence-parallel decode attention (beyond-paper, EXAQ-native).

    The KV cache is sequence-sharded over 'model' (the layout runtime/sharding
    picks when kv_heads don't divide TP). Baseline XLA lowering all-gathers the
    whole cache per token (~GBs); here each shard computes local scores and the
    cross-shard softmax combine is:

        max:         one f32 pmax per row
        denominator: psum of 2^M *integer counts* per row (the EXAQ histogram
                     composes exactly across shards — calibrated C makes the
                     quantization grid shard-invariant)
        numerator:   psum of the (B,H,1,Dh) weighted-V partials

    Total wire bytes per layer: O(B*H*(2^M + Dh)) instead of O(B*KV*S*Dh).
    The cache write also happens shard-locally (no resharding copy).

    qh: (B,H,1,Dh); k_new/v_new: (B,KV,1,Dh); cache_{k,v}: (B,KV,Smax,Dh).
    Returns (out (B,H,1,Dh) fp32, new_cache_k, new_cache_v).
    """
    from jax.experimental.shard_map import shard_map
    from repro.runtime import sharding as shd

    mesh = shd._CTX["mesh"]
    dp = shd.data_axes(mesh)
    group = cfg.num_heads // cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    levels = 2**statics.bits
    quantized = statics.impl in ("exaq", "naive")

    def local(q, kn, vn, ck, cv, posv, clipv):
        i = jax.lax.axis_index("model")
        Sl = ck.shape[2]
        # shard-local cache write
        lpos = posv - i * Sl
        in_range = (lpos >= 0) & (lpos < Sl)
        lpos_c = jnp.clip(lpos, 0, Sl - 1)
        ck2 = jax.lax.dynamic_update_slice_in_dim(ck, kn.astype(ck.dtype), lpos_c, axis=2)
        cv2 = jax.lax.dynamic_update_slice_in_dim(cv, vn.astype(cv.dtype), lpos_c, axis=2)
        ck2 = jnp.where(in_range, ck2, ck)
        cv2 = jnp.where(in_range, cv2, cv)
        # grouped-query einsum — NOT repeat_kv: broadcasting kv to H heads
        # materializes a group-factor-sized copy of the cache shard per layer
        # (measured 86 GB/step on qwen3 decode_32k)
        B = q.shape[0]
        qg = q.reshape(B, ck2.shape[1], group, 1, dh)  # (B, KV, G, 1, Dh)
        s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32), ck2.astype(jnp.float32)) * dh**-0.5
        cols = i * Sl + jnp.arange(Sl, dtype=jnp.int32)
        valid = (cols <= posv)[None, None, None, None, :]
        m = jax.lax.pmax(jnp.max(jnp.where(valid, s, _NEG_BIG), axis=-1, keepdims=True), "model")
        if quantized:
            delta = -clipv / levels
            codes = jnp.clip(jnp.floor((s - m - clipv) / delta), 0, levels - 1).astype(jnp.int32)
            lut = jnp.exp(clipv + (jnp.arange(levels, dtype=jnp.float32) + 0.5) * delta)
            e = jnp.full(codes.shape, 1.0, jnp.float32) * lut[0]
            for kk_ in range(1, levels):
                e = jnp.where(codes == kk_, lut[kk_], e)
            e = jnp.where(valid, e, 0.0)
            onehot = (codes[..., None] == jnp.arange(levels)) & valid[..., None]
            counts = jnp.sum(onehot, axis=4, dtype=jnp.int32)           # (B,KV,G,1,levels)
            counts = jax.lax.psum(counts, "model")                       # integer combine
            denom = jnp.einsum("bkgql,l->bkgq", counts.astype(jnp.float32), lut)[..., None]
        else:
            e = jnp.exp(s - m)
            e = jnp.where(valid, e, 0.0)
            denom = jax.lax.psum(jnp.sum(e, axis=-1, keepdims=True), "model")
        part = jnp.einsum("bkgqs,bksd->bkgqd", e, cv2.astype(jnp.float32))
        out = jax.lax.psum(part, "model") / jnp.maximum(denom, 1e-30)
        out = out.reshape(B, ck2.shape[1] * group, 1, dh)
        return out, ck2, cv2

    from jax.sharding import PartitionSpec as P

    q_spec = P(dp, None, None, None)
    kv_spec = P(dp, None, "model", None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec, kv_spec, kv_spec, P(), P()),
        out_specs=(q_spec, kv_spec, kv_spec),
        check_rep=False,
    )
    return fn(qh, k_new, v_new, cache_k, cache_v, pos, jnp.asarray(clip, jnp.float32))


def cross_attention(params, x, enc_kv, cfg, statics: AttnStatics, clip):
    """Decoder cross-attention to precomputed encoder K/V (B, KV, Senc, Dh)."""
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, dh)
    qh = jnp.swapaxes(q, 1, 2)
    k, v = enc_kv
    group = cfg.num_heads // cfg.num_kv_heads
    kk, vv = _repeat_kv(k, group), _repeat_kv(v, group)
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kk).astype(jnp.float32) * dh**-0.5
    w = _weights(s, statics, clip, None)
    o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(vv.dtype), vv)
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, -1).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["wo"].astype(x.dtype))


def init_cross_kv(params, enc_out, cfg):
    """Precompute encoder K/V for cross-attention. enc_out: (B, Senc, D)."""
    B, S, _ = enc_out.shape
    dh = cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, params["wk"].astype(enc_out.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    v = jnp.einsum("bsd,de->bse", enc_out, params["wv"].astype(enc_out.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    return jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
