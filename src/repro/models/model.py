"""Composable model definition covering all assigned architecture families.

One ``Model`` object (pure functions bound to a ModelConfig) provides:
  init / forward_train / prefill / decode_step / init_cache / default_qstate

Layer stacks are scan-over-layers (stacked params, per-layer EXAQ clip values
as scan xs) so HLO size is O(1) in depth — required for tractable 512-device
SPMD compiles. Families:

  dense | vlm    : [attn -> mlp] x L, optional patch-embed prefix (stub frontend)
  moe            : [attn -> moe_ffn] x L (+ shared experts)
  ssm            : [mamba2] x L (attention-free; EXAQ n/a)
  hybrid (zamba2): groups of `hybrid_period` mamba blocks + ONE weight-shared
                   attention block applied on concat(h, h0) after each group
  audio (whisper): enc-dec; encoder over stub frame embeddings, decoder with
                   self + cross attention
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import clipping
from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.attention import AttnStatics
from repro.models.layers import gated_mlp, init_gated_mlp, rmsnorm, sinusoidal_positions, truncated_normal_init
from repro.runtime.sharding import shard_activation


def _statics(cfg) -> AttnStatics:
    return AttnStatics(cfg.quant.softmax_impl, cfg.quant.bits, cfg.quant.use_fused_kernel)


def default_qstate(cfg) -> dict[str, jnp.ndarray]:
    """Per-site clip values from the default sigma (pre-calibration).

    Calibration replaces these with per-layer values (core.calibration)."""
    q = cfg.quant
    if q.softmax_impl == "exact":
        c = -1.0  # unused
    elif q.softmax_impl == "naive":
        # NAIVE default: C = min/2 with min ~ -4 sigma (calibration overwrites)
        c = -2.0 * q.sigma_default
    else:
        c = clipping.get_clip_rule(q.clip_rule, q.bits)(q.sigma_default)
    qs = {}
    if cfg.family in ("dense", "vlm", "moe"):
        qs["attn_clip"] = jnp.full((cfg.num_layers,), c, jnp.float32)
    elif cfg.family == "hybrid":
        qs["shared_clip"] = jnp.full((cfg.num_layers // cfg.hybrid_period,), c, jnp.float32)
    elif cfg.family == "audio":
        qs["enc_clip"] = jnp.full((cfg.enc_layers,), c, jnp.float32)
        qs["attn_clip"] = jnp.full((cfg.num_layers,), c, jnp.float32)
        qs["cross_clip"] = jnp.full((cfg.num_layers,), c, jnp.float32)
    return qs


def qstate_from_calibrator(cfg, calib) -> dict[str, jnp.ndarray]:
    """Build per-layer clips from a core.calibration.Calibrator artifact."""
    q = cfg.quant
    qs = default_qstate(cfg)
    for key, n in (("attn_clip", cfg.num_layers), ("enc_clip", cfg.enc_layers),
                   ("cross_clip", cfg.num_layers if cfg.enc_dec else 0),
                   ("shared_clip", cfg.num_layers // cfg.hybrid_period if cfg.hybrid_period else 0)):
        if key not in qs:
            continue
        vals = []
        for i in range(n):
            site = f"{key[:-5]}/{i}"
            if site in calib.stats:
                if q.softmax_impl == "naive":
                    vals.append(calib.naive_params(site, q.bits).clip)
                else:
                    vals.append(calib.exaq_params(site, q.bits, rule=q.clip_rule).clip)
            else:
                vals.append(float(qs[key][i]))
        qs[key] = jnp.asarray(vals, jnp.float32)
    return qs


@dataclass(frozen=True)
class Model:
    cfg: object

    # ----------------------------------------------------------- init
    def init(self, key, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        vp = cfg.padded_vocab
        params: dict = {
            "embed": {"tokens": truncated_normal_init(keys[0], (vp, cfg.d_model), 1.0, dtype)},
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "head": truncated_normal_init(keys[1], (cfg.d_model, vp), cfg.d_model**-0.5, dtype),
        }
        if cfg.frontend is not None:
            params["frontend"] = {
                "frontend_proj": truncated_normal_init(keys[2], (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim**-0.5, dtype)
            }
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            params["layers"] = self._init_decoder_stack(keys[3], cfg.num_layers, dtype)
        elif fam == "ssm":
            params["layers"] = self._init_ssm_stack(keys[3], cfg.num_layers, dtype)
        elif fam == "hybrid":
            n_groups = cfg.num_layers // cfg.hybrid_period
            params["layers"] = jax.vmap(
                lambda k: jax.vmap(lambda kk: self._init_ssm_layer(kk, dtype))(jax.random.split(k, cfg.hybrid_period))
            )(jax.random.split(keys[3], n_groups))
            params["shared"] = self._init_shared_block(keys[4], dtype)
        elif fam == "audio":
            params["enc_layers"] = jax.vmap(lambda k: self._init_enc_layer(k, dtype))(
                jax.random.split(keys[3], cfg.enc_layers)
            )
            params["layers"] = jax.vmap(lambda k: self._init_dec_layer(k, dtype))(
                jax.random.split(keys[4], cfg.num_layers)
            )
            params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        else:
            raise ValueError(fam)
        return params

    def _init_decoder_layer(self, key, dtype):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.init_attention(k1, cfg, dtype=dtype),
        }
        if cfg.moe is not None:
            p["moe"] = moe.init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = init_gated_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        return p

    def _init_decoder_stack(self, key, n, dtype):
        return jax.vmap(lambda k: self._init_decoder_layer(k, dtype))(jax.random.split(key, n))

    def _init_ssm_layer(self, key, dtype):
        cfg = self.cfg
        return {"ln1": jnp.ones((cfg.d_model,), dtype), "ssm": mamba2.init_mamba(key, cfg, dtype)}

    def _init_ssm_stack(self, key, n, dtype):
        return jax.vmap(lambda k: self._init_ssm_layer(k, dtype))(jax.random.split(key, n))

    def _init_shared_block(self, key, dtype):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.ones((2 * cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.init_attention(k1, cfg, d_in=2 * cfg.d_model, dtype=dtype),
            "mlp": init_gated_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    def _init_enc_layer(self, key, dtype):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.init_attention(k1, cfg, dtype=dtype),
            "mlp": init_gated_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    def _init_dec_layer(self, key, dtype):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "ln3": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.init_attention(k1, cfg, dtype=dtype),
            "cross": attn.init_attention(k2, cfg, dtype=dtype),
            "mlp": init_gated_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
        }

    # ------------------------------------------------------- embedding
    def _embed(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        if cfg.frontend == "vlm":
            ve = jnp.einsum(
                "bte,ed->btd", batch["vision_embeds"].astype(h.dtype), params["frontend"]["frontend_proj"].astype(h.dtype)
            )
            ft = min(cfg.frontend_tokens, h.shape[1])
            h = jnp.concatenate([ve[:, :ft], h[:, ft:]], axis=1)
        return shard_activation(h, "btd")

    def _mask_padded_vocab(self, logits):
        cfg = self.cfg
        if cfg.padded_vocab == cfg.vocab_size:
            return logits
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        return jnp.where(iota < cfg.vocab_size, logits, jnp.asarray(-1e30, logits.dtype))

    def _remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if self.cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        return jax.checkpoint(fn, policy=policy)

    # ------------------------------------------------------ train fwd
    def forward_train(self, params, batch, qstate=None) -> tuple[jnp.ndarray, dict]:
        """-> (logits (B,S,V), aux dict)."""
        cfg = self.cfg
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        fam = cfg.family

        if fam == "audio":
            return self._forward_whisper_train(params, batch, qstate)

        h = self._embed(params, batch)
        aux = {}
        if fam in ("dense", "vlm", "moe"):
            def body(carry, xs):
                h, aux_lb, aux_z = carry
                lp, clip = xs
                a = attn.attention_train(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip)
                h = h + a
                if cfg.moe is not None:
                    f, moe_aux = moe.moe_ffn(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
                    aux_lb = aux_lb + moe_aux["moe_lb"]
                    aux_z = aux_z + moe_aux["moe_z"]
                else:
                    f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
                h = shard_activation(h + f, "btd")
                return (h, aux_lb, aux_z), None

            (h, lb, z), _ = jax.lax.scan(
                self._remat(body), (h, 0.0, 0.0), (params["layers"], qstate["attn_clip"])
            )
            if cfg.moe is not None:
                aux = {"moe_lb": lb / cfg.num_layers, "moe_z": z / cfg.num_layers}
        elif fam == "ssm":
            def body(h, lp):
                out, _ = mamba2.mamba_forward(
                    lp["ssm"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, mode="train", chunk=cfg.ssm_chunk
                )
                return shard_activation(h + out, "btd"), None

            h, _ = jax.lax.scan(self._remat(body), h, params["layers"])
        elif fam == "hybrid":
            h0 = h

            def group(carry, xs):
                h = carry
                gp, clip = xs

                def inner(hh, lp):
                    out, _ = mamba2.mamba_forward(
                        lp["ssm"], rmsnorm(hh, lp["ln1"], cfg.norm_eps), cfg, mode="train", chunk=cfg.ssm_chunk
                    )
                    return hh + out, None

                h, _ = jax.lax.scan(inner, h, gp)
                h = self._shared_block_train(params["shared"], h, h0, clip, statics)
                return shard_activation(h, "btd"), None

            h, _ = jax.lax.scan(self._remat(group), h, (params["layers"], qstate["shared_clip"]))
        else:
            raise ValueError(fam)

        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return shard_activation(logits, "logits"), aux

    def _shared_block_train(self, sp, h, h0, clip, statics, block_q=512):
        cfg = self.cfg
        cat = jnp.concatenate([h, h0], axis=-1)
        a = attn.attention_train(sp["attn"], rmsnorm(cat, sp["ln1"], cfg.norm_eps), cfg, statics, clip, block_q=block_q)
        h = h + a
        f = gated_mlp(sp["mlp"], rmsnorm(h, sp["ln2"], cfg.norm_eps))
        return h + f

    def _forward_whisper_train(self, params, batch, qstate):
        cfg = self.cfg
        statics = _statics(cfg)
        enc = self._encode_audio(params, batch, qstate, statics)
        # decoder
        tok = batch["tokens"]
        h = jnp.take(params["embed"]["tokens"], tok, axis=0)
        h = h + sinusoidal_positions(h.shape[1], cfg.d_model)[None].astype(h.dtype)
        h = shard_activation(h, "btd")

        def body(h, xs):
            lp, clip, cclip = xs
            a = attn.attention_train(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip, causal=True)
            h = h + a
            kv = attn.init_cross_kv(lp["cross"], enc, cfg)
            c = attn.cross_attention(lp["cross"], rmsnorm(h, lp["ln2"], cfg.norm_eps), kv, cfg, statics, cclip)
            h = h + c
            f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps))
            return shard_activation(h + f, "btd"), None

        h, _ = jax.lax.scan(self._remat(body), h, (params["layers"], qstate["attn_clip"], qstate["cross_clip"]))
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return shard_activation(logits, "logits"), {}

    def _encode_audio(self, params, batch, qstate, statics):
        cfg = self.cfg
        x = batch["audio_embeds"]
        h = jnp.einsum("bse,ed->bsd", x, params["frontend"]["frontend_proj"].astype(x.dtype))
        h = h + sinusoidal_positions(h.shape[1], cfg.d_model)[None].astype(h.dtype)
        h = shard_activation(h, "btd")

        def body(h, xs):
            lp, clip = xs
            a = attn.attention_train(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip, causal=False)
            h = h + a
            f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return shard_activation(h + f, "btd"), None

        h, _ = jax.lax.scan(self._remat(body), h, (params["enc_layers"], qstate["enc_clip"]))
        return rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    # ----------------------------------------------------- calibration
    def calibrate(self, params, batch, qstate=None) -> dict[str, jnp.ndarray]:
        """One forward pass collecting per-layer softmax-input stats
        (paper §5.1.1: sigma of the max-subtracted attention logits, plus the
        min for the NAIVE baseline). dense/vlm/moe families; other families
        fall back to defaults (noted in DESIGN.md)."""
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm", "moe"), cfg.family
        qstate = qstate or default_qstate(cfg)
        statics = AttnStatics("exact", cfg.quant.bits, False)
        h = self._embed(params, batch)

        def body(h, lp):
            x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            sigma, smin = attn.attention_score_stats(lp["attn"], x, cfg)
            a = attn.attention_train(lp["attn"], x, cfg, statics, jnp.float32(-1.0))
            h = h + a
            if cfg.moe is not None:
                f, _ = moe.moe_ffn(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
            else:
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h + f, (sigma, smin)

        _, (sigmas, mins) = jax.lax.scan(body, h, params["layers"])
        return {"attn_sigma": sigmas, "attn_min": mins}

    def qstate_from_stats(self, stats: dict) -> dict[str, jnp.ndarray]:
        """Per-layer clip values from calibration stats, honoring cfg.quant."""
        cfg = self.cfg
        q = cfg.quant
        if q.softmax_impl == "naive":
            clips = jnp.minimum(0.5 * stats["attn_min"], -1e-3)  # (min+max)/2, max=0
        else:
            slope, intercept = clipping.PAPER_CLIP_COEFFS.get(q.bits, (None, None)) if q.clip_rule == "paper" else (None, None)
            if slope is None:
                clips = jnp.asarray(
                    [clipping.optimal_clip_analytic(float(s), q.bits) for s in jax.device_get(stats["attn_sigma"])],
                    jnp.float32,
                )
            else:
                clips = slope * stats["attn_sigma"] + intercept
        return {"attn_clip": clips.astype(jnp.float32)}

    # --------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        cache: dict = {"pos": jnp.zeros((), jnp.int32)}
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            cache["k"] = jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, max_seq, dh), dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
        elif fam == "ssm":
            cache.update(self._ssm_cache(cfg.num_layers, batch, dtype))
        elif fam == "hybrid":
            n_groups = cfg.num_layers // cfg.hybrid_period
            ssm = self._ssm_cache(cfg.num_layers, batch, dtype)
            cache["conv"] = ssm["conv"].reshape((n_groups, cfg.hybrid_period) + ssm["conv"].shape[1:])
            cache["ssm"] = ssm["ssm"].reshape((n_groups, cfg.hybrid_period) + ssm["ssm"].shape[1:])
            cache["k"] = jnp.zeros((n_groups, batch, cfg.num_kv_heads, max_seq, dh), dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
        elif fam == "audio":
            cache["k"] = jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, max_seq, dh), dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
            cache["cross_k"] = jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, cfg.enc_seq, dh), dtype)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        return cache

    def init_block_pool(self, num_blocks: int, block_size: int, dtype=jnp.bfloat16,
                        mesh=None) -> dict:
        """Global paged KV pool: {"k","v"} of (L, num_blocks, KV, bs, Dh).

        The device half of the paged cache (DESIGN.md §3): blocks are the unit
        of allocation/sharing; ``runtime.kv_pool.BlockPool`` owns the ids and
        block 0 is the reserved null sink for gated writes. Attention token
        decoders only — paging needs a ragged KV sequence axis to page.

        ``dtype=jnp.int8`` builds the quantized pool (DESIGN.md §6): int8
        payloads plus "k_scale"/"v_scale" planes of (L, num_blocks, KV) fp32
        per-block per-kv-head dequant scales, zero-initialized (0 = "scale
        not yet seeded by a first write").

        ``dtype="int4"`` (string sentinel — there is no jnp int4) builds the
        packed pool (DESIGN.md §10): uint8 payloads of (L, num_blocks, KV,
        bs, Dh//2) holding two head-dim-adjacent nibbles per byte, the fp32
        block-scale planes above, plus "k_sub"/"v_sub" uint8 planes of
        (L, num_blocks, KV, n_sub) 4-bit sub-block scale codes (0 = unset).

        ``mesh`` places the pool sharded at construction (DESIGN.md §9):
        payloads per ``sharding.block_pool_spec`` (kv-heads over 'model'
        when divisible, else replicated), scale planes per
        ``sharding.block_scale_spec`` / ``sharding.block_sub_scale_spec`` —
        so each tensor-parallel shard allocates only its local head
        partition.

        ssm / hybrid families build the architecture-agnostic *StatePool*
        instead (DESIGN.md §13): per-layer plane groups keyed by what each
        layer kind needs. Mamba2 layers get a "conv" plane of
        (L, num_blocks, w-1, ch) raw conv-tail rows plus an "ssm" plane of
        (L, num_blocks, nh, hd, ds) fp32 SSD states, checkpointed at block
        granularity — block b holds the recurrent state *through the last
        live token of block b*, which is exactly what a resume, CoW fork or
        prefix hit at that block boundary must see. Hybrid (zamba2) adds the
        shared-attention "k"/"v" planes of (G, num_blocks, KV, bs, Dh) with
        G = num_layers // hybrid_period. The block axis sits at position 1
        in *every* plane, so the engine's generic block-copy / table
        machinery never inspects plane kinds — blocks are blocks. State
        planes are full-precision only (quantized pools are attention-only).
        """
        from repro.kernels import ops

        cfg = self.cfg
        fam = cfg.family
        if fam in ("ssm", "hybrid"):
            if ops.kv_cache_is_int4(dtype) or jnp.dtype(dtype) == jnp.int8:
                raise ValueError(
                    f"quantized block pools are attention-only; family={fam!r} "
                    "state planes must stay full-precision (DESIGN.md §13)"
                )
            pool = dict(self._ssm_cache(cfg.num_layers, num_blocks, dtype))
            if fam == "hybrid":
                n_groups = cfg.num_layers // cfg.hybrid_period
                dh = cfg.resolved_head_dim
                k = jnp.zeros((n_groups, num_blocks, cfg.num_kv_heads, block_size, dh), dtype)
                pool["k"], pool["v"] = k, jnp.zeros_like(k)
            if mesh is not None:
                from jax.sharding import NamedSharding

                from repro.runtime import sharding as shd

                specs = shd.state_pool_specs(cfg, mesh)
                pool["conv"] = jax.device_put(pool["conv"], NamedSharding(mesh, specs["conv"]))
                pool["ssm"] = jax.device_put(pool["ssm"], NamedSharding(mesh, specs["ssm"]))
                if "k" in pool:
                    sh = NamedSharding(mesh, shd.block_pool_spec(cfg, mesh))
                    pool["k"] = jax.device_put(pool["k"], sh)
                    pool["v"] = jax.device_put(pool["v"], sh)
            return pool
        assert fam in ("dense", "vlm", "moe"), (
            f"paged KV pool requires an attention KV cache, got family={fam!r}"
        )
        dh = cfg.resolved_head_dim
        int4 = ops.kv_cache_is_int4(dtype)
        if int4:
            if dh % 2 != 0:
                raise ValueError(f"packed int4 pool needs an even head_dim, got {dh}")
            k = jnp.zeros((cfg.num_layers, num_blocks, cfg.num_kv_heads, block_size, dh // 2),
                          jnp.uint8)
        else:
            k = jnp.zeros((cfg.num_layers, num_blocks, cfg.num_kv_heads, block_size, dh), dtype)
        pool = {"k": k, "v": jnp.zeros_like(k)}
        if int4 or jnp.dtype(dtype) == jnp.int8:
            # two distinct buffers: the engine donates the pool pytree into
            # its jitted steps, and aliased leaves can't be donated twice
            shape = (cfg.num_layers, num_blocks, cfg.num_kv_heads)
            pool["k_scale"] = jnp.zeros(shape, jnp.float32)
            pool["v_scale"] = jnp.zeros(shape, jnp.float32)
        if int4:
            sub_shape = (cfg.num_layers, num_blocks, cfg.num_kv_heads, ops.kv4_num_sub(block_size))
            pool["k_sub"] = jnp.zeros(sub_shape, jnp.uint8)
            pool["v_sub"] = jnp.zeros(sub_shape, jnp.uint8)
        if mesh is not None:
            from jax.sharding import NamedSharding

            from repro.runtime import sharding as shd

            sh = NamedSharding(mesh, shd.block_pool_spec(cfg, mesh))
            pool["k"] = jax.device_put(pool["k"], sh)
            pool["v"] = jax.device_put(pool["v"], sh)
            if "k_scale" in pool:
                ssh = NamedSharding(mesh, shd.block_scale_spec(cfg, mesh))
                pool["k_scale"] = jax.device_put(pool["k_scale"], ssh)
                pool["v_scale"] = jax.device_put(pool["v_scale"], ssh)
            if "k_sub" in pool:
                sub_sh = NamedSharding(mesh, shd.block_sub_scale_spec(cfg, mesh))
                pool["k_sub"] = jax.device_put(pool["k_sub"], sub_sh)
                pool["v_sub"] = jax.device_put(pool["v_sub"], sub_sh)
        return pool

    def _ssm_cache(self, n_layers, batch, dtype):
        cfg = self.cfg
        return {
            "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, mamba2.conv_channels(cfg)), dtype),
            "ssm": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        }

    def prefill(self, params, batch, cache, qstate=None, lens=None):
        """Process the full prompt; fill the cache. Returns (last_logits, cache).

        lens: optional (B,) true prompt lengths for right-padded batches —
        logits are gathered at ``lens - 1`` per row instead of the last
        position (causal masking keeps the padded tail from affecting live
        positions; the serving engine masks it out of decode via kv_lens)."""
        cfg = self.cfg
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        fam = cfg.family
        tokens = batch["tokens"]
        B, S = tokens.shape

        if fam in ("dense", "vlm", "moe"):
            h = self._embed(params, batch)

            def body(carry, xs):
                h = carry
                lp, clip = xs
                a, (kh, vh) = attn.attention_prefill(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip)
                h = h + a
                if cfg.moe is not None:
                    f = moe.moe_ffn_infer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
                else:
                    f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
                return shard_activation(h + f, "btd"), (kh, vh)

            h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], qstate["attn_clip"]))
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], ks.astype(cache["k"].dtype), 0, axis=3
            )
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vs.astype(cache["v"].dtype), 0, axis=3
            )
        elif fam == "ssm":
            h = self._embed(params, batch)

            def body(h, lp):
                out, c = mamba2.mamba_forward(
                    lp["ssm"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, mode="prefill", chunk=cfg.ssm_chunk
                )
                return shard_activation(h + out, "btd"), c

            h, cs = jax.lax.scan(body, h, params["layers"])
            cache = dict(cache)
            cache["conv"] = cs["conv"].astype(cache["conv"].dtype)
            cache["ssm"] = cs["ssm"]
        elif fam == "hybrid":
            h = self._embed(params, batch)
            h0 = h

            def group(carry, xs):
                h = carry
                gp, clip = xs

                def inner(hh, lp):
                    out, c = mamba2.mamba_forward(
                        lp["ssm"], rmsnorm(hh, lp["ln1"], cfg.norm_eps), cfg, mode="prefill", chunk=cfg.ssm_chunk
                    )
                    return hh + out, c

                h, cs = jax.lax.scan(inner, h, gp)
                cat = jnp.concatenate([h, h0], axis=-1)
                a, (kh, vh) = attn.attention_prefill(
                    params["shared"]["attn"], rmsnorm(cat, params["shared"]["ln1"], cfg.norm_eps), cfg, statics, clip
                )
                h = h + a
                f = gated_mlp(params["shared"]["mlp"], rmsnorm(h, params["shared"]["ln2"], cfg.norm_eps))
                return shard_activation(h + f, "btd"), (cs, kh, vh)

            h, (cs, ks, vs) = jax.lax.scan(group, h, (params["layers"], qstate["shared_clip"]))
            cache = dict(cache)
            cache["conv"] = cs["conv"].astype(cache["conv"].dtype)
            cache["ssm"] = cs["ssm"]
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks.astype(cache["k"].dtype), 0, axis=3)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs.astype(cache["v"].dtype), 0, axis=3)
        elif fam == "audio":
            enc = self._encode_audio(params, batch, qstate, statics)
            h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
            h = h + sinusoidal_positions(S, cfg.d_model)[None].astype(h.dtype)

            def body(h, xs):
                lp, clip, cclip = xs
                a, (kh, vh) = attn.attention_prefill(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip)
                h = h + a
                ckv = attn.init_cross_kv(lp["cross"], enc, cfg)
                c = attn.cross_attention(lp["cross"], rmsnorm(h, lp["ln2"], cfg.norm_eps), ckv, cfg, statics, cclip)
                h = h + c
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps))
                return shard_activation(h + f, "btd"), (kh, vh, ckv[0], ckv[1])

            h, (ks, vs, cks, cvs) = jax.lax.scan(
                body, h, (params["layers"], qstate["attn_clip"], qstate["cross_clip"])
            )
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks.astype(cache["k"].dtype), 0, axis=3)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs.astype(cache["v"].dtype), 0, axis=3)
            cache["cross_k"] = cks.astype(cache["cross_k"].dtype)
            cache["cross_v"] = cvs.astype(cache["cross_v"].dtype)
        else:
            raise ValueError(fam)

        cache["pos"] = jnp.asarray(S, jnp.int32)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        if lens is None:
            h_last = h[:, -1]
        else:
            idx = jnp.clip(lens.astype(jnp.int32) - 1, 0, S - 1)
            h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", h_last, params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return logits, cache

    def decode_step_ragged(self, params, tokens, cache, lens, qstate=None):
        """Slot-batched decode over a ragged KV cache (continuous batching).

        tokens: (S, 1) one next-token per slot; cache k/v: (L, S, KV, Smax, Dh);
        lens: (S,) live cache length per slot (the new token is written at
        index lens[b] and attends to lens[b]+1 positions). Returns
        (logits (S, V), new_cache). Attention families only — SSM/hybrid/audio
        caches have no ragged sequence axis to batch over.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm", "moe"), (
            f"ragged decode requires an attention KV cache, got family={cfg.family!r}"
        )
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        new_cache = dict(cache)

        def body(h, xs):
            lp, clip, ck, cv = xs
            a, nk, nv = attn.attention_decode_ragged(
                lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip, ck, cv, lens
            )
            h = h + a
            if cfg.moe is not None:
                f = moe.moe_ffn_infer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
            else:
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h + f, (nk, nv)

        h, (nk, nv) = jax.lax.scan(body, h, (params["layers"], qstate["attn_clip"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = nk, nv
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return logits, new_cache

    def decode_step_paged(self, params, tokens, pool, block_tables, lens, active,
                          qstate=None, *, block_size=None):
        """Slot-batched decode over a block-paged KV pool (DESIGN.md §3).

        The paged sibling of ``decode_step_ragged``: tokens (S, 1); pool k/v
        (L, N, KV, bs, Dh) (+ "k_scale"/"v_scale" planes when the pool is
        int8 — DESIGN.md §6 — and additionally "k_sub"/"v_sub" sub-block
        scale-code planes when it is packed int4, payload dtype uint8 —
        DESIGN.md §10); block_tables (S, MB); lens (S,) live length per
        slot; active (S,) bool — inactive slots' KV writes are gated to the
        null block so recycled blocks can't be corrupted mid-chunk. With
        ``cfg.quant.use_fused_kernel`` + exaq, every layer's attention runs
        the fused Pallas paged-decode kernel (block-table-indexed pool loads,
        no HBM gather — DESIGN.md §3); otherwise the gather-then-dispatch
        reference. Returns (logits (S, V), new_pool).

        ssm / hybrid families route to the StatePool decode branch
        (DESIGN.md §13), which needs the kw-only ``block_size`` (the pure
        state planes have no block-size axis to read it from).
        """
        cfg = self.cfg
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        if cfg.family in ("ssm", "hybrid"):
            return self._decode_step_paged_state(
                params, h, pool, block_tables, lens, active, qstate, statics, block_size
            )
        assert cfg.family in ("dense", "vlm", "moe"), (
            f"paged decode requires an attention KV cache, got family={cfg.family!r}"
        )
        int4 = pool["k"].dtype == jnp.uint8
        quantized = int4 or pool["k"].dtype == jnp.int8

        def body(h, xs):
            lp, clip, pk, pv, *sc = xs
            a, nkv = attn.attention_decode_paged(
                lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip,
                pk, pv, block_tables, lens, active, *sc,
            )
            h = h + a
            if cfg.moe is not None:
                f = moe.moe_ffn_infer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
            else:
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h + f, nkv

        keys = ("k", "v") + (("k_scale", "v_scale") if quantized else ()) \
            + (("k_sub", "v_sub") if int4 else ())
        xs = (params["layers"], qstate["attn_clip"]) + tuple(pool[k] for k in keys)
        h, nkv = jax.lax.scan(body, h, xs)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return logits, dict(zip(keys, nkv))

    def _decode_step_paged_state(self, params, h, pool, block_tables, lens, active,
                                 qstate, statics, block_size):
        """State-family decode over the paged StatePool (DESIGN.md §13).

        Position ``lens[s]`` is being decoded, so the recurrent state
        *through* position ``lens[s]-1`` is read from block ``(lens-1)//bs``
        (where the previous decode step or the prefill checkpointed it) and
        the updated state through ``lens[s]`` is written to block
        ``lens//bs``. A full block's final checkpoint (its state through its
        last token) lands while the write index still points *at* that
        block; every later step writes strictly past it, so completed
        (shareable, registered) blocks are never touched again — only the
        partial tail block is overwritten in place, which is why the host
        never registers partial blocks for state pools. Because every step
        here is the same per-token ``_ssd_chunk`` / conv-window math as the
        chunked prefill, preempt-and-recompute and prefix reuse reproduce
        the uninterrupted trajectory bitwise.
        """
        cfg = self.cfg
        bs = block_size
        assert bs is not None, "state-family paged decode needs block_size"
        read_bi = jnp.maximum(lens - 1, 0) // bs
        write_bi = lens // bs
        read_blk = jnp.take_along_axis(block_tables, read_bi[:, None], axis=1)[:, 0]
        # inactive slots write to the reserved null block (id 0) so recycled
        # blocks can't be corrupted mid-chunk — same gating as the KV planes
        write_blk = jnp.where(
            active, jnp.take_along_axis(block_tables, write_bi[:, None], axis=1)[:, 0], 0
        )

        def step(lp, hh, pconv, pssm):
            cc = jnp.take(pconv, read_blk, axis=0)
            cs = jnp.take(pssm, read_blk, axis=0)
            out, c = mamba2.mamba_forward(
                lp["ssm"], rmsnorm(hh, lp["ln1"], cfg.norm_eps), cfg, mode="decode",
                cache={"conv": cc, "ssm": cs},
            )
            nconv = pconv.at[write_blk].set(c["conv"].astype(pconv.dtype))
            nssm = pssm.at[write_blk].set(c["ssm"])
            return hh + out, nconv, nssm

        if cfg.family == "ssm":
            def body(hh, xs):
                lp, pconv, pssm = xs
                hh, nconv, nssm = step(lp, hh, pconv, pssm)
                return hh, (nconv, nssm)

            h, (nconv, nssm) = jax.lax.scan(body, h, (params["layers"], pool["conv"], pool["ssm"]))
            new_pool = {"conv": nconv, "ssm": nssm}
        else:  # hybrid: groups of mamba layers + the weight-shared attention block
            ng = cfg.num_layers // cfg.hybrid_period
            pconv = pool["conv"].reshape((ng, cfg.hybrid_period) + pool["conv"].shape[1:])
            pssm = pool["ssm"].reshape((ng, cfg.hybrid_period) + pool["ssm"].shape[1:])
            h0 = h

            def group(hh, xs):
                gp, clip, gconv, gssm, pk, pv = xs

                def inner(hhh, ys):
                    lp, lconv, lssm = ys
                    hhh, nconv, nssm = step(lp, hhh, lconv, lssm)
                    return hhh, (nconv, nssm)

                hh, (nconv, nssm) = jax.lax.scan(inner, hh, (gp, gconv, gssm))
                cat = jnp.concatenate([hh, h0], axis=-1)
                a, nkv = attn.attention_decode_paged(
                    params["shared"]["attn"], rmsnorm(cat, params["shared"]["ln1"], cfg.norm_eps),
                    cfg, statics, clip, pk, pv, block_tables, lens, active,
                )
                hh = hh + a
                f = gated_mlp(params["shared"]["mlp"], rmsnorm(hh, params["shared"]["ln2"], cfg.norm_eps))
                return hh + f, (nconv, nssm) + tuple(nkv)

            h, (nconv, nssm, nk, nv) = jax.lax.scan(
                group, h,
                (params["layers"], qstate["shared_clip"], pconv, pssm, pool["k"], pool["v"]),
            )
            new_pool = {
                "conv": nconv.reshape(pool["conv"].shape),
                "ssm": nssm.reshape(pool["ssm"].shape),
                "k": nk, "v": nv,
            }
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"].astype(h.dtype))
        return self._mask_padded_vocab(logits), new_pool

    def prefill_paged_chunk(self, params, tokens, pool, block_table, start, chunk_len,
                            blk_t, off_t, qstate=None, *, block_size=None):
        """One fixed-size chunk of a paged prefill for a single request.

        tokens (1, C) right-padded chunk; block_table (MB,) the request's
        table; start scalar — tokens already cached (prefix hits + previous
        chunks); chunk_len scalar — live tokens in this chunk; blk_t/off_t
        (C,) host-computed scatter targets (padded rows -> null block).
        Attends causally by global position against the request's window, so
        a prompt prefilled in chunks matches a one-shot prefill bit-for-bit
        (DESIGN.md §3). With ``cfg.quant.use_fused_kernel`` + exaq, every
        layer's attention runs the fused Pallas paged-prefill kernel
        (block-table-indexed pool reads, no dense window gather —
        DESIGN.md §7); otherwise the gather-then-attend reference. int8
        pools carry "k_scale"/"v_scale" planes that the scatter seeds and
        the read paths dequantize against (DESIGN.md §6); packed int4
        pools add "k_sub"/"v_sub" sub-block scale-code planes
        (DESIGN.md §10).
        Returns (logits (1, V) at the chunk's last live row, new_pool) —
        only the final chunk's logits seed sampling.

        ssm / hybrid families route to the StatePool chunk branch
        (DESIGN.md §13): per-token SSD recurrence with block-granular
        conv/ssm checkpoints scattered to ``blk_t[::block_size]``.
        """
        cfg = self.cfg
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        if cfg.family in ("ssm", "hybrid"):
            return self._prefill_paged_chunk_state(
                params, h, pool, block_table, start, chunk_len, blk_t, off_t,
                qstate, statics, block_size,
            )
        assert cfg.family in ("dense", "vlm", "moe"), (
            f"paged prefill requires an attention KV cache, got family={cfg.family!r}"
        )
        int4 = pool["k"].dtype == jnp.uint8
        quantized = int4 or pool["k"].dtype == jnp.int8

        def body(h, xs):
            lp, clip, pk, pv, *sc = xs
            a, nkv = attn.attention_prefill_chunk(
                lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip,
                pk, pv, block_table, start, blk_t, off_t, *sc,
            )
            h = h + a
            if cfg.moe is not None:
                f = moe.moe_ffn_infer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
            else:
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h + f, nkv

        keys = ("k", "v") + (("k_scale", "v_scale") if quantized else ()) \
            + (("k_sub", "v_sub") if int4 else ())
        xs = (params["layers"], qstate["attn_clip"]) + tuple(pool[k] for k in keys)
        h, nkv = jax.lax.scan(body, h, xs)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        idx = jnp.clip(chunk_len - 1, 0, tokens.shape[1] - 1)
        h_last = jax.lax.dynamic_index_in_dim(h[0], idx, axis=0, keepdims=False)
        logits = jnp.einsum("d,dv->v", h_last, params["head"].astype(h.dtype))[None]
        logits = self._mask_padded_vocab(logits)
        return logits, dict(zip(keys, nkv))

    def _prefill_paged_chunk_state(self, params, h, pool, block_table, start, chunk_len,
                                   blk_t, off_t, qstate, statics, block_size):
        """State-family chunked prefill over the paged StatePool (DESIGN.md §13).

        Resume state: the chunk needs the conv tail / SSD state through
        global position ``start - 1``. The host keeps ``start`` block-aligned
        (prefix hits truncate to full blocks; prefill_chunk % block_size == 0
        is an engine gate), so that state is exactly the checkpoint of block
        ``(start-1)//bs``; ``start == 0`` selects zeros instead (jnp.where
        keeps the select NaN-safe regardless of what the gathered block
        holds). This chunk's checkpoints scatter to ``blk_t[::bs]`` — the
        host points pad rows at the null block, so pads-only blocks discard
        themselves. Pad rows inside a live block are dt-masked in
        ``mamba_paged_prefill_chunk``: the carried state passes through them
        bitwise, so the tail checkpoint holds the state through the last
        live token.
        """
        cfg = self.cfg
        bs = block_size
        assert bs is not None, "state-family paged prefill needs block_size"
        ckpt_blks = blk_t[::bs]
        read_blk = block_table[jnp.maximum(start - 1, 0) // bs]

        def step(lp, hh, pconv, pssm):
            cp = jnp.where(start > 0, pconv[read_blk], jnp.zeros_like(pconv[read_blk]))[None]
            h0 = jnp.where(start > 0, pssm[read_blk], jnp.zeros_like(pssm[read_blk]))[None]
            out, conv_ck, ssm_ck = mamba2.mamba_paged_prefill_chunk(
                lp["ssm"], rmsnorm(hh, lp["ln1"], cfg.norm_eps), cfg, cp, h0, chunk_len,
                block_size=bs,
            )
            nconv = pconv.at[ckpt_blks].set(conv_ck.astype(pconv.dtype))
            nssm = pssm.at[ckpt_blks].set(ssm_ck)
            return hh + out, nconv, nssm

        if cfg.family == "ssm":
            def body(hh, xs):
                lp, pconv, pssm = xs
                hh, nconv, nssm = step(lp, hh, pconv, pssm)
                return shard_activation(hh, "btd"), (nconv, nssm)

            h, (nconv, nssm) = jax.lax.scan(body, h, (params["layers"], pool["conv"], pool["ssm"]))
            new_pool = {"conv": nconv, "ssm": nssm}
        else:  # hybrid: groups of mamba layers + the weight-shared attention block
            ng = cfg.num_layers // cfg.hybrid_period
            pconv = pool["conv"].reshape((ng, cfg.hybrid_period) + pool["conv"].shape[1:])
            pssm = pool["ssm"].reshape((ng, cfg.hybrid_period) + pool["ssm"].shape[1:])
            h0_tok = h

            def group(hh, xs):
                gp, clip, gconv, gssm, pk, pv = xs

                def inner(hhh, ys):
                    lp, lconv, lssm = ys
                    hhh, nconv, nssm = step(lp, hhh, lconv, lssm)
                    return hhh, (nconv, nssm)

                hh, (nconv, nssm) = jax.lax.scan(inner, hh, (gp, gconv, gssm))
                cat = jnp.concatenate([hh, h0_tok], axis=-1)
                a, nkv = attn.attention_prefill_chunk(
                    params["shared"]["attn"], rmsnorm(cat, params["shared"]["ln1"], cfg.norm_eps),
                    cfg, statics, clip, pk, pv, block_table, start, blk_t, off_t,
                )
                hh = hh + a
                f = gated_mlp(params["shared"]["mlp"], rmsnorm(hh, params["shared"]["ln2"], cfg.norm_eps))
                return shard_activation(hh + f, "btd"), (nconv, nssm) + tuple(nkv)

            h, (nconv, nssm, nk, nv) = jax.lax.scan(
                group, h,
                (params["layers"], qstate["shared_clip"], pconv, pssm, pool["k"], pool["v"]),
            )
            new_pool = {
                "conv": nconv.reshape(pool["conv"].shape),
                "ssm": nssm.reshape(pool["ssm"].shape),
                "k": nk, "v": nv,
            }
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        idx = jnp.clip(chunk_len - 1, 0, h.shape[1] - 1)
        h_last = jax.lax.dynamic_index_in_dim(h[0], idx, axis=0, keepdims=False)
        logits = jnp.einsum("d,dv->v", h_last, params["head"].astype(h.dtype))[None]
        return self._mask_padded_vocab(logits), new_pool

    def verify_paged_chunk(self, params, tokens, pool, block_table, start,
                           blk_t, off_t, qstate=None):
        """Speculative-verify window for one request (DESIGN.md §12).

        tokens (1, C) = [pending, draft_1..draft_{C-1}] at global positions
        ``start + i``; block_table (MB,) is the slot prefix composed with the
        draft branch's blocks; blk_t/off_t (C,) host-computed scatter targets
        inside the branch. The body is ``prefill_paged_chunk`` — same fused
        paged-prefill kernel, same chunk-invariant two-pass histogram combine
        (§2/§7), so row i's attention is bit-identical to the decode step
        that would have consumed the same context — with two differences:

          * scale seeding runs under ``seed_first_row`` so rejected rows
            can't perturb quantized scales vanilla decode would have seeded
            differently (attention.py, §12);
          * logits come back for EVERY row (C, V), because the accept rule
            needs the target's argmax after each draft position, not just
            the last.

        Returns (logits (C, V), new_pool).
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm", "moe"), (
            f"paged verify requires an attention KV cache, got family={cfg.family!r}"
        )
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        int4 = pool["k"].dtype == jnp.uint8
        quantized = int4 or pool["k"].dtype == jnp.int8
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)

        def body(h, xs):
            lp, clip, pk, pv, *sc = xs
            a, nkv = attn.attention_prefill_chunk(
                lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip,
                pk, pv, block_table, start, blk_t, off_t, *sc,
                seed_first_row=True,
            )
            h = h + a
            if cfg.moe is not None:
                f = moe.moe_ffn_infer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
            else:
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h + f, nkv

        keys = ("k", "v") + (("k_scale", "v_scale") if quantized else ()) \
            + (("k_sub", "v_sub") if int4 else ())
        xs = (params["layers"], qstate["attn_clip"]) + tuple(pool[k] for k in keys)
        h, nkv = jax.lax.scan(body, h, xs)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("cd,dv->cv", h[0], params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return logits, dict(zip(keys, nkv))

    def decode_step(self, params, tokens, cache, qstate=None):
        """tokens: (B, 1) -> (logits (B, V), new cache)."""
        cfg = self.cfg
        qstate = qstate or default_qstate(cfg)
        statics = _statics(cfg)
        fam = cfg.family
        pos = cache["pos"]
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
        new_cache = dict(cache)

        if fam in ("dense", "vlm", "moe"):
            def body(h, xs):
                lp, clip, ck, cv = xs
                a, nk, nv = attn.attention_decode(
                    lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip, ck, cv, pos,
                    sp=cfg.quant.sp_decode,
                )
                h = h + a
                if cfg.moe is not None:
                    f = moe.moe_ffn_infer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
                else:
                    f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
                return h + f, (nk, nv)

            h, (nk, nv) = jax.lax.scan(body, h, (params["layers"], qstate["attn_clip"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = nk, nv
        elif fam == "ssm":
            def body(h, xs):
                lp, cc, cs = xs
                out, c = mamba2.mamba_forward(
                    lp["ssm"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, mode="decode",
                    cache={"conv": cc, "ssm": cs},
                )
                return h + out, (c["conv"], c["ssm"])

            h, (ncc, ncs) = jax.lax.scan(body, h, (params["layers"], cache["conv"], cache["ssm"]))
            new_cache["conv"], new_cache["ssm"] = ncc.astype(cache["conv"].dtype), ncs
        elif fam == "hybrid":
            h0 = h

            def group(h, xs):
                gp, clip, cc, cs, ck, cv = xs

                def inner(hh, ys):
                    lp, icc, ics = ys
                    out, c = mamba2.mamba_forward(
                        lp["ssm"], rmsnorm(hh, lp["ln1"], cfg.norm_eps), cfg, mode="decode",
                        cache={"conv": icc, "ssm": ics},
                    )
                    return hh + out, (c["conv"], c["ssm"])

                h, (ncc, ncs) = jax.lax.scan(inner, h, (gp, cc, cs))
                cat = jnp.concatenate([h, h0], axis=-1)
                a, nk, nv = attn.attention_decode(
                    params["shared"]["attn"], rmsnorm(cat, params["shared"]["ln1"], cfg.norm_eps),
                    cfg, statics, clip, ck, cv, pos,
                )
                h = h + a
                f = gated_mlp(params["shared"]["mlp"], rmsnorm(h, params["shared"]["ln2"], cfg.norm_eps))
                return h + f, (ncc, ncs, nk, nv)

            h, (ncc, ncs, nk, nv) = jax.lax.scan(
                group, h, (params["layers"], qstate["shared_clip"], cache["conv"], cache["ssm"], cache["k"], cache["v"])
            )
            new_cache.update(conv=ncc.astype(cache["conv"].dtype), ssm=ncs, k=nk, v=nv)
        elif fam == "audio":
            smax = cache["k"].shape[3]
            pe = sinusoidal_positions(smax, cfg.d_model)
            h = h + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None].astype(h.dtype)

            def body(h, xs):
                lp, clip, cclip, ck, cv, xk, xv = xs
                a, nk, nv = attn.attention_decode(
                    lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, statics, clip, ck, cv, pos
                )
                h = h + a
                c = attn.cross_attention(lp["cross"], rmsnorm(h, lp["ln2"], cfg.norm_eps), (xk, xv), cfg, statics, cclip)
                h = h + c
                f = gated_mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps))
                return h + f, (nk, nv)

            h, (nk, nv) = jax.lax.scan(
                body, h,
                (params["layers"], qstate["attn_clip"], qstate["cross_clip"],
                 cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
            )
            new_cache["k"], new_cache["v"] = nk, nv
        else:
            raise ValueError(fam)

        new_cache["pos"] = pos + 1
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"].astype(h.dtype))
        logits = self._mask_padded_vocab(logits)
        return logits, new_cache


def build_model(cfg) -> Model:
    return Model(cfg)
