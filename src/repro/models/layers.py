"""Shared NN primitives (pure-functional, pytree params).

Activation sharding hints go through ``repro.runtime.sharding.shard_activation``
(no-op outside a mesh context) so the same model code runs on 1 CPU device and
on the 512-chip production mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gated_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP. params: wi (D, 2F) packing [gate, up]; wo (F, D)."""
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    return jnp.einsum("...f,fd->...d", silu(gate) * up, params["wo"].astype(x.dtype))


def init_gated_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": truncated_normal_init(k1, (d_model, 2 * d_ff), d_model**-0.5, dtype),
        "wo": truncated_normal_init(k2, (d_ff, d_model), d_ff**-0.5, dtype),
    }


# ---------------------------------------------------------------- RoPE

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, N, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if ang.ndim == 2:  # (S, Dh/2) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d_model))
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d_model // 2)]))
    return pe
