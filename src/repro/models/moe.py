"""Mixture-of-Experts FFN (GShard/Mixtral-style capacity routing).

Token-choice top-k with per-group capacity; dispatch/combine are einsums (the
SPMD-friendly formulation — XLA turns them into all-to-alls under expert
parallelism, experts sharded over the 'model' axis). Supports DeepSeekMoE
fine-grained experts with always-on shared experts.

The router softmax stays exact (rank-sensitive top-k, negligible cost) —
documented design choice in DESIGN.md §5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_gated_mlp, silu, truncated_normal_init
from repro.runtime.sharding import shard_activation


def init_moe(key, cfg, dtype=jnp.float32) -> dict:
    m = cfg.moe
    d = cfg.d_model
    fe = m.d_expert or cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": truncated_normal_init(ks[0], (d, m.num_experts), d**-0.5, jnp.float32),
        "moe_wi": truncated_normal_init(ks[1], (m.num_experts, d, 2 * fe), d**-0.5, dtype),
        "moe_wo": truncated_normal_init(ks[2], (m.num_experts, fe, d), fe**-0.5, dtype),
    }
    if m.num_shared:
        p["shared"] = init_gated_mlp(ks[3], d, m.num_shared * fe, dtype)
    return p


def moe_ffn(params: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (out, aux) with load-balance / z losses."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    gs = min(m.group_size, T)
    assert T % gs == 0, f"tokens {T} not divisible by moe group {gs}"
    G = T // gs
    xg = x.reshape(G, gs, D)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # exact router softmax
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)  # (G, gs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # capacity per expert per group
    cap = int(-(-gs * m.top_k * m.capacity_factor // m.num_experts))
    cap = max(4, -(-cap // 4) * 4)
    E = m.num_experts

    assign = jax.nn.one_hot(idx, E, dtype=jnp.int32).sum(axis=2)            # (G, gs, E) in {0,1}
    weights = (jax.nn.one_hot(idx, E, dtype=jnp.float32) * gate_vals[..., None]).sum(axis=2)
    pos = jnp.cumsum(assign, axis=1) - assign                                # (G, gs, E) slot ids
    keep = (pos < cap) & (assign > 0)
    # dispatch: (G, gs, E, C) one-hot of the slot; combine carries the gate
    dispatch = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    combine = dispatch * weights[..., None].astype(x.dtype)

    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, xg)                   # (E, G, C, D)
    expert_in = shard_activation(expert_in, "experts")
    h = jnp.einsum("egcd,edf->egcf", expert_in, params["moe_wi"].astype(x.dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    hh = silu(gate) * up
    expert_out = jnp.einsum("egcf,efd->egcd", hh, params["moe_wo"].astype(x.dtype))
    expert_out = shard_activation(expert_out, "experts")
    out = jnp.einsum("gtec,egcd->gtd", combine, expert_out).reshape(B, S, D)

    if m.num_shared:
        from repro.models.layers import gated_mlp

        out = out + gated_mlp(params["shared"], x)

    # aux: switch load-balance + router z-loss
    density = assign.astype(jnp.float32).mean(axis=1)                         # (G, E) fraction routed
    router_prob = probs.mean(axis=1)                                          # (G, E)
    lb = E * jnp.mean(jnp.sum(density * router_prob, axis=-1)) * m.top_k
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - keep.sum() / jnp.maximum(assign.sum(), 1)
    aux = {"moe_lb": lb, "moe_z": z, "moe_dropped": dropped}
    return out, aux


def moe_ffn_infer(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Capacity-free MoE FFN for the serving paths: x (B, S, D) -> (B, S, D).

    Inference routing drops the training-time per-group capacity grid (no aux
    losses, no token dropping): router dispatch is a dense per-token weight
    over experts, batched across all live tokens of the decode/prefill call in
    one all-experts einsum. No token count / group divisibility constraints,
    so the jitted decode scan can route a ragged slot batch directly — the
    MoE leg of the StatePool story (stateless but batched, DESIGN.md §13).
    """
    m = cfg.moe
    E = m.num_experts
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # exact router softmax (DESIGN.md §5)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)  # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    weights = (jax.nn.one_hot(idx, E, dtype=jnp.float32)
               * gate_vals[..., None]).sum(axis=2)  # (B, S, E)

    h = jnp.einsum("bsd,edf->bsef", x, params["moe_wi"].astype(x.dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    hh = silu(gate) * up
    expert_out = jnp.einsum("bsef,efd->bsed", hh, params["moe_wo"].astype(x.dtype))
    out = jnp.einsum("bse,bsed->bsd", weights.astype(x.dtype), expert_out)

    if m.num_shared:
        from repro.models.layers import gated_mlp

        out = out + gated_mlp(params["shared"], x)
    return out
