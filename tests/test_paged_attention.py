"""Fused Pallas paged-decode EXAQ attention vs the gather reference
(DESIGN.md §3, fused paged decode): ragged/GQA parity matrix, dead-tail
clamping in ``gather_block_kv``, the bytes-moved model, and bit-exact greedy
parity through ``PagedEngine`` — at fp32/bf16, on the int8 per-block-scaled
pool (DESIGN.md §6), and on the packed-int4 sub-block-scaled pool
(DESIGN.md §10), whose fused paths must match the *dequantizing* gather
oracle and whose engine-level greedy tokens must track the fp32 pool's.
All kernels run in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exaq_params
from repro.kernels import ops
from repro.kernels.exaq_paged_attention import paged_decode_bytes_model

RNG = np.random.default_rng(42)


def _pool_setup(S, KV, bs, MB, D, *, dtype=jnp.float32, seed=0):
    """Random pool + disjoint per-slot tables (ids permuted so table order
    differs from pool order — a bug that ignores the table shows up)."""
    rng = np.random.default_rng(seed)
    N = 1 + S * MB
    pk = jnp.asarray(rng.normal(0, 1, (N, KV, bs, D)), dtype)
    pv = jnp.asarray(rng.normal(0, 1, (N, KV, bs, D)), dtype)
    ids = rng.permutation(np.arange(1, N))[: S * MB].reshape(S, MB)
    return pk, pv, jnp.asarray(ids, jnp.int32)


# int8 pools quantize via the shared `quantize_pool` fixture (conftest.py).


@pytest.mark.parametrize("group", [1, 4, 8])
@pytest.mark.parametrize("bits", [2, 3])
def test_fused_matches_gather_reference_gqa(group, bits):
    """GQA group sizes 1/4/8: fused kernel == global-grid gather reference."""
    KV, bs, MB, D = 2, 8, 4, 64
    H, S = KV * group, 3
    p = exaq_params(1.5, bits)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=group)
    lens = jnp.asarray([5, 17, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    assert got.shape == (S, H, 1, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_ragged_lens_edges():
    """Ragged kv_lens: empty slot (len 0), exactly one block, exactly on a
    block boundary, one past a boundary, full table."""
    S, H, KV, bs, MB, D = 5, 4, 2, 8, 3, 32
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=1)
    lens = jnp.asarray([0, bs, 2 * bs, 2 * bs + 1, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # a slot with no live KV attends to nothing and outputs exactly zero
    assert float(jnp.abs(got[0]).max()) == 0.0


def test_fused_single_block_sequences():
    """MB == 1: the whole cache is one block per slot (init decode state)."""
    S, H, KV, bs, D = 2, 8, 8, 16, 128
    p = exaq_params(2.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, 1, D, seed=2)
    lens = jnp.asarray([1, bs], jnp.int32)
    got = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_shared_prefix_blocks():
    """Two slots whose tables name the SAME prefix blocks (the prefix-cache
    layout): per-slot results match gathering each window independently."""
    S, H, KV, bs, MB, D = 2, 4, 2, 8, 4, 64
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, _ = _pool_setup(S, KV, bs, MB, D, seed=3)
    tbl = jnp.asarray([[1, 2, 3, 4], [1, 2, 5, 6]], jnp.int32)  # shared 2-block prefix
    lens = jnp.asarray([3 * bs + 2, 4 * bs], jnp.int32)
    got = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_bf16_pool():
    """Serving dtype: bf16 pool, fp32 q — fused and reference agree (both
    promote K/V to fp32 before the dots)."""
    S, H, KV, bs, MB, D = 3, 4, 4, 8, 3, 64
    p = exaq_params(1.5, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, dtype=jnp.bfloat16, seed=4)
    lens = jnp.asarray([7, 20, 24], jnp.int32)
    got = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------------------- int8 KV pool

@pytest.mark.parametrize("group", [1, 4, 8])
def test_fused_int8_matches_dequantizing_gather_gqa(group, quantize_pool):
    """GQA 1/4/8 at int8: the fused kernel (scalar-prefetched scales, dequant
    in VMEM) matches the dequantizing gather oracle to <= 1e-5 — both read
    the same codes and the same per-(block, kv-head) scales (DESIGN.md §6)."""
    KV, bs, MB, D = 2, 8, 4, 64
    H, S = KV * group, 3
    p = exaq_params(1.5, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=10 + group)
    qk, qv, ks, vs = quantize_pool(pk, pv)
    lens = jnp.asarray([5, 17, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, use_kernel=True)
    want = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                      k_scale=ks, v_scale=vs, use_kernel=False)
    assert got.shape == (S, H, 1, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_int8_close_to_fp_oracle(quantize_pool):
    """Quantization error is bounded by the scale grid: int8 outputs stay
    within a few dequant ulps of the fp32-pool result on the same values."""
    S, H, KV, bs, MB, D = 2, 4, 2, 8, 3, 32
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=11)
    qk, qv, ks, vs = quantize_pool(pk, pv)
    lens = jnp.asarray([7, 2 * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    # attention output is a convex combination of dequantized V rows, so the
    # error is bounded by V's dequant step (scale/2) plus the K-side weight
    # perturbation — small multiples of the grid, not tight equality
    tol = 10 * float(jnp.max(vs)) / 2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


def test_fused_int8_dead_tail_and_null_block_zero(quantize_pool):
    """Ragged lens at int8: empty slot reads only the null block (scale 0,
    payload 0) and outputs exactly zero; boundary lens match the oracle."""
    S, H, KV, bs, MB, D = 5, 4, 2, 8, 3, 32
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=12)
    qk, qv, ks, vs = quantize_pool(pk, pv)
    lens = jnp.asarray([0, bs, 2 * bs, 2 * bs + 1, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, use_kernel=True)
    want = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                      k_scale=ks, v_scale=vs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert float(jnp.abs(got[0]).max()) == 0.0


def test_gather_requires_scales_iff_int8(quantize_pool):
    pk, pv, tbl = _pool_setup(1, 2, 8, 2, 16, seed=13)
    qk, qv, ks, vs = quantize_pool(pk, pv)
    with pytest.raises(ValueError):
        ops.gather_block_kv(qk, qv, tbl)  # int8 without scales
    with pytest.raises(ValueError):
        ops.gather_block_kv(qk, qv, tbl, None, ks, None)  # int8 missing v_scale
    with pytest.raises(ValueError):
        ops.gather_block_kv(pk, pv, tbl, None, ks, vs)  # fp with scales
    p = exaq_params(1.0, 2)
    lens = jnp.asarray([8], jnp.int32)
    with pytest.raises(ValueError):
        ops.paged_decode_attention(jnp.zeros((1, 2, 1, 16)), qk, qv, tbl, lens, p, 0.25,
                                   k_scale=ks, use_kernel=True)  # fused missing v_scale


# ------------------------------------------------------- packed int4 KV pool

@pytest.mark.parametrize("group", [1, 4, 8])
def test_fused_int4_matches_dequantizing_gather_gqa(group, quantize_pool_int4):
    """GQA 1/4/8 at packed int4: the fused kernel (in-VMEM nibble unpack,
    scalar-prefetched block scales + sub codes) matches the dequantizing
    gather oracle to <= 1e-5 — both decode the same bytes through
    ``kv4_effective_scale``'s exact multiply order (DESIGN.md §10)."""
    KV, bs, MB, D = 2, 8, 4, 64
    H, S = KV * group, 3
    p = exaq_params(1.5, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=20 + group)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    assert qk.dtype == jnp.uint8 and qk.shape[-1] == D // 2
    lens = jnp.asarray([5, 17, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                     use_kernel=True)
    want = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                      k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                      use_kernel=False)
    assert got.shape == (S, H, 1, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_int4_narrow_head_dim_padding(quantize_pool_int4):
    """D // 2 below the 128-lane tile: the packed pool pads to a full lane
    tile and the q/out planes pad to twice that — garbage K padding lanes
    must be zero-killed (a bug here poisons every score), V garbage must be
    sliced away. D=6 makes the padding dominate the payload."""
    S, H, KV, bs, MB, D = 2, 2, 2, 4, 2, 6
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=24)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    lens = jnp.asarray([3, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                     use_kernel=True)
    want = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                      k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                      use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_int4_dead_tail_and_null_block_zero(quantize_pool_int4):
    """Ragged lens at int4: the empty slot reads only the null block (scale
    0, sub codes 0, payload 0) and outputs exactly zero; block-boundary lens
    match the oracle. Sub-code-0 tails decoding to exact zero is the codec
    property test_kv_packing pins; this asserts the kernel honors it."""
    S, H, KV, bs, MB, D = 5, 4, 2, 8, 3, 32
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=25)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    lens = jnp.asarray([0, bs, 2 * bs, 2 * bs + 1, MB * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                     use_kernel=True)
    want = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                      k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                      use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert float(jnp.abs(got[0]).max()) == 0.0


def test_fused_int4_close_to_fp_oracle(quantize_pool_int4):
    """int4 error tracks the sub-block grid: outputs stay within small
    multiples of the effective scale step of the fp32-pool result."""
    S, H, KV, bs, MB, D = 2, 4, 2, 8, 3, 32
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (S, H, 1, D)), jnp.float32)
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=26)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    lens = jnp.asarray([7, 2 * bs], jnp.int32)
    got = ops.paged_decode_attention(q, qk, qv, tbl, lens, p, D**-0.5,
                                     k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                     use_kernel=True)
    want = ops.paged_decode_attention(q, pk, pv, tbl, lens, p, D**-0.5, use_kernel=False)
    # V's dequant step is at most the block scale (code 15/15); K noise
    # perturbs convex weights — small multiples of the grid bound it
    tol = 10 * float(jnp.max(vs)) / 2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


def test_int4_requires_sub_planes_and_fp_forbids_them(quantize_pool_int4):
    pk, pv, tbl = _pool_setup(1, 2, 8, 2, 16, seed=27)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    with pytest.raises(ValueError):
        ops.gather_block_kv(qk, qv, tbl, None, ks, vs)  # packed without subs
    with pytest.raises(ValueError):
        ops.gather_block_kv(qk, qv, tbl, None, ks, vs, ksub, None)  # missing v_sub
    with pytest.raises(ValueError):
        ops.gather_block_kv(pk, pv, tbl, None, None, None, ksub, vsub)  # fp with subs
    p = exaq_params(1.0, 2)
    lens = jnp.asarray([8], jnp.int32)
    with pytest.raises(ValueError):
        ops.paged_decode_attention(jnp.zeros((1, 2, 1, 16)), qk, qv, tbl, lens, p, 0.25,
                                   k_scale=ks, v_scale=vs, k_sub=ksub,
                                   use_kernel=True)  # fused missing v_sub


# --------------------------------------------------------- gather dead tails

def test_gather_block_kv_clamps_dead_tail_to_null_block():
    """With kv_lens, table entries past ceil(len/bs) gather the null block
    instead of whatever stale block ids pad the table — and the live prefix
    is untouched."""
    S, KV, bs, MB, D = 2, 2, 8, 4, 16
    pk, pv, tbl = _pool_setup(S, KV, bs, MB, D, seed=5)
    lens = jnp.asarray([bs + 3, 0], jnp.int32)  # slot0: 2 live blocks; slot1: none
    kg, vg = ops.gather_block_kv(pk, pv, tbl, lens)
    kg_all, _ = ops.gather_block_kv(pk, pv, tbl)
    # live blocks identical to the unclamped gather
    np.testing.assert_array_equal(np.asarray(kg[0, :, : 2 * bs]), np.asarray(kg_all[0, :, : 2 * bs]))
    # dead tail reads block 0 (the null block), not the table's padding ids
    tail = np.asarray(kg[0, :, 2 * bs :]).reshape(KV, MB - 2, bs, D)
    for b in range(MB - 2):
        np.testing.assert_array_equal(tail[:, b], np.asarray(pk[0]))
    tail1 = np.asarray(vg[1]).reshape(KV, MB, bs, D)
    for b in range(MB):
        np.testing.assert_array_equal(tail1[:, b], np.asarray(pv[0]))


def test_repeat_kv_shared_implementation():
    """The single shared GQA repeat: identity at group 1, interleaved copy
    otherwise, and both historical call signatures route through it."""
    from repro.models.attention import _repeat_kv as model_repeat

    x = jnp.asarray(RNG.normal(0, 1, (2, 3, 5, 4)), jnp.float32)
    assert ops.repeat_kv(x, 1) is x
    r = ops.repeat_kv(x, 2)
    assert r.shape == (2, 6, 5, 4)
    np.testing.assert_array_equal(np.asarray(r[:, 0]), np.asarray(r[:, 1]))
    np.testing.assert_array_equal(np.asarray(r[:, 2]), np.asarray(x[:, 1]))
    assert model_repeat is ops.repeat_kv  # one implementation, two call sites
    q = jnp.zeros((2, 6, 5, 4))
    kr, vr = ops._repeat_kv(q, x, x)
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(r))
    np.testing.assert_array_equal(np.asarray(vr), np.asarray(r))


# ------------------------------------------------------------- bytes model

def test_bytes_model_2x_at_half_occupancy():
    """Acceptance: modeled decode-step KV bytes-read drop >= 2x vs the
    gather path at 50% average occupancy."""
    S, MB, bs = 8, 32, 16
    lens = np.full((S,), MB * bs // 2, np.int64)  # 50% occupancy
    m = paged_decode_bytes_model(slots=S, kv_heads=8, max_blocks=MB, block_size=bs,
                                 head_dim=128, kv_lens=lens)
    assert m["bytes_reduction_x"] >= 2.0
    # sanity: gather reads live blocks + writes/reads the dense rectangle
    # (x K+V), fused is (2K + 1V) over live blocks only
    assert m["gather_then_read_bytes"] == (m["live_blocks"] + 2 * S * MB) * 2 * m["block_bytes"]
    assert m["fused_pool_read_bytes"] == 3 * m["live_blocks"] * m["block_bytes"]


def test_bytes_model_kv_dtype_element_sizes():
    """kv_dtype parameterization: fused bytes scale with the element size
    (int8 pays the per-block scale reads; its gather path prices the dense
    dequantized copy at fp32), and int8 cuts >= 1.8x vs bf16."""
    S, MB, bs, KVH, D = 8, 32, 16, 8, 128
    lens = np.full((S,), MB * bs // 2, np.int64)
    kw = dict(slots=S, kv_heads=KVH, max_blocks=MB, block_size=bs, head_dim=D, kv_lens=lens)
    m32 = paged_decode_bytes_model(kv_dtype="fp32", **kw)
    m16 = paged_decode_bytes_model(kv_dtype="bf16", **kw)
    m8 = paged_decode_bytes_model(kv_dtype="int8", **kw)
    assert m32["fused_pool_read_bytes"] == 2 * m16["fused_pool_read_bytes"]
    assert m8["block_bytes"] == KVH * (bs * D + 4)  # payload + 4B scale per head
    assert m16["fused_pool_read_bytes"] / m8["fused_pool_read_bytes"] >= 1.8
    # the gather path's dense intermediate is dequantized fp32 for int8 pools
    assert m8["gather_then_read_bytes"] == (
        m8["live_blocks"] * m8["block_bytes"] + 2 * S * MB * KVH * bs * D * 4) * 2


def test_bytes_model_int4_block_bytes_and_reductions():
    """Packed int4 block pricing (DESIGN.md §10): half-byte payload plus the
    fp32 block scale and one sub code per sub-block, per kv head. Acceptance
    floors: >= 1.8x fewer fused pool bytes than int8, >= 3.5x than bf16."""
    from repro.kernels.ops import kv4_num_sub

    S, MB, bs, KVH, D = 8, 32, 16, 8, 128
    lens = np.full((S,), MB * bs // 2, np.int64)
    kw = dict(slots=S, kv_heads=KVH, max_blocks=MB, block_size=bs, head_dim=D, kv_lens=lens)
    m16 = paged_decode_bytes_model(kv_dtype="bf16", **kw)
    m8 = paged_decode_bytes_model(kv_dtype="int8", **kw)
    m4 = paged_decode_bytes_model(kv_dtype="int4", **kw)
    n_sub = kv4_num_sub(bs)
    assert m4["block_bytes"] == KVH * (bs * D // 2 + 4 + n_sub)
    assert m8["fused_pool_read_bytes"] / m4["fused_pool_read_bytes"] >= 1.8
    assert m16["fused_pool_read_bytes"] / m4["fused_pool_read_bytes"] >= 3.5
    # the gather path's dense intermediate is dequantized fp32 for int4 too
    assert m4["gather_then_read_bytes"] == (
        m4["live_blocks"] * m4["block_bytes"] + 2 * S * MB * KVH * bs * D * 4) * 2


# ------------------------------------------------------- engine greedy parity

def test_paged_engine_fused_matches_gather_greedy():
    """Bit-exact greedy parity through PagedEngine: the fused kernel and the
    gather reference decode the same trace to the same tokens (both are
    global-grid EXAQ; DESIGN.md §3)."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.engine import PagedEngine

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(21)
    spec = [(7, 6), (19, 4), (5, 8)]
    prompts = [rng.integers(0, cfg.vocab_size, n) for n, _ in spec]

    outs = {}
    for fused in (False, True):
        eng = PagedEngine(cfg, params, max_slots=2, max_seq=48, steps_per_sync=4,
                          block_size=8, prefill_chunk=8, seed=0, fused=fused)
        uids = [eng.submit(p, g) for p, (_, g) in zip(prompts, spec)]
        res = eng.run()
        outs[fused] = [res[u].tokens for u in uids]
    assert outs[True] == outs[False]


@pytest.mark.parametrize("cache_dtype", [jnp.int8, "int4"], ids=["int8", "int4"])
def test_paged_engine_quantized_fused_matches_gather_greedy(cache_dtype):
    """Engine-level greedy parity on quantized pools: the fused kernel and
    the gather reference dequantize the same codes with the same scales
    (int8: per-block, DESIGN.md §6; int4: block x sub-block grid, §10), so
    paged decode emits identical tokens either way."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.engine import PagedEngine

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(21)
    spec = [(7, 6), (19, 4), (5, 8)]
    prompts = [rng.integers(0, cfg.vocab_size, n) for n, _ in spec]

    outs = {}
    for fused in (False, True):
        eng = PagedEngine(cfg, params, max_slots=2, max_seq=48, steps_per_sync=4,
                          block_size=8, prefill_chunk=8, seed=0, fused=fused,
                          cache_dtype=cache_dtype)
        uids = [eng.submit(p, g) for p, (_, g) in zip(prompts, spec)]
        res = eng.run()
        outs[fused] = [res[u].tokens for u in uids]
    assert outs[True] == outs[False]


@pytest.fixture(scope="module")
def trained_periodic_model():
    """2-layer model briefly overfit on a periodic token stream (~10 s). A
    *trained* head is required for quantization-agreement claims — random-init
    argmax margins sit below any quantizer's noise floor (same reason
    bench_serving overfits its smoke model). Returns (cfg, params, prompts):
    EXAQ-configured inference cfg and in-distribution prompt prefixes."""
    from repro.configs import get_config
    from repro.optim.adamw import AdamW
    from repro.runtime.train import init_train_state, make_train_step

    base = get_config("yi-6b").reduced(num_layers=2)
    opt = AdamW(lr=3e-3)
    state = init_train_state(base.with_quant(softmax_impl="exact"), opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(base.with_quant(softmax_impl="exact"), opt))
    T, period, tok0 = 32, 7, 5
    seq = np.arange(T + 1) % period + tok0
    batch = {
        "tokens": jnp.asarray(np.stack([np.roll(seq, -s)[:T] for s in range(8)]), jnp.int32),
        "labels": jnp.asarray(np.stack([np.roll(seq, -s)[1 : T + 1] for s in range(8)]), jnp.int32),
    }
    for _ in range(40):
        state, _ = step(state, batch)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), state["params"])
    cfg = base.with_quant(softmax_impl="exaq", bits=2)
    pattern = np.arange(40) % period + tok0
    prompts = [pattern[:n] for n in (9, 14, 6)]
    return cfg, params, prompts


def _greedy_pool_run(cfg, params, prompts, cache_dtype):
    from repro.runtime.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_slots=2, max_seq=48, steps_per_sync=4,
                      block_size=8, prefill_chunk=8, seed=0, cache_dtype=cache_dtype)
    uids = [eng.submit(p, 8) for p in prompts]
    res = eng.run()
    return [res[u].tokens for u in uids], eng.kv_pool_bytes


def test_paged_engine_int8_matches_fp32_pool_greedy(trained_periodic_model):
    """fp32 pool vs int8 pool through the same PagedEngine trace: the
    per-block-scaled quantization error sits far below greedy argmax margins,
    so the token-match rate is asserted >= 99% (DESIGN.md §6)."""
    cfg, params, prompts = trained_periodic_model
    ref, fp32_bytes = _greedy_pool_run(cfg, params, prompts, jnp.float32)
    got, int8_bytes = _greedy_pool_run(cfg, params, prompts, jnp.int8)
    agree = np.concatenate([np.asarray(a) == np.asarray(b) for a, b in zip(ref, got)])
    assert agree.mean() >= 0.99
    # int8 payload + fp32 scales: ~4x smaller than the fp32 pool
    assert fp32_bytes > 3.5 * int8_bytes


def test_paged_engine_int4_matches_fp32_pool_greedy(trained_periodic_model):
    """fp32 pool vs packed-int4 pool on the same trace: the block x sub-block
    scale grid (DESIGN.md §10) keeps 4-bit noise below trained greedy margins
    (acceptance: >= 99% token agreement), at a pool footprint >= 1.8x smaller
    than int8 and >= 7x smaller than fp32."""
    cfg, params, prompts = trained_periodic_model
    ref, fp32_bytes = _greedy_pool_run(cfg, params, prompts, jnp.float32)
    got, int4_bytes = _greedy_pool_run(cfg, params, prompts, "int4")
    agree = np.concatenate([np.asarray(a) == np.asarray(b) for a, b in zip(ref, got)])
    assert agree.mean() >= 0.99
    _, int8_bytes = _greedy_pool_run(cfg, params, prompts, jnp.int8)
    assert int8_bytes > 1.8 * int4_bytes
    assert fp32_bytes > 7.0 * int4_bytes


@pytest.mark.parametrize("cache_dtype", [jnp.int8, "int4"], ids=["int8", "int4"])
def test_slot_engine_rejects_quantized(cache_dtype):
    from repro.configs import get_config
    from repro.runtime.engine import Engine

    cfg = get_config("yi-6b").reduced(num_layers=2)
    with pytest.raises(ValueError):
        Engine(cfg, params=None, max_slots=1, max_seq=16, cache_dtype=cache_dtype)


def test_paged_engine_fused_requires_exaq():
    from repro.configs import get_config
    from repro.runtime.engine import PagedEngine

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exact")
    with pytest.raises(ValueError):
        PagedEngine(cfg, params=None, max_slots=1, max_seq=16, fused=True)
