"""Priority/SLA scheduler tests (DESIGN.md §11): priority classes, TTFT
deadlines, deadline-aware preemption, and admission control.

Policy tests run against the pure-host ``EngineCore`` with the numpy device
emulator from ``runtime/faults.py`` — no jax, fuzz-speed. The greedy-parity
test at the bottom runs the real ``PagedEngine`` on the trained smoke model:
an adversarial trace where a low-priority long request is preempted for a
high-priority arrival must still reproduce the uncontended run's tokens
bit-exactly (preempt-and-recompute is exact — DESIGN.md §3) with no block
leaks (``audit_block_invariants``).
"""

import numpy as np
import pytest

from repro.runtime.engine_core import (
    AdmissionRejected,
    EngineCore,
    Rejected,
    Request,
)
from repro.runtime.faults import HostDeviceEmulator, audit_block_invariants

VOCAB = 40


def _core(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("block_size", 4)
    kw.setdefault("eos_id", None)
    return EngineCore(**kw)


def _drain(core, em, limit=400):
    for _ in range(limit):
        if not core.has_work():
            return
        em.step_chunk(core)
    raise AssertionError(f"engine did not drain in {limit} emulated chunks")


# ------------------------------------------------------------- queue ordering


def test_queue_orders_priority_classes_fifo_within_class(rng):
    core = _core(max_slots=1)
    a = core.submit([1, 2], 4, priority=1)
    b = core.submit([3, 4], 4, priority=0)
    c = core.submit([5, 6], 4, priority=1)
    d = core.submit([7, 8], 4, priority=0)
    assert [r.uid for r in core._queue] == [b, d, a, c]
    assert core._queue[0].uid == b  # peek surface used by engine tests
    assert len(core._queue) == 4 and bool(core._queue)


def test_default_priority_is_pure_fifo(rng):
    core = _core(max_slots=1)
    uids = [core.submit([1, 2, 3], 4) for _ in range(5)]
    assert [r.uid for r in core._queue] == uids


def test_continuation_reenters_ahead_of_its_class():
    """A preempted continuation re-sorts by its original (small) uid, so it
    beats later arrivals of the same class — the appendleft semantics the
    preempt-and-recompute mechanism was built on."""
    core = _core(max_slots=1)
    core._next_uid = 5  # uid 0 was "admitted" before the later arrivals
    late = core.submit([1, 2], 4, priority=1)
    cont = Request((1, 2, 3), 2, priority=1, uid=0)  # uid 0 < late
    core._queue.appendleft(cont)
    assert [r.uid for r in core._queue] == [0, late]


# --------------------------------------------------------- priority preemption


def test_high_priority_preempts_low_at_admission(rng):
    core = _core(max_slots=2)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    u0 = core.submit([2] * 8, 30, priority=5)
    u1 = core.submit([3] * 8, 30, priority=5)
    em.step_chunk(core)
    assert core.num_active == 2
    hi = core.submit([4] * 8, 5, priority=0)
    em.step_chunk(core)
    assert core.stats["preemptions"] >= 1, "high-priority arrival did not evict"
    assert any(not s.free and s.uid == hi for s in core._slots)
    audit_block_invariants(core)
    _drain(core, em)
    res = core.run()
    assert set(res) == {u0, u1, hi}
    # preempt-and-recompute: every request still gets its full budget
    assert [len(res[u].tokens) for u in (u0, u1, hi)] == [30, 30, 5]
    audit_block_invariants(core)


def test_equal_priority_arrivals_never_preempt(rng):
    core = _core(max_slots=1)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    core.submit([2] * 8, 30, priority=1)
    em.step_chunk(core)
    core.submit([3] * 8, 5, priority=1)  # same class: waits its turn
    em.step_chunk(core)
    assert core.stats["preemptions"] == 0
    _drain(core, em)
    assert core.stats["preemptions"] == 0


def test_mid_prefill_slot_is_preemptable(rng):
    """A prefilling slot holds blocks but produced nothing — it must be a
    legal victim, and its continuation is the original request verbatim
    (not a stale-budget corpse)."""
    core = _core(max_slots=1, max_seq=256, prefill_chunk=4)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    lo = core.submit([2] * 40, 4, priority=5)
    em.step_chunk(core)  # one 4-token chunk of 40 — still prefilling
    assert core._slots[0].prefilling
    hi = core.submit([3] * 4, 3, priority=0)
    em.step_chunk(core)
    assert core.stats["preemptions"] == 1
    audit_block_invariants(core)
    _drain(core, em)
    res = core.run()
    assert len(res[lo].tokens) == 4 and len(res[hi].tokens) == 3
    audit_block_invariants(core)


def test_victim_rank_orders_priority_then_slack(rng):
    """Preemption policy: class first, then deadline slack (none = infinite),
    then newest — under max(), the no-deadline newest low-priority slot goes
    first and the tight-deadline urgent slot goes last."""
    core = _core(max_slots=3)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    u0 = core.submit([2] * 4, 20, priority=0, deadline=10.0)
    u1 = core.submit([3] * 4, 20, priority=0, deadline=50.0)
    u2 = core.submit([4] * 4, 20, priority=0)
    em.step_chunk(core, steps=1)
    slot_of = {core._slots[i].uid: i for i in range(3) if not core._slots[i].free}
    assert set(slot_of) == {u0, u1, u2}
    order = sorted(slot_of.values(), key=core._victim_rank)
    assert [core._slots[i].uid for i in order] == [u0, u1, u2]
    # priority dominates slack: make the tight-deadline slot a worse class
    core._slots[slot_of[u0]].req = Request((2,) * 4, 20, priority=7, deadline=10.0, uid=u0)
    order = sorted(slot_of.values(), key=core._victim_rank)
    assert [core._slots[i].uid for i in order] == [u1, u2, u0]


# ------------------------------------------------------------ deadline sheds


def test_expired_deadline_sheds_with_structured_rejection(rng):
    core = _core(max_slots=1)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    u0 = core.submit([2] * 4, 30)
    em.step_chunk(core)
    late = core.submit([3] * 4, 4, deadline=core.now() + 2.0)  # 2-tick TTFT budget
    for _ in range(20):
        em.step_chunk(core)
        if late in core.sheds:
            break
    sheds = core.take_shed()
    assert late in sheds
    r = sheds[late]
    assert r.reason == "deadline" and r.retryable and r.uid == late
    assert r.backoff_hint > 0 and r.occupancy is not None
    assert core.stats["shed"] == 1
    assert late not in [q.uid for q in core._queue]
    assert core.take_shed() == {}  # drains on take
    _drain(core, em)
    res = core.run()
    assert len(res[u0].tokens) == 30  # the punctual request is untouched
    audit_block_invariants(core)


def test_preempted_continuation_survives_expired_deadline(rng):
    """TTFT deadlines gate *first-token* latency: a request that already
    produced tokens and was then preempted must not be shed when its
    deadline lapses mid-recompute — its admission was already honored."""
    core = _core(max_slots=1)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    lo = core.submit([2] * 4, 25, priority=5, deadline=core.now() + 50.0)
    em.step_chunk(core, steps=2)  # first token lands well inside the deadline
    assert any(not s.free and s.uid == lo for s in core._slots)
    hi = core.submit([3] * 4, 30, priority=0)
    em.step_chunk(core)  # preempts lo; its continuation re-queues
    assert core.stats["preemptions"] == 1
    for _ in range(60):  # run the clock far past lo's deadline
        if not core.has_work():
            break
        em.step_chunk(core)
    res = core.run()
    assert core.stats["shed"] == 0 and lo in res and hi in res
    assert len(res[lo].tokens) == 25 and len(res[hi].tokens) == 30
    audit_block_invariants(core)


# --------------------------------------------------------- admission control


def test_try_submit_sheds_at_max_inflight_with_backoff(rng):
    core = _core(max_slots=4, max_inflight=2)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    a = core.try_submit([1, 2], 4)
    b = core.try_submit([1, 2], 4)
    assert isinstance(a, int) and isinstance(b, int)
    r = core.try_submit([1, 2], 4)
    assert isinstance(r, Rejected)
    assert r.reason == "max_inflight" and r.retryable
    assert r.backoff_hint > 0 and r.occupancy is not None
    with pytest.raises(AdmissionRejected) as ei:  # raising surface agrees
        core.submit([1, 2], 4)
    assert ei.value.rejected.reason == "max_inflight"
    _drain(core, em)
    core.run()
    assert isinstance(core.try_submit([1, 2], 4), int)  # capacity came back


def test_try_submit_malformed_is_nonretryable(rng):
    core = _core()
    for prompt, max_new in ([], 4), ([1] * 64, 4), ([1], 0):
        r = core.try_submit(prompt, max_new)
        assert isinstance(r, Rejected)
        assert r.reason == "invalid" and not r.retryable
    assert core._in_system() == 0


def test_admit_watermark_sheds_under_pool_pressure(rng):
    core = _core(max_slots=4, max_seq=32, num_blocks=13, admit_watermark=0.5)
    em = HostDeviceEmulator(rng, vocab=VOCAB, eos=None)
    u0 = core.submit([2] * 28, 3)  # 7 of 12 usable blocks -> 0.58 live
    em.step_chunk(core, steps=1)
    r = core.try_submit([3] * 4, 4)
    assert isinstance(r, Rejected)
    assert r.reason == "pool_pressure" and r.retryable
    assert r.occupancy is not None and r.occupancy.live_fraction >= 0.5
    _drain(core, em)
    res = core.run()
    assert len(res[u0].tokens) == 3
    # finished blocks parked on the LRU are evictable, not live: admission resumes
    assert isinstance(core.try_submit([3] * 4, 4), int)


# ----------------------------------------------- real-engine greedy parity


# the trained `smoke_model` fixture is session-scoped in conftest.py (shared
# with the differential-fuzz and chaos suites)


def test_priority_preemption_keeps_greedy_parity(smoke_model):
    """Adversarial trace on the real engine: a low-priority long request is
    preempted (pool pressure + a high-priority arrival) and must still emit
    the exact tokens of an uncontended run — and the high-priority request's
    tokens too — with no block leaks."""
    from bench_serving import PERIOD, TOK0

    from repro.runtime.engine import PagedEngine

    cfg, params = smoke_model
    pattern = [int(t) for t in np.arange(48) % PERIOD + TOK0]
    lo_prompt, lo_new = pattern[:20], 24
    hi_prompt, hi_new = pattern[5:13], 16

    def build(num_blocks=None):
        return PagedEngine(cfg, params, max_slots=2, max_seq=64, block_size=8,
                           prefill_chunk=16, eos_id=None, seed=0,
                           num_blocks=num_blocks)

    ref = build()  # fully provisioned: no contention possible
    r_lo = ref.submit(lo_prompt, lo_new)
    r_hi = ref.submit(hi_prompt, hi_new)
    ref_out = ref.run()

    eng = build(num_blocks=7)  # 6 usable: exactly the long request's final need
    lo = eng.submit(lo_prompt, lo_new, priority=5)
    eng.step_chunk()
    eng.step_chunk()  # lo is decoding and holds most of the pool
    hi = eng.submit(hi_prompt, hi_new, priority=0, deadline=eng.now() + 100.0)
    out = eng.run()
    assert eng.stats["preemptions"] >= 1, "trace failed to force a preemption"
    assert eng.stats["shed"] == 0
    assert out[lo].tokens == ref_out[r_lo].tokens, "low-priority parity broke"
    assert out[hi].tokens == ref_out[r_hi].tokens, "high-priority parity broke"
    assert len(out[lo].tokens) == lo_new and len(out[hi].tokens) == hi_new
    audit_block_invariants(eng)
