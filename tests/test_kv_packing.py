"""Property-based suite for the packed-int4 KV codec (kernels/kv_codec.py).

Every property runs through ``run_property``: under Hypothesis (when the
container has it) each property is a function of a single case seed that
Hypothesis draws and shrinks; without it, a seeded fallback driver replays
``N_EXAMPLES`` case seeds derived from PYTEST_SEED — either way a failure
report names the exact case seed to replay (DESIGN.md §10 test contract).

The properties pin the codec's four load-bearing guarantees:

  * pack/unpack is an exact bijection for all 16 code points (and byte-level
    for all 256 byte values) — prefix-hash byte stability (I2) depends on it;
  * quantize -> dequantize error is bounded by half the effective sub-block
    scale step, elementwise, with NO saturation — the margin/seed arithmetic
    guarantees every in-block value lands strictly inside ±7;
  * the codec is shape/dtype-stable under vmap (the kernels rely on mapped
    semantics matching the direct call);
  * dead lanes decode to exactly zero: unset block scales, unset sub codes,
    and the null block all produce bit-zero fp32 — the gated-write/null-sink
    discipline reads garbage lanes as zero, never as small noise.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import PYTEST_SEED, derive_seed
from repro.kernels.kv_codec import (
    INT4_QMAX,
    KV_SCALE_MARGIN,
    kv4_dequantize_block,
    kv4_effective_scale,
    kv4_num_sub,
    kv4_quantize,
    kv4_sub_block,
    kv4_write_block_scales,
    kv4_write_sub_scales,
    kv_cache_is_int4,
    kv_cache_is_quantized,
    kv_pack_int4,
    kv_unpack_int4,
)

try:
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# default sized for the tier-1 suite (dispatch cost is dominated by per-shape
# compilation, so example count and geometry diversity are both capped); the
# scheduled long-fuzz CI job raises it via FUZZ_EXAMPLES
N_EXAMPLES = int(os.environ.get("FUZZ_EXAMPLES", "15"))


def run_property(check, nodeid: str, n: int = N_EXAMPLES):
    """Drive ``check(rng)`` over many case seeds.

    Hypothesis path: the case seed is the generated value, so minimal
    counterexamples shrink toward small seeds and the failure output prints
    the falsifying seed. Fallback path: ``n`` seeds drawn from the
    PYTEST_SEED-derived per-test stream; an AssertionError is re-raised with
    the case seed attached so the repro is one env var away.
    """
    if HAVE_HYPOTHESIS:
        given(st.integers(min_value=0, max_value=2**32 - 1))(
            lambda case_seed: check(np.random.default_rng(case_seed))
        )()
        return
    rng = np.random.default_rng(derive_seed(nodeid))
    for i in range(n):
        case_seed = int(rng.integers(0, 2**32))
        try:
            check(np.random.default_rng(case_seed))
        except AssertionError as e:
            raise AssertionError(
                f"property falsified on example {i} with case seed {case_seed} "
                f"(PYTEST_SEED={PYTEST_SEED}); {e}"
            ) from e


def _rand_geometry(rng):
    """A random small pool-block geometry: (KV, bs, D) with D even and bs
    divisible by the sub-block size. Drawn from a small set on purpose —
    every distinct shape compiles its own kernels, so diversity is spent
    where it matters (bs/sub-block structure, odd D/2) and the value
    distributions carry the rest."""
    kv = int(rng.choice([1, 2]))
    bs = int(rng.choice([1, 4, 8, 16]))
    d = int(rng.choice([2, 6, 8, 64]))
    return kv, bs, d


# ------------------------------------------------------------ pack/unpack


def test_pack_unpack_exhaustive_code_points():
    """All 16 signed code points survive pack -> unpack exactly, in every
    low/high nibble pairing (16 x 16 exhaustive)."""
    lo, hi = np.meshgrid(np.arange(-8, 8), np.arange(-8, 8), indexing="ij")
    codes = jnp.asarray(np.stack([lo.ravel(), hi.ravel()], axis=-1), jnp.int32)  # (256, 2)
    packed = kv_pack_int4(codes)
    assert packed.dtype == jnp.uint8 and packed.shape == (256, 1)
    np.testing.assert_array_equal(np.asarray(kv_unpack_int4(packed)), np.asarray(codes))


def test_unpack_pack_exhaustive_bytes():
    """The byte-level inverse: every one of the 256 uint8 values round-trips
    unpack -> pack bit-exactly, so published packed bytes are stable under
    re-encoding (prefix-hash invariant I2)."""
    b = jnp.arange(256, dtype=jnp.uint8)[:, None]
    np.testing.assert_array_equal(np.asarray(kv_pack_int4(kv_unpack_int4(b))), np.asarray(b))


def test_pack_unpack_roundtrip_random_shapes(request):
    def check(rng):
        shape = tuple(int(s) for s in rng.integers(1, 5, size=int(rng.integers(1, 4))))
        shape = shape + (int(rng.choice([2, 8, 64])),)
        codes = jnp.asarray(rng.integers(-8, 8, size=shape), jnp.int32)
        packed = kv_pack_int4(codes)
        assert packed.dtype == jnp.uint8
        assert packed.shape == shape[:-1] + (shape[-1] // 2,)
        out = kv_unpack_int4(packed)
        assert out.shape == codes.shape
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    run_property(check, request.node.nodeid)


# ------------------------------------------------- quantization error bound


def test_quantize_dequantize_error_bounded_by_sub_step(request):
    """|dequant(quantize(x)) - x| <= s_eff / 2 elementwise, where s_eff is
    the token's effective sub-block scale — i.e. rounding is the ONLY error
    source. The seed arithmetic guarantees it: the block scale is margined
    for the block amax and each sub code is the ceiling that keeps the
    margined sub-block amax inside ±7, so no value ever clips."""

    def check(rng):
        kv, bs, d = _rand_geometry(rng)
        n = int(rng.integers(2, 5))
        scale_spread = 10.0 ** rng.uniform(-3, 3)
        pool = jnp.asarray(rng.normal(0.0, scale_spread, size=(n, kv, bs, d)), jnp.float32)
        sub_bs, n_sub = kv4_sub_block(bs), kv4_num_sub(bs)
        amax = jnp.max(jnp.abs(pool), axis=(2, 3))
        scale = kv4_write_block_scales(amax, jnp.zeros_like(amax))
        amax_sub = jnp.max(jnp.abs(pool.reshape(n, kv, n_sub, sub_bs, d)), axis=(3, 4))
        codes = kv4_write_sub_scales(amax_sub, scale, jnp.zeros(amax_sub.shape, jnp.uint8))
        s_eff = kv4_effective_scale(scale, codes)  # (n, kv, n_sub)
        per_tok = jnp.repeat(s_eff, sub_bs, axis=-1)  # (n, kv, bs)
        packed = kv4_quantize(pool, per_tok)
        deq = kv4_dequantize_block(packed, scale, codes)
        err = np.asarray(jnp.abs(deq - pool))
        bound = np.asarray(per_tok)[..., None] * (0.5 + 1e-5) + 1e-12
        worst = (err - bound).max()
        assert (err <= bound).all(), f"rounding bound exceeded by {worst:.3e}"
        # and no saturation: every |value| fits strictly under QMAX * s_eff
        safe = np.asarray(per_tok)[..., None] * INT4_QMAX / KV_SCALE_MARGIN
        assert (np.abs(np.asarray(pool)) <= safe + 1e-6 * np.abs(np.asarray(pool))).all()

    run_property(check, request.node.nodeid)


def test_sub_code_seeding_is_minimal_and_immutable(request):
    """Seeded sub codes are the *smallest* code covering the margined
    sub-block amax (so quantization steps are as fine as the grid allows),
    and a second write never overwrites a set code (first-write-wins)."""

    def check(rng):
        kv, bs, d = _rand_geometry(rng)
        n_sub = kv4_num_sub(bs)
        scale = jnp.asarray(rng.uniform(0.1, 10.0, size=(2, kv)), jnp.float32)
        amax_sub = jnp.asarray(
            rng.uniform(0.0, 1.0, size=(2, kv, n_sub)) * np.asarray(scale)[..., None]
            * INT4_QMAX / KV_SCALE_MARGIN,
            jnp.float32,
        )
        codes = kv4_write_sub_scales(amax_sub, scale, jnp.zeros((2, kv, n_sub), jnp.uint8))
        c = np.asarray(codes, np.int64)
        a, s = np.asarray(amax_sub), np.asarray(scale)[..., None]
        live = a > 0
        assert ((c >= 1) == live).all(), "zero-amax sub-blocks must stay unset"
        # minimality: code covers the margin, code-1 would not (when > 1)
        cover = c * s / 15.0 * INT4_QMAX
        need = KV_SCALE_MARGIN * a
        assert (cover[live] >= need[live] * (1 - 1e-6)).all()
        under = live & (c > 1)
        step_down = (c - 1) * np.broadcast_to(s, c.shape) / 15.0 * INT4_QMAX
        assert (step_down[under] < need[under] * (1 + 1e-6)).all()
        # immutability: a rewrite with different stats returns the old codes
        amax2 = jnp.asarray(rng.uniform(0.0, 5.0, size=(2, kv, n_sub)), jnp.float32)
        again = kv4_write_sub_scales(amax2, scale, codes)
        np.testing.assert_array_equal(np.asarray(again)[live], c[live])

    run_property(check, request.node.nodeid)


# ------------------------------------------------------------ vmap stability


def test_codec_shape_dtype_stable_under_vmap(request):
    """vmapping the codec over a leading batch axis matches the direct
    batched call bit-exactly and preserves shapes/dtypes — the fused kernels
    assume mapped and direct semantics agree."""

    def check(rng):
        b = int(rng.integers(1, 4))
        kv, bs, d = _rand_geometry(rng)
        n_sub = kv4_num_sub(bs)
        codes4 = jnp.asarray(rng.integers(-8, 8, size=(b, bs, d)), jnp.int32)
        packed = kv_pack_int4(codes4)
        vp = jax.vmap(kv_pack_int4)(codes4)
        assert vp.dtype == packed.dtype and vp.shape == packed.shape
        np.testing.assert_array_equal(np.asarray(vp), np.asarray(packed))
        vu = jax.vmap(kv_unpack_int4)(packed)
        np.testing.assert_array_equal(np.asarray(vu), np.asarray(kv_unpack_int4(packed)))

        pool = jnp.asarray(rng.normal(0, 1, size=(b, kv, bs, d)), jnp.float32)
        scale = jnp.asarray(rng.uniform(0.5, 2.0, size=(b, kv)), jnp.float32)
        sub = jnp.asarray(rng.integers(1, 16, size=(b, kv, n_sub)), jnp.uint8)
        per_tok = jnp.repeat(kv4_effective_scale(scale, sub), kv4_sub_block(bs), axis=-1)
        q = kv4_quantize(pool, per_tok)
        vq = jax.vmap(kv4_quantize)(pool, per_tok)
        assert vq.dtype == q.dtype and vq.shape == q.shape
        np.testing.assert_array_equal(np.asarray(vq), np.asarray(q))
        deq = kv4_dequantize_block(q, scale, sub)
        vdeq = jax.vmap(kv4_dequantize_block)(q, scale, sub)
        assert vdeq.dtype == deq.dtype and vdeq.shape == deq.shape
        np.testing.assert_array_equal(np.asarray(vdeq), np.asarray(deq))

    run_property(check, request.node.nodeid)


# ------------------------------------------------------------- dead lanes


def test_dead_tail_lanes_decode_to_exact_zero(request):
    """Unset grids decode to bit-zero fp32: sub code 0 kills its token rows
    even under arbitrary payload bytes, and block scale 0 kills the whole
    block — the property the null-block sink and recycled-block scale resets
    rely on (a 'small noise' decode would leak garbage into attention)."""

    def check(rng):
        kv, bs, d = _rand_geometry(rng)
        n_sub, sub_bs = kv4_num_sub(bs), kv4_sub_block(bs)
        packed = jnp.asarray(rng.integers(0, 256, size=(kv, bs, d // 2)), jnp.uint8)
        scale = jnp.asarray(rng.uniform(0.5, 2.0, size=(kv,)), jnp.float32)
        sub = jnp.asarray(rng.integers(1, 16, size=(kv, n_sub)), jnp.uint8)
        dead = jnp.asarray(rng.integers(0, 2, size=(kv, n_sub)), bool)
        sub = jnp.where(dead, 0, sub)
        deq = np.asarray(kv4_dequantize_block(packed, scale, sub))
        rows_dead = np.repeat(np.asarray(dead), sub_bs, axis=-1)  # (kv, bs)
        assert (deq[rows_dead] == 0.0).all(), "sub code 0 must decode to exact zero"
        # unset block scale kills everything regardless of sub codes
        all_dead = np.asarray(kv4_dequantize_block(packed, jnp.zeros_like(scale), sub))
        assert (all_dead == 0.0).all()

    run_property(check, request.node.nodeid)


def test_quantize_zero_scale_writes_zero_codes():
    """An all-zero write (s_eff 0) stores code 0 (packed byte 0x88 pattern is
    NOT used — the +8 bias encodes code 0 as nibble 8, and dequant reads it
    back as exactly 0 once the grid is live)."""
    x = jnp.zeros((4, 8), jnp.float32)
    packed = kv4_quantize(x, jnp.zeros((4,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(kv_unpack_int4(packed)), np.zeros((4, 8)))


# ------------------------------------------------------------- misc contract


def test_sub_block_geometry_and_dtype_sentinels():
    assert kv4_sub_block(16) == 4 and kv4_num_sub(16) == 4
    assert kv4_sub_block(4) == 4 and kv4_num_sub(4) == 1
    assert kv4_sub_block(2) == 2 and kv4_num_sub(2) == 1
    with pytest.raises(ValueError, match="divisible"):
        kv4_sub_block(6)
    assert kv_cache_is_int4("int4") and not kv_cache_is_int4(jnp.int8)
    assert kv_cache_is_quantized("int4") and kv_cache_is_quantized(jnp.int8)
    assert not kv_cache_is_quantized(jnp.bfloat16)
