"""Cache/pool PartitionSpec policy: divisibility fallbacks and rank locks.

Two failure classes this file pins down (DESIGN.md §9):

  * divisibility edge cases — a mesh axis that does not divide the
    corresponding tensor dim must degrade to a *replicated* (or
    sequence-parallel) spec, never crash and never emit an invalid spec;
  * spec-rank drift — every spec function's rank must keep matching the
    cache tensors it describes (``init_cache`` / ``init_block_pool`` /
    ``_ssm_cache``), or ``device_put`` fails at runtime on the first
    sharded engine.

The spec functions only consult ``mesh.shape`` / ``mesh.axis_names``, so a
stub mesh exercises every tp/dp combination without multi-device jax.
"""

import os
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.runtime import sharding as shd  # noqa: E402


class StubMesh:
    """Duck-typed mesh: shape dict + axis_names, no devices."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def _dense_cfg(kv_heads=2):
    return get_config("yi-6b").reduced(
        num_kv_heads=kv_heads, num_heads=2 * kv_heads)


# ------------------------------------------------------------ validate_spec


def test_validate_spec_keeps_divisible_drops_indivisible():
    mesh = StubMesh(data=2, model=4)
    assert shd.validate_spec(P(None, "model"), (3, 8), mesh) == P(None, "model")
    assert shd.validate_spec(P(None, "model"), (3, 6), mesh) == P(None, None)
    assert shd.validate_spec(P("data", "model"), (6, 6), mesh) == P("data", None)


def test_validate_spec_tuple_axes_use_product():
    mesh = StubMesh(pod=2, data=3, model=2)
    spec = P(("pod", "data"), None)
    assert shd.validate_spec(spec, (12, 5), mesh) == spec  # 12 % 6 == 0
    assert shd.validate_spec(spec, (8, 5), mesh) == P(None, None)  # 8 % 6 != 0


def test_validate_spec_pads_short_specs():
    mesh = StubMesh(model=2)
    out = shd.validate_spec(P("model"), (4, 3, 5), mesh)
    assert out == P("model", None, None)
    assert len(out) == 3


# ------------------------------------------------- pool/cache spec policy


def test_block_pool_spec_shards_kv_heads_when_divisible():
    cfg = _dense_cfg(kv_heads=4)
    assert shd.block_pool_spec(cfg, StubMesh(data=1, model=2)) == \
        P(None, None, "model", None, None)
    assert shd.block_scale_spec(cfg, StubMesh(data=1, model=2)) == \
        P(None, None, "model")


def test_block_pool_spec_falls_back_to_replicated():
    """tp=4 over 2 kv heads: the pool must replicate, not crash — the engine
    then runs the single-shard kernel path (ops._tp_mesh returns None)."""
    cfg = _dense_cfg(kv_heads=2)
    mesh = StubMesh(data=2, model=4)
    assert shd.block_pool_spec(cfg, mesh) == P(None, None, None, None, None)
    assert shd.block_scale_spec(cfg, mesh) == P(None, None, None)


def test_cache_specs_fall_back_to_sequence_parallel():
    """The rectangular/slot caches have a sequence axis to fall back on:
    kv-heads indivisible -> shard sequence over 'model' instead."""
    cfg = _dense_cfg(kv_heads=2)
    div, indiv = StubMesh(data=2, model=2), StubMesh(data=2, model=4)
    assert shd.cache_spec(cfg, div) == P(None, ("data",), "model", None, None)
    assert shd.cache_spec(cfg, indiv) == P(None, ("data",), None, "model", None)
    assert shd.slot_cache_spec(cfg, div) == P(None, ("data",), "model", None, None)
    assert shd.slot_cache_spec(cfg, indiv) == P(None, ("data",), None, "model", None)


def test_ssm_cache_specs_divisibility():
    cfg = get_config("mamba2-1.3b").reduced()
    tp_ok = StubMesh(data=1, model=2)
    specs = shd.ssm_cache_specs(cfg, tp_ok)
    if cfg.ssm_heads % 2 == 0:
        assert specs["ssm"][2] == "model"
    huge = StubMesh(data=1, model=10**9)  # divides nothing
    specs = shd.ssm_cache_specs(cfg, huge)
    assert specs["ssm"][2] is None
    assert specs["conv"][3] is None


# ------------------------------------------------------------- rank locks


def test_kv_cache_spec_rank_matches_init_cache():
    cfg = _dense_cfg()
    mesh = StubMesh(data=1, model=1)
    cache = jax.eval_shape(
        lambda: build_model(cfg).init_cache(2, 32, jnp.bfloat16))
    assert len(shd.cache_spec(cfg, mesh)) == len(cache["k"].shape) == 5
    assert len(shd.slot_cache_spec(cfg, mesh)) == len(cache["v"].shape) == 5


def test_block_pool_spec_rank_matches_init_block_pool():
    cfg = _dense_cfg()
    m = build_model(cfg)
    mesh = StubMesh(data=1, model=1)
    pool = jax.eval_shape(lambda: m.init_block_pool(8, 16, jnp.int8))
    assert len(shd.block_pool_spec(cfg, mesh)) == len(pool["k"].shape) == 5
    assert len(shd.block_scale_spec(cfg, mesh)) == len(pool["k_scale"].shape) == 3
    assert pool["k_scale"].shape == pool["k"].shape[:3]  # (L, N, KV) planes


def test_ssm_cache_spec_ranks_match_ssm_cache():
    cfg = get_config("mamba2-1.3b").reduced()
    mesh = StubMesh(data=1, model=1)
    cache = jax.eval_shape(
        lambda: build_model(cfg).init_cache(2, 32, jnp.bfloat16))
    specs = shd.ssm_cache_specs(cfg, mesh)
    assert len(specs["conv"]) == len(cache["conv"].shape) == 4
    assert len(specs["ssm"]) == len(cache["ssm"].shape) == 5


def test_hybrid_cache_shardings_pad_stacked_ranks():
    """Hybrid caches stack (n_groups, period, ...) on top of the flat specs;
    cache_shardings must tail-align (prefix-pad) every spec, so the batch
    axis keeps its 'data' sharding one position deeper."""
    from repro.runtime import serve as serve_rt

    cfg = get_config("zamba2-2.7b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = jax.eval_shape(lambda: build_model(cfg).init_cache(2, 32, jnp.bfloat16))
    shardings = serve_rt.cache_shardings(cfg, mesh, cache)
    for name in ("conv", "ssm", "k", "v"):
        assert len(shardings[name].spec) == len(cache[name].shape), name


# ------------------------------------------------- trace-time TP dispatch


def test_tp_mesh_discovery_follows_pool_spec_policy():
    """ops._tp_mesh and block_pool_spec must agree: the kernel dispatch goes
    tensor-parallel exactly when the pool spec shards the kv-head axis."""
    from repro.kernels import ops

    assert ops._tp_mesh(4) is None  # no ambient mesh

    div = StubMesh(data=1, model=2)
    with shd.activation_rules(div, {}):
        assert ops._tp_mesh(4) is div
        assert ops._tp_mesh(2) is div
        assert ops._tp_mesh(3) is None  # indivisible -> single-shard path

    no_model = StubMesh(data=4)
    with shd.activation_rules(no_model, {}):
        assert ops._tp_mesh(4) is None

    tp1 = StubMesh(data=1, model=1)
    with shd.activation_rules(tp1, {}):
        assert ops._tp_mesh(4) is None  # tp=1: shard_map would be pure overhead

    assert shd.current_mesh() is None  # context restored


def test_use_mesh_roundtrip():
    mesh = StubMesh(data=1, model=2)
    assert shd.current_mesh() is None
    with shd.use_mesh(mesh):
        assert shd.current_mesh() is mesh
    assert shd.current_mesh() is None
    with shd.use_mesh(None):
        assert shd.current_mesh() is None
