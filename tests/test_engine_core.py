"""Host scheduler (runtime.engine_core) unit tests — NO device arrays.

The whole point of the EngineCore split (DESIGN.md §9) is that every paged
scheduling decision — admission, prefix matching, chunked-prefill planning,
CoW adjudication, preempt-and-recompute, the int8 fresh-scale queue — is
plain Python over numpy scalars and can be tested without compiling a
single jitted function. The first test pins the contract structurally:
importing the module must not import jax.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime.engine_core import (  # noqa: E402
    EngineCore,
    HostCore,
    PrefillChunkPlan,
    Request,
    _bucket,
)
from repro.runtime.kv_pool import NULL_BLOCK, PoolExhausted, PoolStats  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _core(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return EngineCore(**kw)


# ------------------------------------------------------------ import purity


def test_engine_core_imports_without_jax():
    """engine_core is the host half of the split: importing it must not drag
    in jax (device_step.py owns all device code)."""
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.runtime.engine_core, sys; "
         "assert 'jax' not in sys.modules, 'engine_core imported jax'; "
         "print('PURE_HOST_OK')"],
        capture_output=True, text=True, timeout=120,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")),
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "PURE_HOST_OK" in out.stdout


def test_kv_pool_imports_without_jax():
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.runtime.kv_pool, sys; "
         "assert 'jax' not in sys.modules; print('OK')"],
        capture_output=True, text=True, timeout=120,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")),
    )
    assert out.returncode == 0, out.stderr


# --------------------------------------------------------------- validation


def test_validate_rejects_empty_and_oversized():
    core = _core()
    with pytest.raises(ValueError, match="empty"):
        core.submit([], 4)
    with pytest.raises(ValueError, match="max_seq"):
        core.submit(list(range(64)), 4)
    with pytest.raises(ValueError, match="max_new"):
        core.submit([1, 2], 0)


def test_validate_rejects_request_larger_than_pool():
    core = _core(num_blocks=4)  # 3 usable blocks of 4 tokens
    with pytest.raises(ValueError, match="blocks"):
        core.submit(list(range(10)), 10)  # worst case 20 tok = 5 blocks
    core.submit(list(range(6)), 4)  # 10 tok = 3 blocks: fits


# ---------------------------------------------------------------- admission


def test_admit_allocates_table_and_parks_prefilling():
    core = _core()
    core.submit([1, 2, 3, 4, 5, 6], 4)
    assert core._admit() == 1
    s = core._slots[0]
    assert s.prefilling and not s.free
    assert len(s.table) == 2  # ceil(6/4) blocks
    assert (core._tables[0, :2] == s.table).all()
    assert (core._tables[0, 2:] == NULL_BLOCK).all()
    assert core.stats["prompt_tokens"] == 6 and core.stats["prefix_hit_tokens"] == 0


def test_admission_back_pressure_requeues_on_pool_exhaustion():
    core = _core(num_blocks=4, max_slots=2)  # 3 usable blocks
    core.submit([1] * 8, 1)   # needs 2 blocks prompt (+1 gen fits in last)
    core.submit([2] * 8, 1)
    assert core._admit() == 1  # second request cannot get 3 blocks
    assert core.num_queued == 1  # rolled back, not dropped
    assert core.pool.stats.frees >= 0  # rollback released partial allocs


def test_prefix_reuse_after_commit():
    core = _core()
    prompt = [7, 7, 7, 7, 9, 9]  # first block (4 tok) hashable
    core.submit(prompt, 4)
    core._admit()
    plan = core.plan_prefill_chunk(0)
    assert plan.n == 6 and plan.start == 0
    done = core.commit_prefill_chunk(0, plan.n)
    assert done  # whole prompt in one chunk
    # same prompt again: both blocks (full + partial tail) hit the prefix
    # index; cached clamps to len(prompt)-1 so the last token re-prefills
    core.submit(prompt, 4)
    core._admit()
    s1 = core._slots[1]
    assert s1.cached == 5
    assert s1.table == core._slots[0].table  # both blocks shared
    assert core.stats["prefix_hit_tokens"] == 5
    assert core.prefix_hit_rate == pytest.approx(5 / 12)


def test_fully_cached_prompt_still_prefills_last_token():
    core = _core()
    prompt = [3, 3, 3, 3]  # exactly one block
    core.submit(prompt, 4)
    core._admit()
    core.commit_prefill_chunk(0, core.plan_prefill_chunk(0).n)
    core.submit(prompt, 4)
    core._admit()
    # cached is clamped to len(prompt)-1: sampling needs the last token's logits
    assert core._slots[1].cached == 3
    plan = core.plan_prefill_chunk(1)
    assert plan.start == 3 and plan.n == 1


# ------------------------------------------------------------ prefill plans


def test_plan_shapes_and_scatter_targets():
    core = _core(prefill_chunk=8, block_size=4)
    core.submit(list(range(100, 110)), 4)  # 10 tokens, 2 chunks
    core._admit()
    s = core._slots[0]
    p1 = core.plan_prefill_chunk(0)
    assert isinstance(p1, PrefillChunkPlan)
    assert p1.tokens.shape == (1, 8) and p1.n == 8 and p1.start == 0
    assert (p1.tokens[0, :8] == np.arange(100, 108)).all()
    assert (p1.blk_t[:4] == s.table[0]).all() and (p1.blk_t[4:8] == s.table[1]).all()
    assert (p1.off_t[:8] == [0, 1, 2, 3, 0, 1, 2, 3]).all()
    assert not core.commit_prefill_chunk(0, p1.n)
    p2 = core.plan_prefill_chunk(0)
    assert p2.start == 8 and p2.n == 2
    assert (p2.blk_t[:2] == s.table[2]).all()
    # padded rows scatter into the null block at spread offsets
    assert (p2.blk_t[2:] == NULL_BLOCK).all()
    assert core.commit_prefill_chunk(0, p2.n)


# ------------------------------------------------------------ CoW queueing


def test_cow_fork_queues_copy_instead_of_performing_it():
    core = _core()
    prompt = [5, 5, 5, 5]
    core.submit(prompt, 8)
    core._admit()
    core.commit_prefill_chunk(0, core.plan_prefill_chunk(0).n)
    core.submit(prompt, 8)
    core._admit()  # slot 1 shares block 0's first block (refcount 2)
    shared = core._slots[1].table[0]
    assert core.pool.refcount[shared] == 2
    assert core.pending_copies == []
    core._make_writable(1, 0)
    new = core._slots[1].table[0]
    assert new != shared
    assert core.pending_copies == [(shared, new)]
    assert core._tables[1, 0] == new
    # the queue is handed over exactly once
    assert core.take_pending_copies() == [(shared, new)]
    assert core.take_pending_copies() == []


def test_exclusive_block_appends_in_place():
    core = _core()
    core.submit([1, 2, 3], 4)
    core._admit()
    blk = core._slots[0].table[0]
    core._make_writable(0, 0)
    assert core._slots[0].table[0] == blk  # refcount 1: no fork
    assert core.pending_copies == []


# ------------------------------------------------------- fresh-scale queue


def test_fresh_scale_queue_only_when_quantized():
    fp = _core(quantized=False)
    fp.submit([1, 2, 3, 4, 5], 4)
    fp._admit()
    assert fp.take_fresh_scale_ids() == []

    q = _core(quantized=True)
    q.submit([1, 2, 3, 4, 5], 4)
    q._admit()
    fresh = q.take_fresh_scale_ids()
    assert sorted(fresh) == fresh and len(fresh) == 2
    assert set(fresh) == set(q._slots[0].table)
    assert q.take_fresh_scale_ids() == []  # cleared


def test_fork_destination_escapes_scale_reset():
    """A CoW fork's scales arrive with the copied payload: its id must NOT
    sit in the fresh queue or the flush would zero the copied grid."""
    q = _core(quantized=True)
    prompt = [5, 5, 5, 5]
    q.submit(prompt, 8)
    q._admit()
    q.commit_prefill_chunk(0, q.plan_prefill_chunk(0).n)
    q.submit(prompt, 8)
    q._admit()
    q.take_fresh_scale_ids()  # drain admission allocations
    q._make_writable(1, 0)
    _, dst = q.pending_copies[0]
    assert dst not in q._fresh_blocks


# ---------------------------------------------------------------- preempt


def test_preempt_releases_blocks_and_requeues_continuation():
    core = _core()
    core.submit([1, 2, 3, 4, 5], 10)
    core._admit()
    core.commit_prefill_chunk(0, core.plan_prefill_chunk(0).n)
    req = core._slots[0].req
    core._complete_first(0, req, 42)
    core._slots[0].generated.extend([43, 44])
    core._budget[0] = 7
    blocks = list(core._slots[0].table)
    core._preempt(0)
    assert not core._active[0] and core._slots[0].free
    assert (core._tables[0] == NULL_BLOCK).all()
    for b in blocks:
        assert core.pool.refcount[b] == 0  # released (may live in LRU)
    cont = core._queue[0]
    assert cont.uid == req.uid
    assert cont.prompt == req.prompt + (42, 43, 44)
    assert cont.max_new == 7
    assert core._preempt_carry[req.uid] == [42, 43, 44]
    assert core.stats["preemptions"] == 1


def test_finish_merges_preempt_carry():
    core = _core()
    core.submit([1, 2, 3], 5)
    core._admit()
    core.commit_prefill_chunk(0, core.plan_prefill_chunk(0).n)
    req = core._slots[0].req
    core._complete_first(0, req, 10)
    core._preempt_carry[req.uid] = [8, 9]
    core._slots[0].generated.append(11)
    core._finish(0, "length")
    g = core._results[req.uid]
    assert g.tokens == [8, 9, 10, 11]
    assert not core._preempt_carry  # consumed


def test_reserve_raises_when_sole_request_cannot_grow():
    core = _core(num_blocks=4, max_slots=2, max_seq=12)  # 3 usable blocks
    core.submit([1, 2, 3, 4], 20)  # worst case clamps to max_seq = 3 blocks
    core._admit()
    core.commit_prefill_chunk(0, core.plan_prefill_chunk(0).n)
    core._complete_first(0, core._slots[0].req, 1)
    # pin the remaining blocks (as a concurrent prefill would): the sole
    # active slot can neither grow nor find a victim to preempt
    held = [core.pool.alloc(), core.pool.alloc()]
    with pytest.raises(PoolExhausted, match="only active request"):
        core._reserve_chunk_blocks(8)
    for b in held:
        core.pool.release(b)


# ---------------------------------------------------------------- plumbing


def test_step_chunk_is_device_layer_territory():
    with pytest.raises(NotImplementedError):
        HostCore(max_slots=1, max_seq=8).step_chunk()


def test_bucket_rounds_up_to_power_of_two():
    assert _bucket(1, 16) == 16
    assert _bucket(16, 16) == 16
    assert _bucket(17, 16) == 32
    assert _bucket(3, 8) == 8
    assert _bucket(9, 8) == 16


def test_pool_stats_merged_sums_fieldwise():
    a = PoolStats(allocs=3, frees=1, evictions=2, cow_copies=1, hash_hits=4, hash_misses=5)
    b = PoolStats(allocs=10, frees=20, evictions=0, cow_copies=2, hash_hits=0, hash_misses=1)
    m = PoolStats.merged([a, b])
    assert m == PoolStats(allocs=13, frees=21, evictions=2, cow_copies=3,
                          hash_hits=4, hash_misses=6)
    assert PoolStats.merged([]) == PoolStats()


def test_request_uses_host_greedy_default():
    """engine_core cannot import runtime.sampling (it imports jax); the host
    default must be an independent greedy sentinel with the same fields."""
    r = Request((1,), 1)
    assert r.sampling.temperature == 0.0
    assert r.sampling.top_k == 0
    assert r.sampling.top_p == 1.0
