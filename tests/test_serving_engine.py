"""Continuous-batching engine + sampling suite (runtime/engine, runtime/sampling)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import serve as serve_rt
from repro.runtime.engine import Engine
from repro.runtime.sampling import GREEDY, SamplingParams, sample_temperature, sample_tokens


def _cfg(impl="exact", **kw):
    return get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl=impl, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


# ------------------------------------------------------------------ sampling

def test_sampling_greedy_is_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 1, (5, 64)), jnp.float32)
    z = jnp.zeros((5,))
    got = sample_tokens(logits, z, z.astype(jnp.int32), jnp.ones((5,)), jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))


def test_sampling_top_k_restricts_support():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(0, 2, (4, 128)), jnp.float32)
    topk_sets = np.asarray(jax.lax.top_k(logits, 3)[1])
    temps = jnp.full((4,), 1.5)
    ks = jnp.full((4,), 3, jnp.int32)
    ps = jnp.ones((4,))
    for i in range(32):
        got = np.asarray(sample_tokens(logits, temps, ks, ps, jax.random.PRNGKey(i)))
        for row in range(4):
            assert got[row] in topk_sets[row]


def test_sampling_top_p_tiny_nucleus_is_greedy():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(0, 3, (4, 128)), jnp.float32)
    got = sample_tokens(logits, jnp.ones((4,)), jnp.zeros((4,), jnp.int32),
                        jnp.full((4,), 1e-6), jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))


def test_sampling_per_row_mixed_params():
    """Greedy rows stay deterministic while sampled rows vary with the key."""
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(0, 1, (2, 256)), jnp.float32)
    temps = jnp.asarray([0.0, 2.0])
    ks = jnp.zeros((2,), jnp.int32)
    ps = jnp.ones((2,))
    draws = {tuple(np.asarray(sample_tokens(logits, temps, ks, ps, jax.random.PRNGKey(i))))
             for i in range(16)}
    assert len({d[0] for d in draws}) == 1  # greedy row fixed
    assert len({d[1] for d in draws}) > 1   # sampled row varies


def test_sample_temperature_matches_greedy_at_zero():
    """The sort-free fast path: argmax at T=0, key-dependent draws at T>0."""
    rng = np.random.default_rng(8)
    logits = jnp.asarray(rng.normal(0, 1, (3, 128)), jnp.float32)
    got = sample_temperature(logits, jnp.zeros((3,)), jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))
    draws = {tuple(np.asarray(sample_temperature(logits, jnp.full((3,), 1.5), jax.random.PRNGKey(i))))
             for i in range(16)}
    assert len(draws) > 1


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-2)


# ----------------------------------------------------------- ragged decode

def test_decode_step_ragged_matches_rectangular(setup):
    """With uniform lens, the ragged step reproduces decode_step logits."""
    cfg, params = setup
    m = build_model(cfg)
    rng = np.random.default_rng(4)
    B, S = 3, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache = m.init_cache(B, S + 4, jnp.float32)
    _, cache = m.prefill(params, {"tokens": toks}, cache)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    lg_rect, _ = m.decode_step(params, nxt, cache)
    lg_rag, _ = m.decode_step_ragged(
        params, nxt, {"k": cache["k"], "v": cache["v"]}, jnp.full((B,), S, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(lg_rag), np.asarray(lg_rect), atol=1e-4)


def test_engine_matches_legacy_greedy(setup):
    """Engine path (ragged slots, bucketed prefill) == legacy rectangular loop."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    B, S, G = 3, 10, 8
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    # default (bf16) cache on both paths: identical numerics, exact-match safe
    cache = serve_rt.init_cache(cfg, B, S + G)
    legacy = np.asarray(serve_rt.generate(params, cfg, prompts, G, cache=cache))
    engine = np.asarray(serve_rt.generate(params, cfg, prompts, G))
    np.testing.assert_array_equal(engine, legacy)


def test_engine_continuous_batching_ragged(setup):
    """More requests than slots, ragged prompts/budgets: every request
    completes with its own token budget and slots get reused."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    eng = Engine(cfg, params, max_slots=2, max_seq=64, steps_per_sync=4, seed=0)
    spec = [(7, 9), (19, 5), (3, 12), (5, 6), (11, 3)]
    # cycle all three chunk sampler variants: greedy / temperature-only / full
    styles = [GREEDY, SamplingParams(temperature=0.8), SamplingParams(temperature=0.8, top_k=20)]
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, n), g, styles[i % 3])
            for i, (n, g) in enumerate(spec)]
    res = eng.run()
    assert len(res) == len(spec)
    for uid, (_, g) in zip(uids, spec):
        assert len(res[uid].tokens) == g
        assert res[uid].finish_reason == "length"
    assert eng.stats["max_active"] == 2  # both slots ran concurrently
    assert eng.stats["prefills"] == len(spec)  # slots were recycled


def test_engine_eos_eviction(setup):
    """EOS mid-stream finishes the request early and frees the slot."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 9)
    ref = Engine(cfg, params, max_slots=1, max_seq=64, seed=0)
    base = ref.submit(prompt, 10)
    full = ref.run()[base].tokens
    eos = full[3]
    eng = Engine(cfg, params, max_slots=1, max_seq=64, eos_id=eos, seed=0)
    uid = eng.submit(prompt, 10)
    out = eng.run()[uid]
    assert out.finish_reason == "eos"
    assert out.tokens == full[:4]  # EOS included, nothing after


def test_engine_rejects_non_attention_family():
    cfg = get_config("mamba2-1.3b").reduced()
    with pytest.raises(ValueError):
        Engine(cfg, params=None, max_slots=1, max_seq=8)
