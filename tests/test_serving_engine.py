"""Continuous-batching engine + sampling suite (runtime/engine, runtime/sampling):
slot engine, block-paged engine (DESIGN.md §3), and their greedy parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import serve as serve_rt
from repro.runtime.engine import Engine, PagedEngine
from repro.runtime.sampling import GREEDY, SamplingParams, sample_temperature, sample_tokens


def _cfg(impl="exact", **kw):
    return get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl=impl, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


# ------------------------------------------------------------------ sampling

def test_sampling_greedy_is_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 1, (5, 64)), jnp.float32)
    z = jnp.zeros((5,))
    got = sample_tokens(logits, z, z.astype(jnp.int32), jnp.ones((5,)), jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))


def test_sampling_top_k_restricts_support():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(0, 2, (4, 128)), jnp.float32)
    topk_sets = np.asarray(jax.lax.top_k(logits, 3)[1])
    temps = jnp.full((4,), 1.5)
    ks = jnp.full((4,), 3, jnp.int32)
    ps = jnp.ones((4,))
    for i in range(32):
        got = np.asarray(sample_tokens(logits, temps, ks, ps, jax.random.PRNGKey(i)))
        for row in range(4):
            assert got[row] in topk_sets[row]


def test_sampling_top_p_tiny_nucleus_is_greedy():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(0, 3, (4, 128)), jnp.float32)
    got = sample_tokens(logits, jnp.ones((4,)), jnp.zeros((4,), jnp.int32),
                        jnp.full((4,), 1e-6), jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))


def test_sampling_per_row_mixed_params():
    """Greedy rows stay deterministic while sampled rows vary with the key."""
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(0, 1, (2, 256)), jnp.float32)
    temps = jnp.asarray([0.0, 2.0])
    ks = jnp.zeros((2,), jnp.int32)
    ps = jnp.ones((2,))
    draws = {tuple(np.asarray(sample_tokens(logits, temps, ks, ps, jax.random.PRNGKey(i))))
             for i in range(16)}
    assert len({d[0] for d in draws}) == 1  # greedy row fixed
    assert len({d[1] for d in draws}) > 1   # sampled row varies


def test_sample_temperature_matches_greedy_at_zero():
    """The sort-free fast path: argmax at T=0, key-dependent draws at T>0."""
    rng = np.random.default_rng(8)
    logits = jnp.asarray(rng.normal(0, 1, (3, 128)), jnp.float32)
    got = sample_temperature(logits, jnp.zeros((3,)), jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.argmax(logits, -1)))
    draws = {tuple(np.asarray(sample_temperature(logits, jnp.full((3,), 1.5), jax.random.PRNGKey(i))))
             for i in range(16)}
    assert len(draws) > 1


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-2)


# ----------------------------------------------------------- ragged decode

def test_decode_step_ragged_matches_rectangular(setup):
    """With uniform lens, the ragged step reproduces decode_step logits."""
    cfg, params = setup
    m = build_model(cfg)
    rng = np.random.default_rng(4)
    B, S = 3, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache = m.init_cache(B, S + 4, jnp.float32)
    _, cache = m.prefill(params, {"tokens": toks}, cache)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    lg_rect, _ = m.decode_step(params, nxt, cache)
    lg_rag, _ = m.decode_step_ragged(
        params, nxt, {"k": cache["k"], "v": cache["v"]}, jnp.full((B,), S, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(lg_rag), np.asarray(lg_rect), atol=1e-4)


def test_engine_matches_legacy_greedy(setup):
    """Engine path (ragged slots, bucketed prefill) == legacy rectangular loop."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    B, S, G = 3, 10, 8
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    # default (bf16) cache on both paths: identical numerics, exact-match safe
    cache = serve_rt.init_cache(cfg, B, S + G)
    legacy = np.asarray(serve_rt.generate(params, cfg, prompts, G, cache=cache))
    engine = np.asarray(serve_rt.generate(params, cfg, prompts, G))
    np.testing.assert_array_equal(engine, legacy)


def test_engine_continuous_batching_ragged(setup):
    """More requests than slots, ragged prompts/budgets: every request
    completes with its own token budget and slots get reused."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    eng = Engine(cfg, params, max_slots=2, max_seq=64, steps_per_sync=4, seed=0)
    spec = [(7, 9), (19, 5), (3, 12), (5, 6), (11, 3)]
    # cycle all three chunk sampler variants: greedy / temperature-only / full
    styles = [GREEDY, SamplingParams(temperature=0.8), SamplingParams(temperature=0.8, top_k=20)]
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, n), g, styles[i % 3])
            for i, (n, g) in enumerate(spec)]
    res = eng.run()
    assert len(res) == len(spec)
    for uid, (_, g) in zip(uids, spec):
        assert len(res[uid].tokens) == g
        assert res[uid].finish_reason == "length"
    assert eng.stats["max_active"] == 2  # both slots ran concurrently
    assert eng.stats["prefills"] == len(spec)  # slots were recycled


def test_engine_eos_eviction(setup):
    """EOS mid-stream finishes the request early and frees the slot."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 9)
    ref = Engine(cfg, params, max_slots=1, max_seq=64, seed=0)
    base = ref.submit(prompt, 10)
    full = ref.run()[base].tokens
    eos = full[3]
    eng = Engine(cfg, params, max_slots=1, max_seq=64, eos_id=eos, seed=0)
    uid = eng.submit(prompt, 10)
    out = eng.run()[uid]
    assert out.finish_reason == "eos"
    assert out.tokens == full[:4]  # EOS included, nothing after


def test_engine_rejects_non_attention_family():
    cfg = get_config("mamba2-1.3b").reduced()
    with pytest.raises(ValueError):
        Engine(cfg, params=None, max_slots=1, max_seq=8)
    with pytest.raises(ValueError):
        PagedEngine(cfg, params=None, max_slots=1, max_seq=8)


# ------------------------------------------------------- engine edge cases

@pytest.mark.parametrize("cls", [Engine, PagedEngine])
def test_engine_run_with_zero_requests(setup, cls):
    """run() on an idle engine returns immediately with no results."""
    cfg, params = setup
    eng = cls(cfg, params, max_slots=2, max_seq=32, seed=0)
    assert eng.run() == {}
    assert not eng.has_work()
    assert eng.stats["decode_steps"] == 0


@pytest.mark.parametrize("cls", [Engine, PagedEngine])
def test_engine_submit_validation(setup, cls):
    """Prompt length / budget validation at submit — a prompt >= max_seq must
    raise instead of truncating into the prefill buffer."""
    cfg, params = setup
    eng = cls(cfg, params, max_slots=1, max_seq=16, seed=0)
    with pytest.raises(ValueError):
        eng.submit([], 4)
    with pytest.raises(ValueError):
        eng.submit(list(range(16)), 4)  # == max_seq
    with pytest.raises(ValueError):
        eng.submit(list(range(40)), 4)  # > max_seq
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], 0)  # no token budget
    uid = eng.submit(list(range(15)), 1)  # longest admissible prompt
    out = eng.run()[uid]
    assert len(out.tokens) == 1


def test_paged_submit_rejects_request_larger_than_pool(setup):
    cfg, params = setup
    eng = PagedEngine(cfg, params, max_slots=1, max_seq=64, block_size=8,
                      num_blocks=3, seed=0)  # 2 usable blocks = 16 tokens
    with pytest.raises(ValueError):
        eng.submit(list(range(20)), 8)
    uid = eng.submit(list(range(6)), 8)  # 14 tokens worst case: fits
    assert len(eng.run()[uid].tokens) == 8


# ------------------------------------------------------------ paged engine

def test_paged_engine_matches_slot_engine(setup):
    """Bit-exact greedy parity: same ragged trace through both engines, with
    chunked prefill (chunk < prompt) and block-crossing decode."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    spec = [(7, 9), (19, 5), (3, 12), (5, 6), (11, 3)]
    prompts = [rng.integers(0, cfg.vocab_size, n) for n, _ in spec]

    eng = Engine(cfg, params, max_slots=2, max_seq=64, steps_per_sync=4, seed=0)
    uids = [eng.submit(p, g) for p, (_, g) in zip(prompts, spec)]
    res = eng.run()

    peng = PagedEngine(cfg, params, max_slots=2, max_seq=64, steps_per_sync=4,
                       block_size=8, prefill_chunk=8, seed=0)
    puids = [peng.submit(p, g) for p, (_, g) in zip(prompts, spec)]
    pres = peng.run()

    for u, pu in zip(uids, puids):
        assert res[u].tokens == pres[pu].tokens
        assert res[u].finish_reason == pres[pu].finish_reason
    assert peng.stats["prefill_chunks"] >= len(spec)  # 19-token prompt took >1 chunk
    assert peng.pool.num_live == 0  # every block reclaimed after drain


def test_paged_prefix_reuse_and_cow_fork(setup):
    """Shared-prefix reuse: a resubmitted prompt hits the cache; two live
    requests sharing a partial tail block fork it (copy-on-write) and still
    produce identical tokens."""
    cfg, params = setup
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab_size, 21)  # 2 full blocks + 5-token tail

    ref = Engine(cfg, params, max_slots=2, max_seq=64, seed=0)
    ru = ref.submit(prompt, 10)
    base = ref.run()[ru].tokens

    eng = PagedEngine(cfg, params, max_slots=2, max_seq=64, block_size=8,
                      prefill_chunk=32, seed=0)
    u1 = eng.submit(prompt, 10)
    eng.step_chunk(2)  # u1 prefilled + registered, still live
    u2 = eng.submit(prompt, 10)  # identical prompt while u1 decodes
    res = eng.run()
    assert res[u1].tokens == base
    assert res[u2].tokens == base
    assert eng.pool.stats.hash_hits >= 3  # u2 matched u1's blocks incl. the tail
    assert eng.pool.stats.cow_copies >= 1  # shared tail block forked before append
    assert eng.prefix_hit_rate > 0.4


def test_paged_cache_survives_finish_and_eviction_spares_shared(setup):
    """Blocks published to the prefix index keep serving hits after their
    owner finishes (LRU resurrection); under pool pressure eviction reclaims
    only unreferenced cached blocks, never blocks shared by live requests."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 16)  # 2 full blocks
    # pool: 1 null + 6 usable; each request needs <= 3 blocks (16+4 tokens)
    eng = PagedEngine(cfg, params, max_slots=2, max_seq=24, block_size=8,
                      prefill_chunk=16, num_blocks=7, seed=0)
    u1 = eng.submit(prompt, 4)
    first = eng.run()[u1].tokens
    assert eng.pool.num_live == 0 and eng.pool.num_evictable > 0
    # resubmit: hits the parked blocks; plus pressure from a distinct prompt
    u2 = eng.submit(prompt, 4)
    u3 = eng.submit(rng.integers(0, cfg.vocab_size, 16), 4)
    res = eng.run()
    assert res[u2].tokens == first  # cache hit reproduced the same generation
    assert eng.pool.stats.hash_hits >= 2
    assert eng.pool.num_live == 0
    # shared blocks were never evicted out from under u2 while live: its
    # output already proves it, and the pool invariant held throughout
    assert len(res[u3].tokens) == 4


def test_paged_chunked_prefill_interleaves_with_decode(setup):
    """A long prompt prefilling in small chunks never stalls the running
    batch: the short request keeps emitting decode tokens between the long
    prompt's chunks, and both match the slot engine."""
    cfg, params = setup
    rng = np.random.default_rng(12)
    short = rng.integers(0, cfg.vocab_size, 4)
    long = rng.integers(0, cfg.vocab_size, 40)

    ref = Engine(cfg, params, max_slots=2, max_seq=64, seed=0)
    r1, r2 = ref.submit(short, 12), ref.submit(long, 6)
    rres = ref.run()

    eng = PagedEngine(cfg, params, max_slots=2, max_seq=64, block_size=8,
                      prefill_chunk=8, steps_per_sync=2, seed=0)
    u1 = eng.submit(short, 12)
    eng.step_chunk()  # short active and decoding
    u2 = eng.submit(long, 6)  # 40 tokens -> 5 chunks of 8
    interleaved = 0
    while eng.has_work():
        decoding_short = eng.num_active > 0
        prefilling_long = any(not s.free and s.prefilling for s in eng._slots)
        if decoding_short and prefilling_long:
            interleaved += 1
        eng.step_chunk()
    res = eng.run()
    assert interleaved >= 2  # decode chunks ran while the long prompt prefilled
    assert res[u1].tokens == rres[r1].tokens
    assert res[u2].tokens == rres[r2].tokens


def test_paged_decode_pressure_preempts_not_crashes(setup):
    """Pool too small for all live requests to reach their budgets: decode
    growth preempts the newest request (recompute via requeue) instead of
    raising, and every request still finishes with slot-engine-identical
    greedy tokens."""
    cfg, params = setup
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in range(2)]

    ref = Engine(cfg, params, max_slots=2, max_seq=24, seed=0)
    ruids = [ref.submit(p, 16) for p in prompts]
    rres = ref.run()

    # 4 usable blocks of 8 = 32 KV tokens; two requests need up to 48 -> the
    # per-request validation passes but concurrent decode must preempt
    eng = PagedEngine(cfg, params, max_slots=2, max_seq=24, block_size=8,
                      prefill_chunk=8, num_blocks=5, seed=0)
    uids = [eng.submit(p, 16) for p in prompts]
    res = eng.run()
    assert eng.stats["preemptions"] >= 1
    for ru, u in zip(ruids, uids):
        assert res[u].tokens == rres[ru].tokens
        assert res[u].finish_reason == rres[ru].finish_reason


def test_generate_paged_path_matches_slot_path(setup):
    """runtime.serve.generate(paged=True) front-end parity."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 10)), jnp.int32)
    slot = np.asarray(serve_rt.generate(params, cfg, prompts, 8))
    paged = np.asarray(serve_rt.generate(params, cfg, prompts, 8, paged=True,
                                         block_size=8, prefill_chunk=8))
    np.testing.assert_array_equal(paged, slot)
