"""Architecture-agnostic StatePool serving + the EngineConfig/Request API.

Three layers (DESIGN.md §13):

  * Greedy parity — the paged engines must reproduce the rectangular
    reference exactly for every served state family: pure-SSM
    (``mamba2-1.3b``) and hybrid attention+SSM (``zamba2-2.7b``) against the
    unpaged ``serve.generate`` loop, MoE (``deepseek-moe-16b``) against the
    slot ``Engine``. Parity runs with fp32 params and an fp32 pool so the
    only differences left are scheduling artifacts — i.e. bugs.

  * State-plane lifecycle — block-granular SSM checkpoints must survive the
    scheduler's whole repertoire: preempt-and-recompute of a mid-sequence
    slot reproduces the uninterrupted output token-for-token, full-block
    prefix reuse produces real cache hits with unchanged output, and
    exhaustion of the shared pool under a pinned-block harness surfaces the
    structured ``PoolExhausted`` (retryable + occupancy census), never a
    bare error or corrupted state.

  * Config surface — frozen ``EngineConfig``/``Request`` are THE
    construction/submission path: the deprecated per-field kwargs still work
    (with a DeprecationWarning), mixing both is a TypeError, and every
    state-family gate (slot engine, quantized pools, ``ssm_chunk != 1``,
    speculative decoding, unaligned prefill chunks) fails fast with an
    actionable message.  ``launch/serve.py``'s ``args_to_config`` is checked
    as a pure function over the CLI namespace.
"""

import argparse
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.runtime import serve as serve_rt
from repro.runtime.engine import Engine, EngineConfig, PagedEngine
from repro.runtime.engine_core import GREEDY, Request
from repro.runtime.faults import ChaosHarness, audit_block_invariants
from repro.runtime.kv_pool import PoolExhausted

STATE_ARCHS = ("mamba2-1.3b", "zamba2-2.7b")


def _state_model(arch: str):
    """Reduced config (ssm_chunk=1 for state families — DESIGN.md §13) with
    fp32 params: parity layers must see zero dtype noise."""
    cfg = get_config(arch).reduced()
    if cfg.family in ("ssm", "hybrid"):
        cfg = dataclasses.replace(cfg, ssm_chunk=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def mamba2_model():
    return _state_model("mamba2-1.3b")


@pytest.fixture(scope="module")
def zamba2_model():
    return _state_model("zamba2-2.7b")


@pytest.fixture(scope="module")
def moe_model():
    return _state_model("deepseek-moe-16b")


def _prompts(rng, cfg, n, length):
    return [rng.integers(1, cfg.vocab_size, size=(length,)) for _ in range(n)]


# ------------------------------------------------------- greedy parity


@pytest.mark.parametrize("arch", STATE_ARCHS)
def test_state_family_paged_engine_matches_rect_generate(arch, test_seed):
    """Pure-SSM and hybrid configs through ``PagedEngine`` must emit the
    same greedy tokens as the unpaged rectangular ``serve.generate`` loop —
    chunked prefill, block tables, and per-block state checkpoints must be
    invisible in the output."""
    cfg, params = _state_model(arch)
    rng = np.random.default_rng(test_seed)
    P, G, B = 13, 8, 3
    prompts = _prompts(rng, cfg, B, P)

    rect = np.asarray(serve_rt.generate(params, cfg, jnp.asarray(np.stack(prompts)),
                                        G, kv_dtype="fp32"))

    config = EngineConfig(max_slots=B, max_seq=P + G, block_size=4,
                          prefill_chunk=8, kv_dtype="fp32")
    eng = PagedEngine(cfg, params, config)
    uids = [eng.submit(Request(p, G)) for p in prompts]
    res = eng.run()
    audit_block_invariants(eng)
    for b, uid in enumerate(uids):
        assert list(res[uid].tokens) == rect[b].tolist(), (
            f"[seed {test_seed}] {arch} row {b}: paged StatePool diverged "
            f"from the rectangular reference"
        )
    assert eng.mean_occupancy > 0.0


def test_moe_paged_engine_matches_slot_engine(moe_model, test_seed):
    """MoE layers contribute no pool state, but router dispatch must batch
    across live slots identically in both engines: paged and slot greedy
    tokens match exactly."""
    cfg, params = moe_model
    rng = np.random.default_rng(test_seed)
    P, G, B = 13, 8, 2
    prompts = _prompts(rng, cfg, B, P)
    slot = Engine(cfg, params, EngineConfig(max_slots=B, max_seq=P + G,
                                            kv_dtype="fp32"))
    paged = PagedEngine(cfg, params, EngineConfig(max_slots=B, max_seq=P + G,
                                                  block_size=4, prefill_chunk=8,
                                                  kv_dtype="fp32"))
    us = [slot.submit(Request(p, G)) for p in prompts]
    up = [paged.submit(Request(p, G)) for p in prompts]
    rs, rp = slot.run(), paged.run()
    for a, b in zip(us, up):
        assert list(rs[a].tokens) == list(rp[b].tokens)


def test_serve_generate_routes_state_families_through_paged_engine(test_seed):
    """``serve.generate(paged=True)`` is the user-facing entry: for an SSM
    config it must route through the paged StatePool engine and still match
    its own rectangular fallback (``paged=False``)."""
    cfg, params = _state_model("mamba2-1.3b")
    rng = np.random.default_rng(test_seed)
    toks = jnp.asarray(np.stack(_prompts(rng, cfg, 2, 11)))
    rect = np.asarray(serve_rt.generate(params, cfg, toks, 6, kv_dtype="fp32"))
    paged = np.asarray(serve_rt.generate(params, cfg, toks, 6, paged=True,
                                         block_size=4, prefill_chunk=8,
                                         kv_dtype="fp32"))
    np.testing.assert_array_equal(paged, rect)


# ------------------------------------------- state-plane lifecycle


def test_ssm_preempt_recompute_reproduces_uninterrupted_output(mamba2_model,
                                                               test_seed):
    """Preempt a mid-sequence Mamba2 slot, then let the scheduler recompute:
    the final token stream must equal the uninterrupted run's exactly (the
    recurrent state is rebuilt from the prompt + emitted prefix through the
    same chunk-1 scan — DESIGN.md §13)."""
    cfg, params = mamba2_model
    rng = np.random.default_rng(test_seed)
    prompt = rng.integers(1, cfg.vocab_size, size=(15,))
    G = 12
    config = EngineConfig(max_slots=1, max_seq=15 + G, block_size=4,
                          prefill_chunk=8, kv_dtype="fp32", steps_per_sync=4)

    clean = PagedEngine(cfg, params, config)
    u = clean.submit(Request(prompt, G))
    want = list(clean.run()[u].tokens)

    eng = PagedEngine(cfg, params, config)
    u = eng.submit(Request(prompt, G))
    eng.step_chunk()  # prefill chunk(s) + the first decode burst
    eng.step_chunk()
    assert eng.num_active == 1
    eng._preempt(0)  # mid-sequence preemption of the SSM slot
    audit_block_invariants(eng)
    got = list(eng.run()[u].tokens)
    assert got == want, (
        f"[seed {test_seed}] preempt-recompute diverged: {got} vs {want}"
    )
    assert eng.stats["preemptions"] == 1


def test_ssm_prefix_reuse_full_blocks_only(mamba2_model, test_seed):
    """A second request sharing a long prefix must hit the state-block prefix
    cache (full blocks only — partial state tails are mutable and never
    registered, DESIGN.md §13) and still match a cold engine's output."""
    cfg, params = mamba2_model
    rng = np.random.default_rng(test_seed)
    prefix = rng.integers(1, cfg.vocab_size, size=(12,))
    tails = [rng.integers(1, cfg.vocab_size, size=(3,)) for _ in range(2)]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    G = 6
    config = EngineConfig(max_slots=1, max_seq=15 + G, block_size=4,
                          prefill_chunk=4, kv_dtype="fp32")

    # cold engines: one request each, no reuse possible
    want = []
    for p in prompts:
        eng = PagedEngine(cfg, params, config)
        u = eng.submit(Request(p, G))
        want.append(list(eng.run()[u].tokens))

    # warm engine: sequential submissions, second must hit the prefix index
    eng = PagedEngine(cfg, params, config)
    u0 = eng.submit(Request(prompts[0], G))
    got0 = list(eng.run()[u0].tokens)
    u1 = eng.submit(Request(prompts[1], G))
    got1 = list(eng.run()[u1].tokens)
    audit_block_invariants(eng)
    assert got0 == want[0] and got1 == want[1]
    assert eng.stats["prefix_hit_tokens"] > 0, (
        "shared 12-token prefix with block_size=4 produced no state-block hits"
    )
    # full-block-only registration: no partial tail may sit in the index
    bs = eng.block_size
    for s in eng._slots:
        for h, ntok in getattr(s, "hashes", ()):
            if ntok < bs:
                assert h not in eng.pool._index


def test_state_pool_exhaustion_is_structured(mamba2_model, test_seed):
    """Starve the shared block pool under a live Mamba2 request: the
    state-plane allocation path must surface the structured ``PoolExhausted``
    (retryable flag + occupancy census), and the allocator must stay
    audit-clean — no partial state allocation leaks."""
    cfg, params = mamba2_model
    rng = np.random.default_rng(test_seed)
    config = EngineConfig(max_slots=2, max_seq=32, block_size=4,
                          prefill_chunk=8, kv_dtype="fp32")
    eng = PagedEngine(cfg, params, config)
    # 9-token prompt holds 3 state blocks (12-token capacity); max_new=6
    # forces decode growth past the boundary once the pool is pinned
    eng.submit(Request(rng.integers(1, cfg.vocab_size, size=(9,)), 6))
    eng.step_chunk()  # admit + first prefill chunk: the slot holds its blocks
    harness = ChaosHarness(eng, rng)
    harness.exhaust_pool()  # asserts the alloc-path raise is structured
    with pytest.raises(PoolExhausted) as ei:
        for _ in range(32):
            eng.step_chunk()
    assert ei.value.retryable is False  # sole request can never fit — terminal
    assert ei.value.occupancy is not None
    assert ei.value.occupancy.num_live >= len(harness.held)
    audit_block_invariants(eng, held=harness.held)
    harness.release_held()
    audit_block_invariants(eng)


# ---------------------------------------------- EngineConfig / Request API


def test_engine_config_core_kwargs_round_trip():
    config = EngineConfig(max_slots=3, max_seq=64, block_size=8,
                          prefill_chunk=16, num_blocks=20, eos_id=5,
                          steps_per_sync=4, kv_dtype="int8",
                          max_inflight=7, admit_watermark=0.5)
    kw = config.core_kwargs()
    assert kw == dict(max_slots=3, max_seq=64, block_size=8, prefill_chunk=16,
                      num_blocks=20, eos_id=5, steps_per_sync=4,
                      max_inflight=7, admit_watermark=0.5, quantized=True)
    with pytest.raises(dataclasses.FrozenInstanceError):
        config.max_slots = 4


def test_request_polymorphic_submit_rules():
    """One submission surface: a ``Request`` XOR the legacy spread."""
    from repro.runtime.faults import EmulatedEngine

    rng = np.random.default_rng(0)
    eng = EmulatedEngine(rng, EngineConfig(max_slots=2, max_seq=32))
    uid = eng.submit(Request((3, 4, 5), 4, priority=2))
    assert uid >= 0
    assert eng.submit([3, 4, 5], 4) == uid + 1  # legacy spread still works
    with pytest.raises(ValueError):
        eng.submit(Request((3,), 2), 4)  # Request AND max_new
    with pytest.raises(ValueError):
        eng.submit([3, 4, 5])  # raw prompt without max_new
    # uid is engine-assigned: a caller-supplied uid is overwritten, and the
    # submitted Request object itself is never mutated (frozen semantics)
    req = Request((7, 8), 2, uid=999)
    assert eng.submit(req) != 999 and req.uid == 999


def test_legacy_engine_kwargs_warn_and_match_config(smoke_model, test_seed):
    """The deprecated per-field kwargs still construct a working engine (with
    a DeprecationWarning) and produce the exact tokens the EngineConfig path
    does."""
    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    prompt = rng.integers(2, cfg.vocab_size, size=(10,))
    config = EngineConfig(max_slots=2, max_seq=32, block_size=8,
                          prefill_chunk=16, kv_dtype="fp32")
    new = PagedEngine(cfg, params, config)
    with pytest.warns(DeprecationWarning):
        old = PagedEngine(cfg, params, max_slots=2, max_seq=32, block_size=8,
                          prefill_chunk=16, cache_dtype=jnp.float32)
    assert old.config == config
    ua, ub = new.submit(Request(prompt, 5)), old.submit(prompt, 5)
    assert list(new.run()[ua].tokens) == list(old.run()[ub].tokens)
    with pytest.raises(TypeError):  # mixing config and legacy kwargs
        PagedEngine(cfg, params, config, max_slots=2)


@pytest.mark.parametrize("build", [
    pytest.param(lambda cfg, params: Engine(
        cfg, params, EngineConfig(max_slots=2, max_seq=32, kv_dtype="fp32")),
        id="slot-engine"),
    pytest.param(lambda cfg, params: PagedEngine(
        cfg, params, EngineConfig(max_slots=2, max_seq=32, block_size=4,
                                  prefill_chunk=8, kv_dtype="int8")),
        id="quantized-pool"),
    pytest.param(lambda cfg, params: PagedEngine(
        cfg, params, EngineConfig(max_slots=2, max_seq=32, block_size=4,
                                  prefill_chunk=8, kv_dtype="fp32", spec_k=2,
                                  drafter="ngram")),
        id="speculative"),
    pytest.param(lambda cfg, params: PagedEngine(
        cfg, params, EngineConfig(max_slots=2, max_seq=32, block_size=4,
                                  prefill_chunk=6, kv_dtype="fp32")),
        id="unaligned-prefill-chunk"),
])
def test_state_family_gates_fail_fast(mamba2_model, build):
    """Every unsupported state-family combination raises at construction
    with an actionable message, not deep in a jitted trace."""
    cfg, params = mamba2_model
    with pytest.raises(ValueError):
        build(cfg, params)


def test_state_family_requires_chunk1_scan(mamba2_model):
    cfg, params = mamba2_model
    cfg = dataclasses.replace(cfg, ssm_chunk=128)
    with pytest.raises(ValueError, match="ssm_chunk"):
        PagedEngine(cfg, params, EngineConfig(max_slots=2, max_seq=32,
                                              block_size=4, prefill_chunk=8,
                                              kv_dtype="fp32"))


def test_args_to_config_maps_cli_namespace():
    from repro.launch.serve import args_to_config

    ns = argparse.Namespace(slots=4, prompt_len=24, shared_prefix=8, gen=16,
                            block_size=8, prefill_chunk=16, num_blocks=0,
                            eos_id=-1, kv_dtype="int4", fused=True, seed=3,
                            online=False, max_inflight=9, spec_k=0,
                            drafter="ngram", dp=2)
    config = args_to_config(ns)
    assert config == EngineConfig(max_slots=4, max_seq=48, block_size=8,
                                  prefill_chunk=16, num_blocks=None,
                                  eos_id=None, kv_dtype="int4", fused=True,
                                  seed=3, replicas=2)
    # offline runs never thread admission knobs; drafter only rides spec_k
    assert config.max_inflight is None and config.drafter is None
    ns.online, ns.eos_id, ns.spec_k, ns.num_blocks = True, 7, 2, 40
    config = args_to_config(ns)
    assert (config.max_inflight, config.eos_id, config.drafter,
            config.num_blocks) == (9, 7, "ngram", 40)
