"""BlockPool unit suite: allocation, refcounts, prefix hashing, CoW, eviction
(runtime/kv_pool; DESIGN.md §3 invariants I1-I4)."""

import numpy as np
import pytest

from repro.runtime.kv_pool import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    chain_hashes,
    hash_block,
)


# ------------------------------------------------------------------- hashing

def test_chain_hashes_deterministic_and_block_aligned():
    prompt = list(range(37))
    hs = chain_hashes(prompt, 8)
    assert [n for _, n in hs] == [8, 8, 8, 8, 5]  # 4 full blocks + partial tail
    assert hs == chain_hashes(prompt, 8)  # process-independent (crc, not hash())


def test_chain_hashes_prefix_property():
    """Equal prefixes hash equal through the last shared block; the first
    divergent block (and everything after) differs."""
    a = list(range(32))
    b = list(range(24)) + [99] * 8
    ha, hb = chain_hashes(a, 8), chain_hashes(b, 8)
    assert ha[:3] == hb[:3]
    assert ha[3] != hb[3]
    # chaining: same block tokens after divergent history still differ
    c = [99] * 8 + list(range(8, 32))
    hc = chain_hashes(c, 8)
    assert all(x != y for x, y in zip(ha, hc))


def test_hash_block_seeds_chain():
    assert hash_block(0, [1, 2, 3]) != hash_block(1, [1, 2, 3])


# --------------------------------------------------------------- allocation

def test_alloc_release_refcount_roundtrip():
    pool = BlockPool(num_blocks=4, block_size=8)
    assert pool.num_free == 3  # block 0 reserved
    a = pool.alloc()
    assert a != NULL_BLOCK and pool.refcount[a] == 1
    pool.retain(a)
    assert pool.refcount[a] == 2
    pool.release(a)
    assert pool.num_free == 2  # still live
    pool.release(a)
    assert pool.num_free == 3  # unregistered block frees immediately


def test_alloc_exhaustion_raises():
    pool = BlockPool(num_blocks=3, block_size=8)
    pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_pool_validates_args():
    with pytest.raises(ValueError):
        BlockPool(num_blocks=1, block_size=8)  # no room beyond the null block
    with pytest.raises(ValueError):
        BlockPool(num_blocks=4, block_size=0)


# ------------------------------------------------------------- prefix index

def test_register_lookup_retains_and_survives_release():
    pool = BlockPool(num_blocks=4, block_size=8)
    b = pool.alloc()
    pool.register(1234, b)
    pool.release(b)  # registered -> parks on LRU, not the free list
    assert pool.num_free == 2 and pool.num_evictable == 1
    got = pool.lookup(1234)
    assert got == b and pool.refcount[b] == 1  # resurrected + retained
    assert pool.num_evictable == 0
    assert pool.lookup(9999) is None


def test_register_first_writer_wins():
    pool = BlockPool(num_blocks=4, block_size=8)
    b1, b2 = pool.alloc(), pool.alloc()
    pool.register(7, b1)
    pool.register(7, b2)  # concurrent identical prompts: no-op, b1 stays published
    assert pool.lookup(7) == b1


def test_lru_eviction_order_and_live_protection():
    """alloc() under pressure evicts the least-recently-used cached block and
    never touches blocks still referenced by live requests (invariant I3)."""
    pool = BlockPool(num_blocks=4, block_size=8)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    pool.register(1, a)
    pool.register(2, b)
    pool.release(a)
    pool.release(b)  # LRU order: a then b; c stays live
    d = pool.alloc()  # must evict a (oldest), not live c
    assert d == a
    assert pool.lookup(1) is None  # a's index entry gone
    assert pool.lookup(2) == b  # b resurrected
    pool.release(b)
    pool.release(c)
    pool.release(d)


def test_eviction_blocked_while_all_shared():
    pool = BlockPool(num_blocks=3, block_size=8)
    a = pool.alloc()
    pool.register(5, a)
    pool.retain(a)  # shared between two live requests
    b = pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()  # a is registered but live -> not evictable
    pool.release(a)
    with pytest.raises(PoolExhausted):
        pool.alloc()  # still one live ref
    pool.release(a)  # now parked on LRU
    assert pool.alloc() == a  # evictable again
    pool.release(b)


# -------------------------------------------------------------------- CoW

def test_writable_and_fork_semantics():
    pool = BlockPool(num_blocks=4, block_size=8)
    a = pool.alloc()
    assert pool.writable(a)  # exclusive: append in place
    pool.retain(a)
    assert not pool.writable(a)  # shared: must fork
    new = pool.fork(a)
    assert new != a and pool.refcount[new] == 1
    assert pool.refcount[a] == 1  # our ref moved to the fork
    assert pool.stats.cow_copies == 1


def test_fork_of_registered_block_keeps_cache_entry():
    pool = BlockPool(num_blocks=4, block_size=8)
    a = pool.alloc()
    pool.register(11, a)
    pool.retain(a)  # a second request shares the cached tail
    new = pool.fork(a)
    assert pool.lookup(11) == a  # original still serves prefix hits
    pool.release(a)  # lookup's retain
    pool.release(a)  # original owner
    pool.release(new)
