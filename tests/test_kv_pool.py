"""BlockPool unit suite: allocation, refcounts, prefix hashing, CoW, eviction
(runtime/kv_pool; DESIGN.md §3 invariants I1-I4), plus the int8 pool's scale
bookkeeping through ``PagedEngine`` — CoW forks copy scale planes with the
payload, freshly (re)allocated blocks get their scales reset, and cached
quantized prefixes replay exactly (DESIGN.md §6)."""

import numpy as np
import pytest

from repro.runtime.kv_pool import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    chain_hashes,
    hash_block,
)


# ------------------------------------------------------------------- hashing

def test_chain_hashes_deterministic_and_block_aligned():
    prompt = list(range(37))
    hs = chain_hashes(prompt, 8)
    assert [n for _, n in hs] == [8, 8, 8, 8, 5]  # 4 full blocks + partial tail
    assert hs == chain_hashes(prompt, 8)  # process-independent (crc, not hash())


def test_chain_hashes_prefix_property():
    """Equal prefixes hash equal through the last shared block; the first
    divergent block (and everything after) differs."""
    a = list(range(32))
    b = list(range(24)) + [99] * 8
    ha, hb = chain_hashes(a, 8), chain_hashes(b, 8)
    assert ha[:3] == hb[:3]
    assert ha[3] != hb[3]
    # chaining: same block tokens after divergent history still differ
    c = [99] * 8 + list(range(8, 32))
    hc = chain_hashes(c, 8)
    assert all(x != y for x, y in zip(ha, hc))


def test_hash_block_seeds_chain():
    assert hash_block(0, [1, 2, 3]) != hash_block(1, [1, 2, 3])


# --------------------------------------------------------------- allocation

def test_alloc_release_refcount_roundtrip():
    pool = BlockPool(num_blocks=4, block_size=8)
    assert pool.num_free == 3  # block 0 reserved
    a = pool.alloc()
    assert a != NULL_BLOCK and pool.refcount[a] == 1
    pool.retain(a)
    assert pool.refcount[a] == 2
    pool.release(a)
    assert pool.num_free == 2  # still live
    pool.release(a)
    assert pool.num_free == 3  # unregistered block frees immediately


def test_alloc_exhaustion_raises():
    pool = BlockPool(num_blocks=3, block_size=8)
    pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_pool_validates_args():
    with pytest.raises(ValueError):
        BlockPool(num_blocks=1, block_size=8)  # no room beyond the null block
    with pytest.raises(ValueError):
        BlockPool(num_blocks=4, block_size=0)


# ------------------------------------------------------------- prefix index

def test_register_lookup_retains_and_survives_release():
    pool = BlockPool(num_blocks=4, block_size=8)
    b = pool.alloc()
    pool.register(1234, b)
    pool.release(b)  # registered -> parks on LRU, not the free list
    assert pool.num_free == 2 and pool.num_evictable == 1
    got = pool.lookup(1234)
    assert got == b and pool.refcount[b] == 1  # resurrected + retained
    assert pool.num_evictable == 0
    assert pool.lookup(9999) is None


def test_register_first_writer_wins():
    pool = BlockPool(num_blocks=4, block_size=8)
    b1, b2 = pool.alloc(), pool.alloc()
    pool.register(7, b1)
    pool.register(7, b2)  # concurrent identical prompts: no-op, b1 stays published
    assert pool.lookup(7) == b1


def test_lru_eviction_order_and_live_protection():
    """alloc() under pressure evicts the least-recently-used cached block and
    never touches blocks still referenced by live requests (invariant I3)."""
    pool = BlockPool(num_blocks=4, block_size=8)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    pool.register(1, a)
    pool.register(2, b)
    pool.release(a)
    pool.release(b)  # LRU order: a then b; c stays live
    d = pool.alloc()  # must evict a (oldest), not live c
    assert d == a
    assert pool.lookup(1) is None  # a's index entry gone
    assert pool.lookup(2) == b  # b resurrected
    pool.release(b)
    pool.release(c)
    pool.release(d)


def test_eviction_blocked_while_all_shared():
    pool = BlockPool(num_blocks=3, block_size=8)
    a = pool.alloc()
    pool.register(5, a)
    pool.retain(a)  # shared between two live requests
    b = pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()  # a is registered but live -> not evictable
    pool.release(a)
    with pytest.raises(PoolExhausted):
        pool.alloc()  # still one live ref
    pool.release(a)  # now parked on LRU
    assert pool.alloc() == a  # evictable again
    pool.release(b)


# -------------------------------------------------------------------- CoW

def test_writable_and_fork_semantics():
    pool = BlockPool(num_blocks=4, block_size=8)
    a = pool.alloc()
    assert pool.writable(a)  # exclusive: append in place
    pool.retain(a)
    assert not pool.writable(a)  # shared: must fork
    new = pool.fork(a)
    assert new != a and pool.refcount[new] == 1
    assert pool.refcount[a] == 1  # our ref moved to the fork
    assert pool.stats.cow_copies == 1


def test_fork_of_registered_block_keeps_cache_entry():
    pool = BlockPool(num_blocks=4, block_size=8)
    a = pool.alloc()
    pool.register(11, a)
    pool.retain(a)  # a second request shares the cached tail
    new = pool.fork(a)
    assert pool.lookup(11) == a  # original still serves prefix hits
    pool.release(a)  # lookup's retain
    pool.release(a)  # original owner
    pool.release(new)


# ------------------------------------------------- int8 pool scale invariants

def _int8_engine(**kw):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.engine import PagedEngine

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 48)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("steps_per_sync", 4)
    return cfg, params, PagedEngine(cfg, params, seed=0, cache_dtype=jnp.int8, **kw)


def test_int8_cow_fork_copies_scales():
    """The CoW device copy duplicates *all* pool planes — an int8 fork that
    dropped the scales would dequantize the copied codes on the wrong grid."""
    import jax.numpy as jnp

    _, _, eng = _int8_engine()
    pool = dict(eng._pool)
    pool["k"] = pool["k"].at[:, 2].set(7)
    pool["k_scale"] = pool["k_scale"].at[:, 2].set(0.25)
    pool["v_scale"] = pool["v_scale"].at[:, 2].set(0.5)
    out = eng._jit_copy_block(pool, jnp.asarray(2, jnp.int32), jnp.asarray(3, jnp.int32))
    assert (np.asarray(out["k"][:, 3]) == 7).all()
    assert (np.asarray(out["k_scale"][:, 3]) == 0.25).all()
    assert (np.asarray(out["v_scale"][:, 3]) == 0.5).all()


def test_int8_fresh_alloc_resets_scales():
    """Blocks handed out by alloc (free list or eviction) must shed their
    stale quantization grid before the next write; blocks obtained via CoW
    fork are NOT reset (their scale arrives with the copied payload)."""
    _, _, eng = _int8_engine()
    pool = dict(eng._pool)
    pool["k_scale"] = pool["k_scale"].at[:, 1:].set(9.0)
    pool["v_scale"] = pool["v_scale"].at[:, 1:].set(9.0)
    eng._pool = pool
    eng._fresh_blocks = {1, 2}
    eng._flush_fresh_scales()
    assert eng._fresh_blocks == set()
    ks = np.asarray(eng._pool["k_scale"])
    assert (ks[:, [1, 2]] == 0.0).all()  # reset to the "unset" sentinel
    assert (ks[:, 3:] == 9.0).all()  # untouched blocks keep their grid
    assert (np.asarray(eng._pool["v_scale"])[:, [1, 2]] == 0.0).all()
    eng._flush_fresh_scales()  # empty set: no-op, no recompile churn


def test_int8_fork_destination_escapes_scale_reset():
    """Regression: ``fork()`` allocates internally and can return an id that
    was ``_alloc_fresh``'d and then released (admission rollback, preemption)
    while still queued for a scale reset. The fork's scales arrive with the
    copied payload, so the pending reset must NOT zero them — a zeroed grid
    dequantizes the fork's codes to all-zero K/V."""
    _, _, eng = _int8_engine()
    # shared block a (refcount 2), named by slot 0's table
    a = eng.pool.alloc()
    eng.pool.retain(a)
    s = eng._slots[0]
    s.uid, s.table = 0, [a]
    eng._tables[0, 0] = a
    eng._pool = {k: (v.at[:, a].set(0.125) if k.endswith("scale") else v)
                 for k, v in eng._pool.items()}
    # poison: every other block is queued for reset, as after a rollback
    eng._fresh_blocks = set(range(1, eng.pool.num_blocks)) - {a}
    eng._make_writable(0, 0)
    new = s.table[0]
    assert new != a and new not in eng._fresh_blocks
    eng._flush_fresh_scales()
    ks = np.asarray(eng._pool["k_scale"])
    assert (ks[:, new] == 0.125).all()  # fork kept the copied grid
    assert (ks[:, a] == 0.125).all()


def test_int8_prefix_reuse_replays_fresh_prefill():
    """A prompt served from *cached quantized blocks* (plus a CoW fork for
    the appended tail) decodes the same greedy tokens as the same prompt
    prefilled from scratch on a fresh int8 engine: published codes/scales
    are immutable, so reuse is indistinguishable from recompute."""
    rng = np.random.default_rng(5)
    system = rng.integers(0, 500, 16)  # two full 8-token blocks
    tail_a = rng.integers(0, 500, 3)
    tail_b = rng.integers(0, 500, 5)

    cfg, params, shared = _int8_engine(max_slots=2)
    shared.submit(np.concatenate([system, tail_a]), 6)
    shared.step_chunk()  # prefill chunk 1: publishes the first system block
    shared.step_chunk()  # prefill chunk 2: publishes the second
    ub = shared.submit(np.concatenate([system, tail_b]), 6)
    res = shared.run()
    assert shared.stats["prefix_hit_tokens"] >= len(system)

    import jax.numpy as jnp
    from repro.runtime.engine import PagedEngine

    fresh = PagedEngine(cfg, params, max_slots=1, max_seq=48, block_size=8,
                        prefill_chunk=8, steps_per_sync=4, seed=0, cache_dtype=jnp.int8)
    uf = fresh.submit(np.concatenate([system, tail_b]), 6)
    fres = fresh.run()
    assert res[ub].tokens == fres[uf].tokens
