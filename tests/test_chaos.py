"""Chaos-injection suite for the serving stack (DESIGN.md §11).

Three layers, all seeded through PYTEST_SEED (failures replay with one env
var — see conftest):

  * Host chaos — randomized fault schedules (pool exhaustion, mid-stream
    disconnects, malformed requests, deadline pressure, admission-control
    rejections) injected through ``runtime.faults.ChaosHarness`` into the
    pure-host scheduler while the numpy device emulator steps it. After
    EVERY injected event the full allocator audit runs, and once faults
    clear the core must drain: every submitted uid resolves to exactly one
    of {finished, cancelled, shed}, sheds are structured-retryable, and the
    stats ledger agrees with the harness's own counters.

  * Async frontend chaos — the asyncio serving front over the emulated
    engine: token streams resolve, mid-stream cancellation releases every
    block, overload surfaces structured ``Rejected`` (never exceptions),
    deadline sheds close the stream with finish_reason "shed", and a
    stalled device step (``slow_steps``) never wedges the event loop —
    submissions and heartbeats keep running while a chunk drags.

  * Device chaos — the real ``PagedEngine`` on the trained smoke model
    under a tight pool (forcing preempt-and-recompute) plus injected
    disconnects and stalled steps: surviving requests must stream
    bit-exactly the tokens of a fault-free uncontended run, and cancelled
    requests' partials must be exact prefixes of it (greedy decode is
    deterministic; faults may truncate it, never corrupt it).

Scale knobs match the fuzzers: FUZZ_TRACES / FUZZ_STEPS (the scheduled
long-fuzz CI job raises both).
"""

import asyncio
import os

import numpy as np

from repro.runtime.engine_core import EngineCore, Rejected
from repro.runtime.faults import (
    ChaosHarness,
    EmulatedEngine,
    HostDeviceEmulator,
    audit_block_invariants,
    slow_steps,
)
from repro.runtime.frontend import AsyncFrontend
from repro.runtime.kv_pool import PoolExhausted

FUZZ_TRACES = int(os.environ.get("FUZZ_TRACES", "4"))
FUZZ_STEPS = int(os.environ.get("FUZZ_STEPS", "40"))

VOCAB, EOS = 32, 1


# ------------------------------------------------------------- host chaos


def test_chaos_random_fault_schedules(test_seed):
    """Random interleavings of valid submissions, pool-exhaustion pins,
    partial releases, disconnects, malformed batteries, and emulated device
    chunks — audit after every event; full accounting after recovery."""
    rng = np.random.default_rng(test_seed)
    for trace in range(FUZZ_TRACES):
        num_blocks = int(rng.integers(10, 24))
        core = EngineCore(
            max_slots=int(rng.integers(2, 5)), max_seq=48,
            block_size=int(rng.choice([2, 4])), num_blocks=num_blocks,
            prefill_chunk=int(rng.choice([4, 8])), eos_id=EOS,
            max_inflight=None if rng.random() < 0.5 else int(rng.integers(3, 9)),
            admit_watermark=None if rng.random() < 0.5 else 0.9,
        )
        em = HostDeviceEmulator(rng, vocab=VOCAB, eos=EOS)
        h = ChaosHarness(core, rng)
        submitted = []
        for _ in range(FUZZ_STEPS):
            op = rng.random()
            if op < 0.30:
                prompt = [int(t) for t in rng.integers(2, VOCAB, int(rng.integers(2, 9)))]
                dl = None if rng.random() < 0.7 else core.now() + float(rng.integers(1, 30))
                r = core.try_submit(prompt, int(rng.integers(1, 10)),
                                    priority=int(rng.integers(0, 3)), deadline=dl)
                if isinstance(r, Rejected):
                    # valid request: only load shed may turn it away, and
                    # load shed is always structured-retryable with a census
                    assert r.reason in ("max_inflight", "pool_pressure")
                    assert r.retryable and r.backoff_hint > 0
                else:
                    submitted.append(r)
            elif op < 0.40:
                h.exhaust_pool(int(rng.integers(1, num_blocks)))
            elif op < 0.50:
                h.release_held(int(rng.integers(1, num_blocks)))
            elif op < 0.60:
                h.disconnect_random()
            elif op < 0.70:
                h.submit_malformed()
            else:
                try:
                    em.step_chunk(core)
                except PoolExhausted as e:
                    # the harness pinned the pool out from under the only
                    # live request; still structured, and releasing the pins
                    # must fully recover
                    assert e.occupancy is not None
                    h.release_held()
            h.audit()
        # recovery: drop every pin, drain to completion
        h.release_held()
        for guard in range(2000):
            if not core.has_work():
                break
            em.step_chunk(core)
            h.audit()
        else:
            raise AssertionError("core failed to drain after fault removal")
        res = core.take_finished()
        sheds = core.take_shed()
        assert not set(res) & set(sheds), "a uid resolved twice"
        assert set(res) | set(sheds) == set(submitted), "requests vanished"
        for uid, rej in sheds.items():
            assert rej.reason == "deadline" and rej.retryable and rej.uid == uid
        assert core.stats["shed"] == len(sheds)
        assert core.stats["cancelled"] == h.counters["disconnect"]
        assert not h.held
        h.audit()


# -------------------------------------------------------- frontend chaos


def _engine(rng, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("block_size", 4)
    return EmulatedEngine(rng, vocab=VOCAB, eos=None, **kw)


def test_frontend_streams_to_completion(test_seed):
    """Concurrent streams resolve with the engine's exact tokens and
    finish_reason; TTFT telemetry lands; no blocks leak."""
    async def main():
        eng = _engine(np.random.default_rng(test_seed))
        async with AsyncFrontend(eng, chunk_steps=2) as fe:
            h1 = await fe.submit([2] * 6, 12)
            h2 = await fe.submit([3] * 6, 8, priority=1)
            t1, t2 = await h1.collect(), await h2.collect()
            assert len(t1) == 12 and h1.finish_reason == "length"
            assert len(t2) == 8 and h2.finish_reason == "length"
            assert fe.ttft(h1.uid) is not None and fe.ttft(h2.uid) is not None
            assert fe.inflight == 0
            audit_block_invariants(eng)
    asyncio.run(main())


def test_frontend_cancel_mid_stream_releases_blocks(test_seed):
    """A client disconnect mid-generation closes the stream with
    finish_reason "cancelled", releases every block (audit), and leaves the
    surviving stream untouched."""
    async def main():
        eng = _engine(np.random.default_rng(test_seed))
        chunks_done = asyncio.Event()
        loop = asyncio.get_running_loop()
        orig, calls = eng.step_chunk, [0]

        def counting(steps=None):
            r = orig(steps)
            calls[0] += 1
            if calls[0] >= 3:
                loop.call_soon_threadsafe(chunks_done.set)
            return r

        eng.step_chunk = counting
        async with AsyncFrontend(eng, chunk_steps=2) as fe:
            h1 = await fe.submit([2] * 6, 50)
            h2 = await fe.submit([3] * 6, 20)
            await chunks_done.wait()  # h1 is decoding, partial tokens exist
            await h1.cancel()
            assert h1.finish_reason == "cancelled"
            assert 0 < len(h1.tokens) < 50
            t2 = await h2.collect()
            assert len(t2) == 20 and h2.finish_reason == "length"
            await fe.drain()
            audit_block_invariants(eng)
    asyncio.run(main())


def test_frontend_rejections_are_structured(test_seed):
    """Overload and malformed input surface as ``Rejected`` values from
    ``submit`` — retryable-with-backoff vs non-retryable — never as
    exceptions, and never corrupt in-flight streams."""
    async def main():
        eng = _engine(np.random.default_rng(test_seed), max_inflight=1)
        async with AsyncFrontend(eng, chunk_steps=2) as fe:
            h1 = await fe.submit([2] * 4, 30)
            r = await fe.submit([3] * 4, 4)
            assert isinstance(r, Rejected) and r.reason == "max_inflight"
            assert r.retryable and r.backoff_hint > 0
            bad = await fe.submit([], 4)
            assert isinstance(bad, Rejected) and bad.reason == "invalid"
            assert not bad.retryable
            assert len(await h1.collect()) == 30
            # capacity freed: the retry now lands
            h3 = await fe.submit([3] * 4, 4)
            assert not isinstance(h3, Rejected)
            assert len(await h3.collect()) == 4
            audit_block_invariants(eng)
    asyncio.run(main())


def test_frontend_deadline_shed_resolves_stream(test_seed):
    """A queued request whose TTFT deadline lapses behind a slot hog resolves
    as a closed stream with finish_reason "shed" and the structured
    retryable ``Rejected`` — the client is never left hanging."""
    async def main():
        eng = _engine(np.random.default_rng(test_seed), max_slots=1)
        async with AsyncFrontend(eng, chunk_steps=4) as fe:
            hog = await fe.submit([2] * 4, 40)
            late = await fe.submit([3] * 4, 4, deadline=2.0)
            toks = await late.collect()
            assert toks == [] and late.finish_reason == "shed"
            assert late.rejected is not None
            assert late.rejected.reason == "deadline" and late.rejected.retryable
            assert late.rejected.uid == late.uid
            assert len(await hog.collect()) == 40
            audit_block_invariants(eng)
    asyncio.run(main())


def test_frontend_survives_stalled_steps(test_seed):
    """Stalled device chunks must not wedge the loop: a heartbeat coroutine
    keeps beating and a submission lands *while* a chunk drags, because
    ``step_chunk`` runs in the executor, off the event loop."""
    async def main():
        eng = _engine(np.random.default_rng(test_seed))
        undo = slow_steps(eng, 0.02, every=1)
        beats = [0]

        async def heartbeat():
            while True:
                beats[0] += 1
                await asyncio.sleep(0.001)

        async with AsyncFrontend(eng, chunk_steps=2) as fe:
            beat_task = asyncio.get_running_loop().create_task(heartbeat())
            h1 = await fe.submit([2] * 6, 16)
            async for _ in h1:
                break  # first token: the pump is mid-traffic
            h2 = await fe.submit([3] * 6, 8)  # submitted between stalls
            assert not isinstance(h2, Rejected)
            assert len(await h1.collect()) == 16
            assert len(await h2.collect()) == 8
            beat_task.cancel()
            undo()
            # ~8+ stalled chunks x 20ms each: a wedged loop would beat ~once
            assert beats[0] > 5
            audit_block_invariants(eng)
    asyncio.run(main())


def test_frontend_aclose_cancels_unresolved(test_seed):
    """Leaving the context with live streams cancels them engine-side (no
    leaked blocks, no dangling awaiters)."""
    async def main():
        eng = _engine(np.random.default_rng(test_seed))
        async with AsyncFrontend(eng, chunk_steps=1) as fe:
            h = await fe.submit([2] * 6, 500)
        assert h.finish_reason == "cancelled"
        assert await h.collect() == list(h.tokens)  # stream is closed, not hung
        audit_block_invariants(eng)
    asyncio.run(main())


# --------------------------------------------------------- device chaos


def test_device_chaos_survivors_bit_exact(smoke_model, test_seed):
    """Real engine, tight pool, stalled steps, mid-flight disconnects: every
    surviving request reproduces the fault-free uncontended run bit-exactly,
    and every cancelled request's partial is an exact prefix of it."""
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
    from bench_serving import PERIOD, TOK0

    from repro.runtime.engine import PagedEngine

    cfg, params = smoke_model
    pattern = [int(t) for t in np.arange(48) % PERIOD + TOK0]
    reqs = [  # shared prefixes force prefix hits + CoW forks under pressure
        (pattern[:10], 12), (pattern[:14], 12), (pattern[4:14], 12), (pattern[8:18], 12),
    ]

    def build(num_blocks=None):
        return PagedEngine(cfg, params, max_slots=3, max_seq=64, block_size=8,
                           prefill_chunk=8, eos_id=None, seed=0, num_blocks=num_blocks)

    ref = build()  # fully provisioned, fault-free
    ref_uids = [ref.submit(p, m) for p, m in reqs]
    ref_out = ref.run()

    eng = build(num_blocks=8)  # 7 usable for ~13 blocks of demand: contention
    uids = [eng.submit(p, m) for p, m in reqs]
    undo = slow_steps(eng, 0.002, every=2)
    cancel_at = {2: uids[1], 5: uids[3]}
    cancelled = set()
    for chunk in range(1, 500):
        if not eng.has_work():
            break
        eng.step_chunk()
        if chunk in cancel_at and eng.cancel(cancel_at[chunk]):
            cancelled.add(cancel_at[chunk])
        audit_block_invariants(eng)
    else:
        raise AssertionError("chaos run failed to drain")
    undo()
    out = eng.run()

    assert cancelled, "trace failed to land any mid-flight disconnect"
    for uid, ruid in zip(uids, ref_uids):
        full = ref_out[ruid].tokens
        if uid in cancelled:
            assert out[uid].finish_reason == "cancelled"
            got = out[uid].tokens
            assert got == full[:len(got)], "cancelled partial diverged from greedy"
        else:
            assert out[uid].tokens == full, "survivor lost bit-exact parity"
            assert len(out[uid].tokens) == 12
    audit_block_invariants(eng)
