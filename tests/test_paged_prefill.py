"""Fused Pallas paged-prefill EXAQ attention vs the gather-then-attend oracle
(DESIGN.md §7): chunk-boundary/GQA parity matrix, chunk-splitting
bit-exactness vs a one-shot window, shared-prefix (CoW) tables, the int8
per-block-scaled pool with fresh-block scale seeding (DESIGN.md §6), the
prefill bytes model, and bit-exact greedy parity through a full
``PagedEngine`` prefill+decode trace. All kernels run in interpret mode on
CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exaq_params
from repro.kernels import ops
from repro.kernels.exaq_paged_prefill import paged_prefill_bytes_model

RNG = np.random.default_rng(7)


def _window_setup(KV, bs, MB, D, *, dtype=jnp.float32, seed=0):
    """Random pool + one request's table (ids permuted so table order differs
    from pool order — a bug that ignores the table shows up)."""
    rng = np.random.default_rng(seed)
    N = 1 + 2 * MB
    pk = jnp.asarray(rng.normal(0, 1, (N, KV, bs, D)), dtype)
    pv = jnp.asarray(rng.normal(0, 1, (N, KV, bs, D)), dtype)
    tbl = jnp.asarray(rng.permutation(np.arange(1, N))[:MB], jnp.int32)
    return pk, pv, tbl


# int8 pools quantize via the shared `quantize_pool` fixture (conftest.py).

# chunk geometries straddling block boundaries (bs = 8, MB = 4):
#   chunk 1 at the very start; chunk 1 mid-block; chunk crossing one
#   boundary; chunk landing exactly on a boundary; chunk spanning several
#   blocks; chunk == whole prompt (one-shot)
BOUNDARY_CASES = [(0, 1), (5, 1), (6, 5), (8, 8), (3, 18), (0, 29)]


@pytest.mark.parametrize("start,C", BOUNDARY_CASES)
def test_fused_matches_gather_oracle_chunk_boundaries(start, C):
    KV, bs, MB, D = 2, 8, 4, 32
    H = 2 * KV
    p = exaq_params(1.5, 2)
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=start * 37 + C)
    q = jnp.asarray(RNG.normal(0, 1, (1, H, C, D)), jnp.float32)
    got = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                      use_kernel=True)
    want = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                       use_kernel=False)
    assert got.shape == (1, H, C, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("group", [1, 4, 8])
@pytest.mark.parametrize("bits", [2, 3])
def test_fused_matches_gather_oracle_gqa(group, bits):
    """GQA group sizes 1/4/8: one kv head's query group forms the q rows."""
    KV, bs, MB, D = 2, 8, 3, 64
    H, C, start = KV * group, 6, 9
    p = exaq_params(1.5, bits)
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=group)
    q = jnp.asarray(RNG.normal(0, 1, (1, H, C, D)), jnp.float32)
    got = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                      use_kernel=True)
    want = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                       use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_chunked_equals_one_shot_window():
    """Splitting a prefill into chunks is bit-identical to one shot: the
    two-pass combine anchors every row at its true global max, so the rows
    of a later chunk match the same rows of a single whole-window call
    (DESIGN.md §2/§7)."""
    KV, bs, MB, D = 2, 8, 4, 32
    H, P, split = 4, 27, 11
    p = exaq_params(1.0, 2)
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=6)
    q = jnp.asarray(RNG.normal(0, 1, (1, H, P, D)), jnp.float32)
    one_shot = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(0), p, D**-0.5,
                                           use_kernel=True)
    first = ops.paged_prefill_attention(q[:, :, :split], pk, pv, tbl, jnp.int32(0),
                                        p, D**-0.5, use_kernel=True)
    second = ops.paged_prefill_attention(q[:, :, split:], pk, pv, tbl, jnp.int32(split),
                                         p, D**-0.5, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(one_shot[:, :, :split]), np.asarray(first))
    np.testing.assert_array_equal(np.asarray(one_shot[:, :, split:]), np.asarray(second))


def test_fused_shared_prefix_cow_table():
    """Two requests whose tables share earlier chunks' prefix blocks (the
    CoW/prefix-cache layout): each request's fused chunk matches gathering
    its own window independently."""
    KV, bs, MB, D = 2, 8, 4, 32
    H, C = 4, 7
    p = exaq_params(1.0, 2)
    pk, pv, _ = _window_setup(KV, bs, MB, D, seed=8)
    tables = [jnp.asarray([1, 2, 3, 4], jnp.int32),   # owner of the prefix
              jnp.asarray([1, 2, 5, 6], jnp.int32)]   # shares blocks 1-2, forked tail
    for start, tbl in zip((2 * bs + 3, 2 * bs + 1), tables):
        q = jnp.asarray(RNG.normal(0, 1, (1, H, C, D)), jnp.float32)
        got = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                          use_kernel=True)
        want = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                           use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_bf16_pool():
    KV, bs, MB, D = 2, 8, 3, 64
    H, C, start = 4, 5, 10
    p = exaq_params(1.5, 2)
    pk, pv, tbl = _window_setup(KV, bs, MB, D, dtype=jnp.bfloat16, seed=9)
    q = jnp.asarray(RNG.normal(0, 1, (1, H, C, D)), jnp.float32)
    got = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                      use_kernel=True)
    want = ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(start), p, D**-0.5,
                                       use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------------------- int8 KV pool

@pytest.mark.parametrize("group", [1, 4])
def test_fused_int8_matches_dequantizing_oracle(group, quantize_pool):
    """int8 pool: the fused kernel (scalar-prefetched scales, dequant in
    VMEM) matches the dequantizing gather oracle — same codes, same
    per-(block, kv-head) scales (DESIGN.md §6)."""
    KV, bs, MB, D = 2, 8, 3, 32
    H, C, start = KV * group, 6, 7
    p = exaq_params(1.5, 2)
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=20 + group)
    qk, qv, ks, vs = quantize_pool(pk, pv)
    q = jnp.asarray(RNG.normal(0, 1, (1, H, C, D)), jnp.float32)
    got = ops.paged_prefill_attention(q, qk, qv, tbl, jnp.int32(start), p, D**-0.5,
                                      k_scale=ks, v_scale=vs, use_kernel=True)
    want = ops.paged_prefill_attention(q, qk, qv, tbl, jnp.int32(start), p, D**-0.5,
                                       k_scale=ks, v_scale=vs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_int8_fresh_block_scale_seeding_through_chunk_scatter():
    """attention_prefill_chunk on an int8 pool seeds still-unset block scales
    from the chunk's per-target-block amax and both read paths (fused kernel
    / gather oracle) then dequantize against the SAME seeded planes — the
    scattered codes, seeded scales, and attention outputs agree."""
    from repro.configs import get_config
    from repro.models import attention as attn
    from repro.models.attention import AttnStatics
    from repro.models.model import default_qstate

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    key = jax.random.PRNGKey(3)
    params = attn.init_attention(key, cfg, dtype=jnp.float32)
    bs, MB, C, start = 8, 4, 8, 4
    N = 1 + MB
    KV, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    x = jnp.asarray(RNG.normal(0, 0.1, (1, C, cfg.d_model)), jnp.float32)
    pool_k = jnp.zeros((N, KV, bs, dh), jnp.int8)
    pool_v = jnp.zeros_like(pool_k)
    # block 1 was written by an earlier chunk (its scale is set and immutable);
    # blocks 2-3 are fresh allocations whose scales must seed from this chunk
    k_scale = jnp.zeros((N, KV), jnp.float32).at[1].set(0.05)
    v_scale = jnp.zeros((N, KV), jnp.float32).at[1].set(0.07)
    tbl = jnp.asarray([1, 2, 3, 0], jnp.int32)
    blk_t = jnp.asarray([tbl[(start + i) // bs] for i in range(C)], jnp.int32)
    off_t = jnp.asarray([(start + i) % bs for i in range(C)], jnp.int32)
    clip = default_qstate(cfg)["attn_clip"][0]

    outs, pools = {}, {}
    for fused in (False, True):
        statics = AttnStatics("exaq", 2, fused)
        o, new_kv = attn.attention_prefill_chunk(
            params, x, cfg, statics, clip, pool_k, pool_v, tbl,
            jnp.int32(start), blk_t, off_t, k_scale, v_scale)
        outs[fused], pools[fused] = o, new_kv
    # scatter is shared: codes and seeded scale planes are identical
    for a, b in zip(pools[False], pools[True]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ks_new = pools[True][2]
    assert float(ks_new[1, 0]) == pytest.approx(0.05)  # set scale immutable
    assert float(jnp.min(ks_new[2])) > 0.0             # fresh block seeded
    np.testing.assert_allclose(np.asarray(outs[True]), np.asarray(outs[False]), atol=1e-5)


def test_prefill_requires_scales_iff_int8(quantize_pool):
    KV, bs, MB, D = 2, 8, 2, 16
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=30)
    qk, qv, ks, vs = quantize_pool(pk, pv)
    p = exaq_params(1.0, 2)
    q = jnp.zeros((1, 2, 4, D))
    with pytest.raises(ValueError):
        ops.paged_prefill_attention(q, qk, qv, tbl, jnp.int32(0), p, 0.25,
                                    k_scale=ks, use_kernel=True)  # missing v_scale
    with pytest.raises(ValueError):
        ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(0), p, 0.25,
                                    k_scale=ks, v_scale=vs, use_kernel=True)  # fp + scales


# ------------------------------------------------------- packed int4 KV pool

@pytest.mark.parametrize("group", [1, 4, 8])
@pytest.mark.parametrize("start,C", BOUNDARY_CASES)
def test_fused_int4_matches_dequantizing_oracle_matrix(group, start, C, quantize_pool_int4):
    """Acceptance matrix — GQA 1/4/8 x chunk-boundary cases at packed int4:
    the fused kernel (in-VMEM nibble unpack, scalar-prefetched block scales +
    sub codes) matches the dequantizing gather oracle to <= 1e-5
    (DESIGN.md §10)."""
    KV, bs, MB, D = 2, 8, 4, 32
    H = KV * group
    p = exaq_params(1.5, 2)
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=start * 37 + C + group)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    q = jnp.asarray(RNG.normal(0, 1, (1, H, C, D)), jnp.float32)
    got = ops.paged_prefill_attention(q, qk, qv, tbl, jnp.int32(start), p, D**-0.5,
                                      k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                      use_kernel=True)
    want = ops.paged_prefill_attention(q, qk, qv, tbl, jnp.int32(start), p, D**-0.5,
                                       k_scale=ks, v_scale=vs, k_sub=ksub, v_sub=vsub,
                                       use_kernel=False)
    assert got.shape == (1, H, C, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_int4_fresh_block_seeding_through_chunk_scatter():
    """attention_prefill_chunk on a packed-int4 pool seeds still-unset block
    scales AND sub codes from the chunk's amax grid; set planes are immutable
    (first-write rule, DESIGN.md §10). Both read paths dequantize against the
    same seeded grid, so scattered nibbles, scale planes, sub codes and
    attention outputs agree."""
    from repro.configs import get_config
    from repro.kernels.ops import kv4_num_sub
    from repro.models import attention as attn
    from repro.models.attention import AttnStatics
    from repro.models.model import default_qstate

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    key = jax.random.PRNGKey(3)
    params = attn.init_attention(key, cfg, dtype=jnp.float32)
    bs, MB, C, start = 8, 4, 8, 4
    N = 1 + MB
    KV, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    n_sub = kv4_num_sub(bs)
    x = jnp.asarray(RNG.normal(0, 0.1, (1, C, cfg.d_model)), jnp.float32)
    pool_k = jnp.zeros((N, KV, bs, dh // 2), jnp.uint8)
    pool_v = jnp.zeros_like(pool_k)
    # block 1 was written by an earlier chunk (scale + sub codes set and
    # immutable); blocks 2-3 are fresh — their whole grid seeds from this chunk
    k_scale = jnp.zeros((N, KV), jnp.float32).at[1].set(0.05)
    v_scale = jnp.zeros((N, KV), jnp.float32).at[1].set(0.07)
    k_sub = jnp.zeros((N, KV, n_sub), jnp.uint8).at[1].set(9)
    v_sub = jnp.zeros((N, KV, n_sub), jnp.uint8).at[1].set(11)
    tbl = jnp.asarray([1, 2, 3, 0], jnp.int32)
    blk_t = jnp.asarray([tbl[(start + i) // bs] for i in range(C)], jnp.int32)
    off_t = jnp.asarray([(start + i) % bs for i in range(C)], jnp.int32)
    clip = default_qstate(cfg)["attn_clip"][0]

    outs, pools = {}, {}
    for fused in (False, True):
        statics = AttnStatics("exaq", 2, fused)
        o, new_kv = attn.attention_prefill_chunk(
            params, x, cfg, statics, clip, pool_k, pool_v, tbl,
            jnp.int32(start), blk_t, off_t, k_scale, v_scale, k_sub, v_sub)
        outs[fused], pools[fused] = o, new_kv
    # scatter is shared: nibbles, scale planes and sub codes are identical
    for a, b in zip(pools[False], pools[True]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _, _, ks_new, _, ksub_new, vsub_new = pools[True]
    assert float(ks_new[1, 0]) == pytest.approx(0.05)        # scale immutable
    assert int(jnp.min(ksub_new[1])) == 9                    # sub codes immutable
    assert int(jnp.min(vsub_new[1])) == 11
    assert float(jnp.min(ks_new[2])) > 0.0                   # fresh block seeded
    # this chunk wrote rows 4..7 of block 2 -> its written sub-blocks carry
    # live codes in [1, 15]; block 3 stays fully unset (never targeted)
    assert int(jnp.max(ksub_new[2])) >= 1
    assert int(jnp.max(ksub_new[3])) == 0 and float(ks_new[3, 0]) == 0.0
    np.testing.assert_allclose(np.asarray(outs[True]), np.asarray(outs[False]), atol=1e-5)


def test_prefill_requires_sub_planes_iff_int4(quantize_pool_int4):
    KV, bs, MB, D = 2, 8, 2, 16
    pk, pv, tbl = _window_setup(KV, bs, MB, D, seed=31)
    qk, qv, ks, vs, ksub, vsub = quantize_pool_int4(pk, pv)
    p = exaq_params(1.0, 2)
    q = jnp.zeros((1, 2, 4, D))
    with pytest.raises(ValueError):
        ops.paged_prefill_attention(q, qk, qv, tbl, jnp.int32(0), p, 0.25,
                                    k_scale=ks, v_scale=vs, k_sub=ksub,
                                    use_kernel=True)  # packed missing v_sub
    with pytest.raises(ValueError):
        ops.paged_prefill_attention(q, pk, pv, tbl, jnp.int32(0), p, 0.25,
                                    k_sub=ksub, v_sub=vsub,
                                    use_kernel=True)  # fp pool with sub codes


# ------------------------------------------------------------- bytes model

def test_prefill_bytes_model_2x_at_half_occupancy():
    """Acceptance: modeled prefill KV bytes drop >= 2x vs gather-then-attend
    when the prompt fills 50% of the padded window."""
    MB, bs, C = 32, 16, 32
    P = MB * bs // 2  # 50% pool/window occupancy at the end of prefill
    m = paged_prefill_bytes_model(prompt_len=P, chunk=C, kv_heads=8, max_blocks=MB,
                                  block_size=bs, head_dim=128)
    assert m["bytes_reduction_x"] >= 2.0
    # sanity: gather reads live blocks + writes/reads the dense rectangle
    # every chunk (x K+V); fused is (2K + 1V) over live blocks only
    assert m["gather_then_attend_bytes"] == (
        m["live_block_reads"] + 2 * m["chunks"] * MB) * 2 * m["block_bytes"]
    assert m["fused_pool_read_bytes"] == 3 * m["live_block_reads"] * m["block_bytes"]
    assert m["chunks"] == -(-P // C)


def test_prefill_bytes_model_prefix_hits_and_dtype():
    """start_cached (prefix-cache hits) removes whole chunks; int8 pays the
    per-block scale reads and prices the gather's dense copy at fp32."""
    kw = dict(prompt_len=128, chunk=16, kv_heads=4, max_blocks=16, block_size=16,
              head_dim=64)
    cold = paged_prefill_bytes_model(**kw)
    warm = paged_prefill_bytes_model(start_cached=96, **kw)
    assert warm["chunks"] == 2 and cold["chunks"] == 8
    assert warm["fused_pool_read_bytes"] < cold["fused_pool_read_bytes"]
    m8 = paged_prefill_bytes_model(kv_dtype="int8", **kw)
    assert m8["block_bytes"] == 4 * (16 * 64 + 4)
    assert m8["gather_then_attend_bytes"] == (
        m8["live_block_reads"] * m8["block_bytes"]
        + 2 * m8["chunks"] * 16 * 4 * 16 * 64 * 4) * 2
    # packed int4: half-byte payload + fp32 scale + per-sub-block code per head
    from repro.kernels.ops import kv4_num_sub

    m4 = paged_prefill_bytes_model(kv_dtype="int4", **kw)
    assert m4["block_bytes"] == 4 * (16 * 64 // 2 + 4 + kv4_num_sub(16))
    assert m8["fused_pool_read_bytes"] / m4["fused_pool_read_bytes"] >= 1.8
    m16 = paged_prefill_bytes_model(kv_dtype="bf16", **kw)
    assert m16["fused_pool_read_bytes"] / m4["fused_pool_read_bytes"] >= 3.5


# ------------------------------------------------------- engine greedy parity

def _engine_trace(cfg, params, *, fused, cache_dtype=jnp.float32):
    from repro.runtime.engine import PagedEngine

    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, 12)
    spec = [(14, 6), (21, 4), (9, 8)]  # prompts span several prefill chunks
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, n)])
               for n, _ in spec]
    eng = PagedEngine(cfg, params, max_slots=2, max_seq=64, steps_per_sync=4,
                      block_size=8, prefill_chunk=8, seed=0, fused=fused,
                      cache_dtype=cache_dtype)
    uids = [eng.submit(p, g) for p, (_, g) in zip(prompts, spec)]
    res = eng.run()
    assert eng.stats["prefill_chunks"] > len(prompts)  # chunked, not one-shot
    assert eng.stats["prefix_hit_tokens"] > 0          # CoW/prefix paths engaged
    return [res[u].tokens for u in uids]


def test_paged_engine_fused_prefill_matches_gather_greedy():
    """Bit-exact greedy parity through a full prefill+decode PagedEngine
    trace: with ``fused`` toggled, BOTH the paged-prefill and paged-decode
    kernels swap in, and the emitted tokens must match the gather references
    exactly (shared-prefix prompts, multi-chunk prefills — DESIGN.md §7)."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    assert _engine_trace(cfg, params, fused=True) == _engine_trace(cfg, params, fused=False)


@pytest.mark.parametrize("cache_dtype", [jnp.int8, "int4"], ids=["int8", "int4"])
def test_paged_engine_fused_prefill_quantized_matches_gather_greedy(cache_dtype):
    """Engine-level parity on quantized pools: quantize-on-scatter with
    first-write scale (+ int4 sub-code) seeding is shared by both paths, so
    fused and gather dequantize identical codes and emit identical greedy
    tokens through multi-chunk shared-prefix prefills (DESIGN.md §6/§7/§10)."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("yi-6b").reduced(num_layers=2).with_quant(softmax_impl="exaq", bits=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0), jnp.float32)
    assert (_engine_trace(cfg, params, fused=True, cache_dtype=cache_dtype)
            == _engine_trace(cfg, params, fused=False, cache_dtype=cache_dtype))
