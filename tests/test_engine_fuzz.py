"""Randomized differential trace fuzzer for the serving engines.

Two layers, both seeded from PYTEST_SEED (see conftest — every failure
report prints the derived seed, so any counterexample replays with one env
var):

  * Host fuzz — random admission / chunked-prefill / CoW-fork / preempt /
    eviction schedules driven through a pure-host ``EngineCore`` with a
    numpy emulation of the device decode chunk. After EVERY step the full
    allocator state is audited against the BlockPool invariants I1-I4
    (DESIGN.md §3): refcounts equal table references, free/LRU/live
    partition the pool, the prefix index and its reverse map agree, the
    null block is never touched, and queued CoW destinations are never
    pending a scale reset.

  * Differential fuzz — the same randomized request trace run through real
    ``PagedEngine`` instances across the fp32/bf16/int8/int4 pool formats,
    fused and gather paths: fused-vs-gather greedy tokens must match
    exactly per format (same dequant arithmetic, kernel parity <= 1e-5,
    trained smoke-model margins — DESIGN.md §6/§10), quantized formats
    must agree with the fp32 pool on nearly every token, and the allocator
    invariants hold after every engine step.

Scale knobs for the scheduled long-fuzz CI job: FUZZ_TRACES multiplies the
host-fuzz trace count, FUZZ_STEPS the per-trace step count.
"""

import os
import sys

import numpy as np
import pytest

from conftest import PYTEST_SEED, derive_seed
from repro.runtime.engine_core import EngineCore
from repro.runtime.kv_pool import NULL_BLOCK, PoolExhausted

FUZZ_TRACES = int(os.environ.get("FUZZ_TRACES", "4"))
FUZZ_STEPS = int(os.environ.get("FUZZ_STEPS", "40"))


# ------------------------------------------------------------ invariant audit


def check_invariants(core: EngineCore) -> None:
    """Audit the full allocator + scheduler state (BlockPool I1-I4 plus the
    engine-core bookkeeping that rides on them). Cheap enough to run after
    every fuzz step."""
    pool = core.pool
    n = pool.num_blocks
    ref = np.asarray(pool.refcount)
    free = list(pool._free)
    lru = list(pool._lru)

    # I4: the null block is permanently reserved
    assert NULL_BLOCK not in free and NULL_BLOCK not in lru
    assert ref[NULL_BLOCK] == 0

    # I1: free / evictable(LRU) / live partition the usable ids exactly
    assert len(set(free)) == len(free), "duplicate ids on the free list"
    assert len(set(lru)) == len(lru), "duplicate ids on the LRU"
    live = {b for b in range(1, n) if ref[b] > 0}
    assert live.isdisjoint(free), f"live blocks on the free list: {live & set(free)}"
    assert live.isdisjoint(lru), f"live blocks on the LRU: {live & set(lru)}"
    assert set(free).isdisjoint(lru)
    assert live | set(free) | set(lru) == set(range(1, n)), "pool partition leak"

    # I3: evictable blocks are refcount-0 AND published (else they'd be free)
    for b in lru:
        assert ref[b] == 0 and b in pool._hash_of

    # I2 bookkeeping: index and reverse map agree
    for h, b in pool._index.items():
        assert pool._hash_of.get(b) == h, f"index/hash_of disagree on block {b}"

    # refcount accounting: every reference is exactly one slot-table entry
    expected = np.zeros(n, np.int64)
    for i, s in enumerate(core._slots):
        if s.free:
            continue
        for b in s.table:
            assert b != NULL_BLOCK
            expected[b] += 1
        # the device mirror matches host truth
        t = core._tables[i]
        assert list(t[: len(s.table)]) == list(s.table)
        assert (t[len(s.table):] == NULL_BLOCK).all()
    np.testing.assert_array_equal(
        ref[1:], expected[1:],
        err_msg="refcounts drifted from slot-table references",
    )

    # queued CoW destinations must not be pending a scale reset (the copy
    # delivers their valid grid; a later reset would zero it)
    for _, dst in core.pending_copies:
        assert dst not in core._fresh_blocks


# ----------------------------------------------------------------- host fuzz


def _host_step_chunk(core: EngineCore, rng, vocab: int, eos: int) -> None:
    """One PagedEngine.step_chunk with the device replaced by a numpy decode
    emulation that honors decode_scan's visible semantics (emission masks,
    budget/eos/max_seq finish transitions)."""
    core._admit()
    for i, s in enumerate(core._slots):
        if not s.free and s.prefilling:
            plan = core.plan_prefill_chunk(i)
            core.take_pending_copies()
            core.take_fresh_scale_ids()
            if core.commit_prefill_chunk(i, plan.n):
                core._complete_first(i, s.req, int(rng.integers(0, vocab)))
    if core.num_active == 0:
        return
    steps = core._clamp_steps(int(rng.integers(1, core.steps_per_sync + 1)))
    core._reserve_chunk_blocks(steps)
    if core.num_active == 0:
        return
    core.take_pending_copies()
    core.take_fresh_scale_ids()
    S = core.max_slots
    lens = core.kv_lens.copy()
    active = core._active.copy()
    budget = core._budget.copy()
    tokens = core._tokens.copy()
    emitted = np.full((steps, S), -1, np.int64)
    masks = np.zeros((steps, S), bool)
    was_active = core._active.copy()
    for t in range(steps):
        for b in range(S):
            if not active[b]:
                continue
            nxt = int(rng.integers(0, vocab))
            masks[t, b] = True
            emitted[t, b] = nxt
            tokens[b, 0] = nxt
            lens[b] += 1
            budget[b] -= 1
            if nxt == eos or budget[b] <= 0 or lens[b] >= core.max_seq:
                active[b] = False
    core._absorb_chunk(tokens, lens, active, budget, emitted, masks, was_active)


def test_engine_core_invariants_under_random_schedules(test_seed):
    """Random traces: bursty submissions (shared prefixes force CoW forks and
    prefix hits), tight pools (forcing eviction and preempt-and-recompute),
    random chunk sizes — with the full allocator audit after every step."""
    rng = np.random.default_rng(test_seed)
    vocab, eos = 40, 1
    for trace in range(FUZZ_TRACES):
        bs = int(rng.choice([2, 4, 8]))
        max_seq = int(rng.choice([32, 48, 64]))
        max_slots = int(rng.integers(2, 5))
        per_table = -(-max_seq // bs)
        full = 1 + max_slots * per_table
        num_blocks = int(rng.choice([full, max(per_table + 2, int(full * 0.5))]))
        core = EngineCore(max_slots=max_slots, max_seq=max_seq, block_size=bs,
                          prefill_chunk=int(rng.choice([4, 8, 16])),
                          num_blocks=num_blocks, eos_id=eos,
                          steps_per_sync=int(rng.integers(2, 9)),
                          quantized=bool(rng.integers(0, 2)))
        prefixes = [tuple(rng.integers(2, vocab, int(rng.integers(0, 17))))
                    for _ in range(3)]
        submitted = 0
        for step in range(FUZZ_STEPS):
            for _ in range(int(rng.integers(0, 3))):
                pre = prefixes[int(rng.integers(0, len(prefixes)))]
                body = tuple(rng.integers(2, vocab, int(rng.integers(1, 13))))
                prompt = (pre + body)[: max_seq - 2]
                try:
                    core.submit(list(prompt), int(rng.integers(1, 10)))
                    submitted += 1
                except ValueError:
                    pass  # request larger than this trace's tight pool
            try:
                _host_step_chunk(core, rng, vocab, eos)
            except PoolExhausted:
                # honest back-pressure when prefilling slots pin the pool and
                # the active set can't shrink further — legal terminal state
                check_invariants(core)
                break
            check_invariants(core)
        else:
            while core.has_work():
                try:
                    _host_step_chunk(core, rng, vocab, eos)
                except PoolExhausted:
                    check_invariants(core)
                    break
                check_invariants(core)
        done = len(core._results) + len(core._preempt_carry)
        assert submitted > 0, f"trace {trace} submitted nothing — widen the generator"
        check_invariants(core)


def test_fresh_scale_queue_never_contains_fork_destinations(test_seed):
    """Directed micro-fuzz of the reset/copy ordering contract: interleave
    allocs, releases and forks, draining the copy queue right after each
    fork the way ``PagedEngine._make_writable`` does; at every drain, the
    copy destination must have escaped the fresh-scale set (DESIGN.md §6 —
    a CoW dst whose scales get zeroed after the copy lands would silently
    dequantize to garbage)."""
    rng = np.random.default_rng(test_seed)
    core = EngineCore(max_slots=4, max_seq=64, block_size=4, num_blocks=24,
                      quantized=True)
    held: list[int] = []
    for step in range(300):
        op = rng.integers(0, 4)
        if op == 0:
            try:
                held.append(core._alloc_fresh())
            except PoolExhausted:
                pass
        elif op == 1 and held:
            core.pool.release(held.pop(int(rng.integers(0, len(held)))))
        elif op == 2 and held:
            blk = held[int(rng.integers(0, len(held)))]
            core.pool.retain(blk)
            held.append(blk)
        elif op == 3 and held:
            blk = held[int(rng.integers(0, len(held)))]
            if core.pool.refcount[blk] > 1:
                try:
                    new = core.pool.fork(blk)
                except PoolExhausted:
                    continue
                core._fresh_blocks.discard(new)
                core.pending_copies.append((blk, new))
                held[held.index(blk)] = new
                # PagedEngine drains the copy queue as soon as the fork is
                # planned — the dst must already be out of the fresh set,
                # else the pending reset would zero its just-copied scales.
                for _, dst in core.take_pending_copies():
                    assert dst not in core._fresh_blocks
        if step % 17 == 16:  # periodic launch: fresh-scale queue flushes
            drained = core.take_fresh_scale_ids()
            assert core.take_fresh_scale_ids() == []  # queue clears on take
            assert len(set(drained)) == len(drained)
    assert not core.pending_copies  # every fork drained inline
    drained = core.take_fresh_scale_ids()
    assert core.take_fresh_scale_ids() == []
    assert all(0 < b < 24 for b in drained)


# ---------------------------------------------------------- differential fuzz


@pytest.fixture(scope="module")
def smoke_model():
    """2-layer smoke model briefly overfit on a periodic stream (the bench's
    recipe): random-init logits are argmax noise — quantization-agreement
    fuzzing needs confident greedy margins to measure the pools, not ties."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
    from bench_serving import make_smoke_model

    cfg, params, loss = make_smoke_model("yi-6b", train_steps=60)
    assert loss < 0.2, f"smoke model failed to overfit (loss {loss})"
    return cfg, params


def _run_trace(cfg, params, trace, *, kv_dtype, fused):
    from repro.runtime.engine import PagedEngine
    from repro.runtime.serve import KV_DTYPES

    eng = PagedEngine(cfg, params, max_slots=3, max_seq=64, block_size=8,
                      prefill_chunk=16, eos_id=None, seed=0, fused=fused,
                      cache_dtype=KV_DTYPES[kv_dtype])
    for batch in trace:
        for prompt, max_new in batch:
            eng.submit(prompt, max_new)
        eng.step_chunk()
        check_invariants(eng)
    while eng.has_work():
        eng.step_chunk()
        check_invariants(eng)
    return {uid: g.tokens for uid, g in eng.run().items()}


def _make_trace(rng, vocab: int, n_requests: int = 5):
    """Bursty schedule of shared-prefix prompts: some steps submit nothing,
    some submit two — exercising admission alongside live decode. Prompts
    are rotated windows of the smoke model's trained periodic pattern —
    agreement floors against the fp32 pool need in-distribution margins
    (random tokens collapse argmax margins to the quantizer's noise floor;
    see the smoke_model fixture), and the ragged cut/rotation still
    diversifies block layouts and prefix-cache hits across seeds."""
    del vocab  # prompts come from the trained pattern, not the full vocab
    from bench_serving import PERIOD, TOK0

    pattern = [int(t) for t in np.arange(48) % PERIOD + TOK0]
    prefix = pattern[:12]
    trace, left = [], n_requests
    while left > 0:
        k = int(min(left, rng.integers(0, 3)))
        batch = []
        for _ in range(k):
            cut = int(rng.integers(0, len(prefix) + 1))
            # the tail continues the pattern from the cut so the whole prompt
            # stays a (rotated) in-distribution window
            n_body = int(rng.integers(4, 16))
            body = pattern[cut : cut + n_body]
            batch.append((prefix[:cut] + body, int(rng.integers(4, 10))))
            left -= 1
        trace.append(batch)
    return trace


def test_differential_pools_fused_vs_gather_same_trace(smoke_model, test_seed):
    """One randomized trace through every pool format x path: fused and
    gather must emit identical greedy tokens per format, and the quantized
    pools must track the fp32 pool's tokens (the bench gates the exact
    agreement floors; here the trained margins make disagreement a bug
    signal, not noise)."""
    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    trace = _make_trace(rng, cfg.vocab_size)
    ref = _run_trace(cfg, params, trace, kv_dtype="fp32", fused=False)
    flat_ref = [t for uid in sorted(ref) for t in ref[uid]]
    for kv_dtype in ("fp32", "bf16", "int8", "int4"):
        gather = _run_trace(cfg, params, trace, kv_dtype=kv_dtype, fused=False)
        fused = _run_trace(cfg, params, trace, kv_dtype=kv_dtype, fused=True)
        assert gather == fused, (
            f"[seed {test_seed}] kv_dtype={kv_dtype}: fused and gather paths "
            f"diverged on the same trace"
        )
        flat = [t for uid in sorted(gather) for t in gather[uid]]
        assert len(flat) == len(flat_ref)
        agree = float(np.mean(np.asarray(flat) == np.asarray(flat_ref)))
        floor = 1.0 if kv_dtype == "fp32" else 0.95
        assert agree >= floor, (
            f"[seed {test_seed}] kv_dtype={kv_dtype}: greedy agreement "
            f"{agree:.3f} vs fp32 below {floor}"
        )
