"""Randomized differential trace fuzzer for the serving engines.

Two layers, both seeded from PYTEST_SEED (see conftest — every failure
report prints the derived seed, so any counterexample replays with one env
var):

  * Host fuzz — random admission / chunked-prefill / CoW-fork / preempt /
    eviction / *cancellation* schedules driven through a pure-host
    ``EngineCore`` with a numpy emulation of the device decode chunk
    (``runtime.faults.HostDeviceEmulator``). After EVERY step — and after
    every cancellation — the full allocator state is audited against the
    BlockPool invariants I1-I4 (DESIGN.md §3) via
    ``runtime.faults.audit_block_invariants``: refcounts equal table
    references, free/LRU/live partition the pool, the prefix index and its
    reverse map agree, the null block is never touched, and queued CoW
    destinations are never pending a scale reset. A second host fuzzer runs
    the speculative-decoding lifecycle (DESIGN.md §12): fork-k-branches /
    verify / release events interleaved with cancels, preemptions and pool
    exhaustion, with branch tables counted into the refcount audit after
    every event.

  * Differential fuzz — the same randomized request trace (submissions AND
    mid-flight cancel events) run through real ``PagedEngine`` instances
    across the fp32/bf16/int8/int4 pool formats, fused and gather paths:
    fused-vs-gather greedy tokens must match exactly per format (same
    dequant arithmetic, kernel parity <= 1e-5, trained smoke-model margins
    — DESIGN.md §6/§10), quantized formats must agree with the fp32 pool on
    nearly every token, and the allocator invariants hold after every
    engine step and cancellation. Cancel timing is measured in trace steps
    and the engines run with eos_id=None, so scheduling (and therefore each
    cancelled request's partial length) is identical across formats — only
    token *values* may differ.

Scale knobs for the scheduled long-fuzz CI job: FUZZ_TRACES multiplies the
host-fuzz trace count, FUZZ_STEPS the per-trace step count.
"""

import os

import numpy as np
import pytest

from conftest import PYTEST_SEED, derive_seed
from repro.runtime.engine_core import EngineCore
from repro.runtime.faults import HostDeviceEmulator, audit_block_invariants
from repro.runtime.kv_pool import PoolExhausted

FUZZ_TRACES = int(os.environ.get("FUZZ_TRACES", "4"))
FUZZ_STEPS = int(os.environ.get("FUZZ_STEPS", "40"))

# the audit moved to runtime/faults.py so the chaos suite shares it; the
# local name is kept — half this file reads as "step, then check_invariants"
check_invariants = audit_block_invariants


# ----------------------------------------------------------------- host fuzz


def _host_step_chunk(core: EngineCore, rng, vocab: int, eos: int) -> None:
    """One emulated PagedEngine.step_chunk (see HostDeviceEmulator)."""
    HostDeviceEmulator(rng, vocab=vocab, eos=eos).step_chunk(core)


def _cancel_random(core: EngineCore, rng) -> bool:
    """Cancel one uniformly-chosen in-flight request (queued, prefilling, or
    decoding); no-op when nothing is in flight."""
    uids = [s.uid for s in core._slots if not s.free]
    uids += [r.uid for r in core._queue]
    if not uids:
        return False
    assert core.cancel(int(rng.choice(uids)))
    return True


def test_engine_core_invariants_under_random_schedules(test_seed):
    """Random traces: bursty submissions (shared prefixes force CoW forks and
    prefix hits), tight pools (forcing eviction and preempt-and-recompute),
    random chunk sizes, random mid-flight cancellations — with the full
    allocator audit after every step AND after every cancellation
    (refcount-vs-table equality is exactly where a cancel leak would show)."""
    rng = np.random.default_rng(test_seed)
    vocab, eos = 40, 1
    for trace in range(FUZZ_TRACES):
        bs = int(rng.choice([2, 4, 8]))
        max_seq = int(rng.choice([32, 48, 64]))
        max_slots = int(rng.integers(2, 5))
        per_table = -(-max_seq // bs)
        full = 1 + max_slots * per_table
        num_blocks = int(rng.choice([full, max(per_table + 2, int(full * 0.5))]))
        core = EngineCore(max_slots=max_slots, max_seq=max_seq, block_size=bs,
                          prefill_chunk=int(rng.choice([4, 8, 16])),
                          num_blocks=num_blocks, eos_id=eos,
                          steps_per_sync=int(rng.integers(2, 9)),
                          quantized=bool(rng.integers(0, 2)))
        prefixes = [tuple(rng.integers(2, vocab, int(rng.integers(0, 17))))
                    for _ in range(3)]
        submitted = cancelled = 0
        for step in range(FUZZ_STEPS):
            for _ in range(int(rng.integers(0, 3))):
                pre = prefixes[int(rng.integers(0, len(prefixes)))]
                body = tuple(rng.integers(2, vocab, int(rng.integers(1, 13))))
                prompt = (pre + body)[: max_seq - 2]
                try:
                    core.submit(list(prompt), int(rng.integers(1, 10)))
                    submitted += 1
                except ValueError:
                    pass  # request larger than this trace's tight pool
            try:
                _host_step_chunk(core, rng, vocab, eos)
            except PoolExhausted:
                # honest back-pressure when prefilling slots pin the pool and
                # the active set can't shrink further — legal terminal state
                check_invariants(core)
                break
            check_invariants(core)
            if rng.random() < 0.25 and _cancel_random(core, rng):
                cancelled += 1
                check_invariants(core)
        else:
            while core.has_work():
                try:
                    _host_step_chunk(core, rng, vocab, eos)
                except PoolExhausted:
                    check_invariants(core)
                    break
                check_invariants(core)
        done = len(core._results) + len(core._preempt_carry)
        assert submitted > 0, f"trace {trace} submitted nothing — widen the generator"
        check_invariants(core)


def test_state_pool_invariants_under_random_schedules(test_seed):
    """The base fuzzer re-run with ``state_blocks=True`` (DESIGN.md §13):
    the same bursty shared-prefix traces, tight pools, random cancels and
    preempt-and-recompute cycles, but with the StatePool registration rules
    in force — prefix hits truncate to full blocks and mutable partial tails
    must never appear in the prefix index (the extra clause
    ``audit_block_invariants`` grows when ``core.state_blocks`` is set).
    State pools are never quantized, so the fresh-scale queue must stay
    empty for the whole trace."""
    rng = np.random.default_rng(test_seed)
    vocab, eos = 40, 1
    for trace in range(FUZZ_TRACES):
        bs = int(rng.choice([2, 4, 8]))
        max_seq = int(rng.choice([32, 48, 64]))
        max_slots = int(rng.integers(2, 5))
        per_table = -(-max_seq // bs)
        full = 1 + max_slots * per_table
        num_blocks = int(rng.choice([full, max(per_table + 2, int(full * 0.5))]))
        core = EngineCore(max_slots=max_slots, max_seq=max_seq, block_size=bs,
                          prefill_chunk=int(rng.choice([4, 8, 16])),
                          num_blocks=num_blocks, eos_id=eos,
                          steps_per_sync=int(rng.integers(2, 9)),
                          state_blocks=True)
        prefixes = [tuple(rng.integers(2, vocab, int(rng.integers(0, 17))))
                    for _ in range(3)]
        submitted = 0
        for step in range(FUZZ_STEPS):
            for _ in range(int(rng.integers(0, 3))):
                pre = prefixes[int(rng.integers(0, len(prefixes)))]
                body = tuple(rng.integers(2, vocab, int(rng.integers(1, 13))))
                prompt = (pre + body)[: max_seq - 2]
                try:
                    core.submit(list(prompt), int(rng.integers(1, 10)))
                    submitted += 1
                except ValueError:
                    pass
            try:
                _host_step_chunk(core, rng, vocab, eos)
            except PoolExhausted:
                check_invariants(core)
                break
            check_invariants(core)
            assert not core._fresh_blocks and not core.take_fresh_scale_ids(), \
                "state pools are unquantized: no scale resets may queue"
            if rng.random() < 0.25 and _cancel_random(core, rng):
                check_invariants(core)
        else:
            while core.has_work():
                try:
                    _host_step_chunk(core, rng, vocab, eos)
                except PoolExhausted:
                    check_invariants(core)
                    break
                check_invariants(core)
        assert submitted > 0, f"trace {trace} submitted nothing — widen the generator"
        check_invariants(core)


def test_state_pool_preempt_keeps_emitted_prefix(test_seed):
    """Host check of the SSM preempt-and-recompute carry (DESIGN.md §13): a
    scripted mid-decode preemption of every active ``state_blocks`` slot
    must fold the tokens emitted so far into the continuation request —
    final streams start with the captured prefix, land exactly ``max_new``
    tokens (nothing lost, nothing doubled), and the allocator audit stays
    clean through the preempt/readmit cycle. (Value-exact recompute needs
    the real model and lives in test_state_pool.py; the emulator draws
    token values from its rng.)"""
    from repro.runtime.faults import EmulatedEngine

    rng = np.random.default_rng(test_seed)
    eng = EmulatedEngine(rng, max_slots=2, max_seq=48, block_size=4,
                         prefill_chunk=8, steps_per_sync=4, eos_id=None,
                         vocab=40, state_blocks=True)
    prng = np.random.default_rng(test_seed + 1)
    uids = [eng.submit(list(prng.integers(2, 40, 11)), 9) for _ in range(3)]
    steps, prefixes = 0, {}
    while eng.has_work():
        eng.step_chunk()
        check_invariants(eng)
        steps += 1
        if steps in (2, 4):  # two scripted preemption storms mid-decode
            for i in range(eng.max_slots):
                if eng._active[i]:
                    uid = eng._slots[i].uid
                    prefixes[uid] = list(eng.tokens_so_far(uid))
                    eng._preempt(i)
            check_invariants(eng)
    results = {uid: list(g.tokens) for uid, g in eng.run().items()}
    assert set(results) == set(uids)
    assert all(len(t) == 9 for t in results.values())
    assert eng.stats["preemptions"] > 0, "storms at chunks 2/4 preempted nothing"
    for uid, pre in prefixes.items():
        assert results[uid][: len(pre)] == pre, (
            f"[seed {test_seed}] uid {uid}: preemption dropped emitted tokens"
        )


def _spec_event(core: EngineCore, rng, vocab: int) -> None:
    """One speculative lifecycle event on a random decoding slot (DESIGN.md
    §12): fork 1-3 draft branches, drain the queued device effects the way
    the engine would, then resolve the round by an rng-chosen fate — cancel
    mid-verify, preempt mid-verify, plain abort (dropped round), or commit
    one winner and release the losers through the normal abort path. The
    allocator audit runs between every sub-event; a ``PoolExhausted`` during
    branch planning must roll that branch back completely (the audit right
    after is what catches a leaked partial allocation)."""
    slots = [i for i in range(core.max_slots) if core._active[i]]
    if not slots:
        return
    slot = int(rng.choice(slots))
    uid = core._slots[slot].uid
    L = int(core.kv_lens[slot])
    kmax = max(0, min(4, int(core._budget[slot]) - 1, core.max_seq - 1 - L))
    plans = []
    for _ in range(int(rng.integers(1, 4))):
        drafts = [int(t) for t in rng.integers(0, vocab, int(rng.integers(0, kmax + 1)))]
        try:
            plans.append(core.plan_spec_round(slot, drafts))
        except PoolExhausted:
            break  # full rollback claimed; branches planned so far stay live
        check_invariants(core)
    check_invariants(core)
    # the engine drains fork copies + scale resets before the verify launch;
    # a fork destination must already have escaped the fresh-scale set
    for _, dst in core.take_pending_copies():
        assert dst not in core._fresh_blocks
    core.take_fresh_scale_ids()
    fate = rng.random()
    if not plans or fate < 0.12:
        assert core.cancel(uid)              # client vanished mid-verify
    elif fate < 0.24:
        core._preempt(slot)                  # pool pressure mid-verify
    elif fate < 0.36:
        core.abort_spec_branches(slot)       # round dropped (deadline, fault)
    else:
        winner = plans[int(rng.integers(0, len(plans)))]
        k = len(winner.branch.drafts)
        a = int(rng.integers(0, k + 1))      # scripted accept length
        verified = list(winner.branch.drafts[:a])
        for i in range(a, k + 1):
            t = int(rng.integers(0, vocab))
            if i < k and t == winner.branch.drafts[i]:
                t = (t + 1) % vocab
            verified.append(t)
        res = core.commit_spec_round(winner, verified)
        check_invariants(core)
        core.absorb_spec_round(slot, res.emitted)  # may finish -> aborts losers
        check_invariants(core)
        core.abort_spec_branches(slot)       # losing siblings release normally
    check_invariants(core)
    assert core._branches.get(slot) is None, "spec event left branches in flight"


def test_spec_branch_lifecycle_invariants_under_random_schedules(test_seed):
    """The host fuzz of the speculative fork/verify/release lifecycle: the
    same bursty tight-pool traces as the base fuzzer, with spec events mixed
    into every step — multi-branch forks, scripted accept lengths 0..k,
    cancels and preemptions landing mid-verify, and PoolExhausted during
    branch planning. Invariants I1-I4 plus refcount-vs-table equality (branch
    tables included) must hold after every event, and no event may leave a
    branch in flight past its round."""
    rng = np.random.default_rng(test_seed)
    vocab, eos = 40, 1
    for trace in range(FUZZ_TRACES):
        bs = int(rng.choice([2, 4, 8]))
        max_seq = int(rng.choice([32, 48, 64]))
        max_slots = int(rng.integers(2, 5))
        per_table = -(-max_seq // bs)
        full = 1 + max_slots * per_table
        num_blocks = int(rng.choice([full, max(per_table + 3, int(full * 0.5))]))
        core = EngineCore(max_slots=max_slots, max_seq=max_seq, block_size=bs,
                          prefill_chunk=int(rng.choice([4, 8, 16])),
                          num_blocks=num_blocks, eos_id=eos,
                          steps_per_sync=int(rng.integers(2, 9)),
                          quantized=bool(rng.integers(0, 2)))
        submitted = spec_events = 0
        for step in range(FUZZ_STEPS):
            for _ in range(int(rng.integers(0, 3))):
                prompt = tuple(rng.integers(2, vocab, int(rng.integers(1, 13))))
                try:
                    core.submit(list(prompt), int(rng.integers(2, 12)))
                    submitted += 1
                except ValueError:
                    pass
            # admit + prefill through the emulator only: spec rounds replace
            # decode chunks entirely when spec_k > 0 (a branch in flight
            # during a decode chunk cannot happen in production)
            try:
                core._admit()
                for i, s in enumerate(core._slots):
                    if not s.free and s.prefilling:
                        plan = core.plan_prefill_chunk(i)
                        core.take_pending_copies()
                        core.take_fresh_scale_ids()
                        if core.commit_prefill_chunk(i, plan.n):
                            core._complete_first(i, s.req,
                                                 int(rng.integers(0, vocab)))
            except PoolExhausted:
                check_invariants(core)
                break
            check_invariants(core)
            for _ in range(int(rng.integers(1, 3))):
                _spec_event(core, rng, vocab)
                spec_events += 1
            if rng.random() < 0.2 and _cancel_random(core, rng):
                check_invariants(core)
        assert submitted > 0 and spec_events > 0
        assert not core._branches, "trace ended with branches in flight"
        check_invariants(core)


def test_fresh_scale_queue_never_contains_fork_destinations(test_seed):
    """Directed micro-fuzz of the reset/copy ordering contract: interleave
    allocs, releases and forks, draining the copy queue right after each
    fork the way ``PagedEngine._make_writable`` does; at every drain, the
    copy destination must have escaped the fresh-scale set (DESIGN.md §6 —
    a CoW dst whose scales get zeroed after the copy lands would silently
    dequantize to garbage)."""
    rng = np.random.default_rng(test_seed)
    core = EngineCore(max_slots=4, max_seq=64, block_size=4, num_blocks=24,
                      quantized=True)
    held: list[int] = []
    for step in range(300):
        op = rng.integers(0, 4)
        if op == 0:
            try:
                held.append(core._alloc_fresh())
            except PoolExhausted:
                pass
        elif op == 1 and held:
            core.pool.release(held.pop(int(rng.integers(0, len(held)))))
        elif op == 2 and held:
            blk = held[int(rng.integers(0, len(held)))]
            core.pool.retain(blk)
            held.append(blk)
        elif op == 3 and held:
            blk = held[int(rng.integers(0, len(held)))]
            if core.pool.refcount[blk] > 1:
                try:
                    new = core.pool.fork(blk)
                except PoolExhausted:
                    continue
                core._fresh_blocks.discard(new)
                core.pending_copies.append((blk, new))
                held[held.index(blk)] = new
                # PagedEngine drains the copy queue as soon as the fork is
                # planned — the dst must already be out of the fresh set,
                # else the pending reset would zero its just-copied scales.
                for _, dst in core.take_pending_copies():
                    assert dst not in core._fresh_blocks
        if step % 17 == 16:  # periodic launch: fresh-scale queue flushes
            drained = core.take_fresh_scale_ids()
            assert core.take_fresh_scale_ids() == []  # queue clears on take
            assert len(set(drained)) == len(drained)
    assert not core.pending_copies  # every fork drained inline
    drained = core.take_fresh_scale_ids()
    assert core.take_fresh_scale_ids() == []
    assert all(0 < b < 24 for b in drained)


# ---------------------------------------------------------- differential fuzz


# quantization-agreement fuzzing needs confident greedy margins to measure
# the pools, not argmax ties: the session-scoped trained `smoke_model`
# fixture lives in conftest.py (shared with the SLA and chaos suites)


def _run_trace(cfg, params, trace, *, kv_dtype, fused, cancels=()):
    """Replay a (submission, cancel) schedule. ``cancels`` maps step index ->
    list of submission ordinals to cancel right after that step; uids are
    assigned in submission order, identically across engine configs, so the
    same schedule cancels the same logical requests everywhere. The
    allocator audit runs after every step and every cancellation."""
    from repro.runtime.engine import PagedEngine
    from repro.runtime.serve import KV_DTYPES

    cancels = dict(cancels)
    eng = PagedEngine(cfg, params, max_slots=3, max_seq=64, block_size=8,
                      prefill_chunk=16, eos_id=None, seed=0, fused=fused,
                      cache_dtype=KV_DTYPES[kv_dtype])
    uids: list[int] = []
    for step, batch in enumerate(trace):
        for prompt, max_new in batch:
            uids.append(eng.submit(prompt, max_new))
        eng.step_chunk()
        check_invariants(eng)
        for ordinal in cancels.get(step, ()):
            eng.cancel(uids[ordinal])  # False when already finished — also
            check_invariants(eng)      # a legal (deterministic) outcome
    while eng.has_work():
        eng.step_chunk()
        check_invariants(eng)
    return {uid: g.tokens for uid, g in eng.run().items()}


def _make_trace(rng, vocab: int, n_requests: int = 5):
    """Bursty schedule of shared-prefix prompts: some steps submit nothing,
    some submit two — exercising admission alongside live decode. Prompts
    are rotated windows of the smoke model's trained periodic pattern —
    agreement floors against the fp32 pool need in-distribution margins
    (random tokens collapse argmax margins to the quantizer's noise floor;
    see the smoke_model fixture), and the ragged cut/rotation still
    diversifies block layouts and prefix-cache hits across seeds.

    Also emits cancel/abort events: each submission may be scheduled for
    cancellation a few steps after it lands, so the differential fuzzer
    covers mid-flight removal (queued, prefilling, and decoding victims).
    Returns (trace, cancels) in ``_run_trace``'s schedule format."""
    del vocab  # prompts come from the trained pattern, not the full vocab
    from bench_serving import PERIOD, TOK0

    pattern = [int(t) for t in np.arange(48) % PERIOD + TOK0]
    prefix = pattern[:12]
    trace, left, ordinal = [], n_requests, 0
    cancels: dict[int, list[int]] = {}
    while left > 0:
        k = int(min(left, rng.integers(0, 3)))
        batch = []
        for _ in range(k):
            cut = int(rng.integers(0, len(prefix) + 1))
            # the tail continues the pattern from the cut so the whole prompt
            # stays a (rotated) in-distribution window
            n_body = int(rng.integers(4, 16))
            body = pattern[cut : cut + n_body]
            batch.append((prefix[:cut] + body, int(rng.integers(4, 10))))
            if rng.random() < 0.3:  # mid-flight abort, 0-2 steps later
                when = len(trace) + int(rng.integers(0, 3))
                cancels.setdefault(when, []).append(ordinal)
            ordinal += 1
            left -= 1
        trace.append(batch)
    return trace, cancels


def test_differential_pools_fused_vs_gather_same_trace(smoke_model, test_seed):
    """One randomized trace (with mid-flight cancels) through every pool
    format x path: fused and gather must emit identical greedy tokens per
    format, and the quantized pools must track the fp32 pool's tokens (the
    bench gates the exact agreement floors; here the trained margins make
    disagreement a bug signal, not noise). With eos_id=None and step-indexed
    cancels, every engine produces the same per-request token *counts* —
    cancelled partials included — so the flat comparison stays aligned."""
    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    trace, cancels = _make_trace(rng, cfg.vocab_size)
    ref = _run_trace(cfg, params, trace, kv_dtype="fp32", fused=False,
                     cancels=cancels)
    flat_ref = [t for uid in sorted(ref) for t in ref[uid]]
    for kv_dtype in ("fp32", "bf16", "int8", "int4"):
        gather = _run_trace(cfg, params, trace, kv_dtype=kv_dtype, fused=False,
                            cancels=cancels)
        fused = _run_trace(cfg, params, trace, kv_dtype=kv_dtype, fused=True,
                           cancels=cancels)
        assert gather == fused, (
            f"[seed {test_seed}] kv_dtype={kv_dtype}: fused and gather paths "
            f"diverged on the same trace"
        )
        flat = [t for uid in sorted(gather) for t in gather[uid]]
        assert len(flat) == len(flat_ref)
        agree = float(np.mean(np.asarray(flat) == np.asarray(flat_ref)))
        floor = 1.0 if kv_dtype == "fp32" else 0.95
        assert agree >= floor, (
            f"[seed {test_seed}] kv_dtype={kv_dtype}: greedy agreement "
            f"{agree:.3f} vs fp32 below {floor}"
        )
