"""Paper §3/§4: quantizer + Algo. 2 softmax invariants (incl. hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import (
    QuantParams,
    decode,
    encode,
    exact_softmax,
    exaq_params,
    histogram_denominator,
    lut_lookup,
    naive_params,
    quantized_softmax,
)


def test_quantparams_basic():
    p = exaq_params(1.0, 2, rule="paper")
    assert p.clip == pytest.approx(-3.51)
    assert p.levels == 4
    assert p.delta == pytest.approx(3.51 / 4)
    lut = p.lut_np()
    assert np.all(np.diff(lut) > 0) and lut[-1] < 1.0


@settings(max_examples=30, deadline=None)
@given(
    bits=st.integers(2, 4),
    clip=st.floats(-8.0, -0.5),
    data=st.lists(st.floats(-20.0, 0.0), min_size=4, max_size=64),
)
def test_encode_decode_roundtrip(bits, clip, data):
    p = QuantParams(bits=bits, clip=clip)
    x = jnp.asarray(data, jnp.float32)
    codes = encode(x, p)
    assert int(codes.min()) >= 0 and int(codes.max()) < p.levels
    xq = decode(codes, p)
    # in-range values reconstruct within Delta/2 (+fp slack)
    in_range = (x >= clip) & (x <= 0)
    err = jnp.abs(xq - x)
    assert float(jnp.max(jnp.where(in_range, err, 0.0))) <= p.delta / 2 + 1e-5


def test_histogram_equals_direct_sum():
    p = exaq_params(1.5, 2)
    x = jnp.asarray(np.random.default_rng(0).normal(-2, 1.5, (5, 300)).clip(max=0), jnp.float32)
    codes = encode(x, p)
    lut = p.lut(jnp.float32)
    direct = jnp.sum(lut_lookup(codes, lut), axis=-1)
    hist = histogram_denominator(codes, lut, axis=-1)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(hist), rtol=1e-6)


def test_softmax_rows_sum_to_one_and_nonneg():
    p = exaq_params(2.0, 2)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 2, (8, 128)), jnp.float32)
    y = quantized_softmax(x, p)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, rtol=1e-5)
    assert float(y.min()) >= 0


def test_softmax_shift_invariance():
    """Softmax(x + c) == Softmax(x) must hold for the quantized path too
    (the grid is anchored at the row max)."""
    p = exaq_params(1.0, 3)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (4, 64)), jnp.float32)
    y1 = quantized_softmax(x, p)
    y2 = quantized_softmax(x + 13.7, p)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_masked_softmax_zero_weight_on_masked():
    p = exaq_params(1.0, 2)
    x = jnp.zeros((2, 16), jnp.float32)
    mask = jnp.arange(16)[None, :] < jnp.asarray([5, 16])[:, None]
    y = quantized_softmax(x, p, where=mask)
    assert float(jnp.abs(y[0, 5:]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    sigma=st.floats(0.9, 3.4),
    bits=st.integers(2, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_exaq_beats_naive_mse(sigma, bits, seed):
    """The paper's core accuracy claim, as a property: EXAQ clipping beats
    NAIVE (min/2) clipping on *heavy-tailed* logits — the regime real softmax
    inputs live in (paper Table 2: NAIVE collapses on actual LLMs precisely
    because outliers blow up the min; on pure Gaussians the two tie)."""
    rng = np.random.default_rng(seed)
    xx = rng.normal(0, sigma, (16, 256))
    out_mask = rng.random((16, 256)) < 0.02           # 2% outlier tail
    xx = np.where(out_mask, xx - rng.exponential(10 * sigma, (16, 256)), xx)
    x = jnp.asarray(xx, jnp.float32)
    ref = exact_softmax(x)
    pe = exaq_params(sigma, bits)
    xmin = float((x - x.max(-1, keepdims=True)).min())
    pn = naive_params(xmin, bits)
    err_e = float(((quantized_softmax(x, pe) - ref) ** 2).mean())
    err_n = float(((quantized_softmax(x, pn) - ref) ** 2).mean())
    assert err_e <= err_n * 1.05 + 1e-9


def test_exaq_close_to_exact_at_2bit():
    """Quantitative guardrail: 2-bit EXAQ softmax stays close to exact."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 2.0, (32, 512)), jnp.float32)
    ref = exact_softmax(x)
    y = quantized_softmax(x, exaq_params(2.0, 2))
    # probabilities live at ~1/512 scale; MSE should be tiny
    assert float(((y - ref) ** 2).mean()) < 1e-4
