"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exaq_params
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("rows,cols", [(1, 8), (3, 100), (8, 128), (17, 250), (2, 1024), (5, 2000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [2, 3])
def test_softmax_kernel_sweep(rows, cols, dtype, bits):
    p = exaq_params(1.5, bits)
    x = jnp.asarray(RNG.normal(0, 1.5, (rows, cols)), dtype)
    got = ops.exaq_softmax(x, p)
    want = ref.exaq_softmax_ref(x, p)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("shape", [(2, 3, 64), (1, 1, 1, 300)])
def test_softmax_kernel_leading_dims(shape):
    p = exaq_params(1.0, 2)
    x = jnp.asarray(RNG.normal(0, 1, shape), jnp.float32)
    got = ops.exaq_softmax(x, p)
    want = ref.exaq_softmax_ref(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_softmax_kernel_with_lens():
    p = exaq_params(1.0, 2)
    x = jnp.asarray(RNG.normal(0, 1, (6, 200)), jnp.float32)
    lens = jnp.asarray([1, 10, 50, 200, 128, 77], jnp.int32)
    got = ops.exaq_softmax(x, p, lens=lens)
    want = ref.exaq_softmax_ref(x, p, lens=lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # masked tail carries no weight
    assert float(jnp.abs(got[0, 1:]).max()) == 0.0


def test_softmax_rowsum_one():
    p = exaq_params(2.0, 2)
    x = jnp.asarray(RNG.normal(0, 2, (16, 384)), jnp.float32)
    y = ops.exaq_softmax(x, p)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, rtol=1e-5)


@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 2, 1, 64, 32), (2, 4, 2, 96, 64), (1, 8, 8, 128, 80)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel_vs_oracle(b, h, hkv, s, d, dtype):
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), dtype)
    scale = d**-0.5
    got = ops.exaq_attention(q, k, v, p, scale, block_q=32, block_kv=32)
    g = h // hkv
    kr, vr = jnp.repeat(k, g, axis=1), jnp.repeat(v, g, axis=1)
    want = ref.flash_exaq_attention_ref(q, kr, vr, p, scale, block_kv=32)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


def test_flash_attention_close_to_exact():
    """Statistical: EXAQ attention output stays near exact attention."""
    p = exaq_params(1.0, 3)
    b, h, s, d = 2, 4, 128, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    got = ops.exaq_attention(q, k, v, p, d**-0.5, block_q=64, block_kv=64)
    exact = ref.mha_ref(q, k, v, d**-0.5)
    assert float(jnp.abs(got - exact).mean()) < 0.08


@pytest.mark.parametrize("b,h,hkv,sc,d", [(2, 4, 2, 128, 64), (1, 8, 2, 256, 32)])
def test_decode_kernel_full_cache_matches_flash_ref(b, h, hkv, sc, d):
    """With the cache full, decode == non-causal flash over the same blocks."""
    p = exaq_params(1.0, 2)
    q = jnp.asarray(RNG.normal(0, 1, (b, h, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, sc, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, sc, d)), jnp.float32)
    lens = jnp.full((b,), sc, jnp.int32)
    got = ops.decode_attention(q, k, v, lens, p, d**-0.5, block_kv=64)
    g = h // hkv
    kr, vr = jnp.repeat(k, g, axis=1), jnp.repeat(v, g, axis=1)
    want = ref.flash_exaq_attention_ref(q, kr, vr, p, d**-0.5, causal=False, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_kernel_partial_lens_close_to_global_grid():
    p = exaq_params(1.0, 2)
    b, h, hkv, sc, d = 2, 4, 2, 256, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, sc, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, sc, d)), jnp.float32)
    lens = jnp.asarray([100, 256], jnp.int32)
    got = ops.decode_attention(q, k, v, lens, p, d**-0.5, block_kv=64)
    want = ops.decode_attention(q, k, v, lens, p, d**-0.5, use_kernel=False)
    # online vs global grid: loose statistical agreement (DESIGN.md §2)
    assert float(jnp.abs(got - want).mean()) < 0.1


def test_chunked_softmax_long_rows():
    """Rows beyond MAX_FUSED_COLS take the two-pass path."""
    p = exaq_params(1.0, 2)
    x = jnp.asarray(RNG.normal(0, 1, (2, ops.MAX_FUSED_COLS + 256)), jnp.float32)
    y = ops.exaq_softmax(x, p)
    want = ref.exaq_softmax_ref(x, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("n,chunk", [(200, 64), (256, 64), (63, 64), (130, 32)])
def test_chunked_softmax_matches_ref_at_n_gt_chunk(n, chunk):
    """The chunked scan (global max pass + per-chunk quantize/histogram
    partials) is exact vs the one-shot reference for any n/chunk ratio."""
    p = exaq_params(1.2, 2)
    x = jnp.asarray(RNG.normal(0, 1.2, (5, n)), jnp.float32)
    got = ops.exaq_softmax_chunked(x, p, chunk=chunk)
    want = ref.exaq_softmax_ref(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_chunked_softmax_ragged_lens_and_leading_dims():
    p = exaq_params(1.0, 3)
    x = jnp.asarray(RNG.normal(0, 1, (2, 3, 150)), jnp.float32)
    lens = jnp.asarray([[1, 40, 150], [97, 64, 5]], jnp.int32)
    got = ops.exaq_softmax_chunked(x, p, lens=lens, chunk=32)
    want = ref.exaq_softmax_ref(x, p, lens=lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # masked tail carries no weight
    assert float(jnp.abs(got[0, 0, 1:]).max()) == 0.0
