"""Paper §3: analytical optimal clipping (Eq. 14 / Table 1 / Fig. 3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import clipping


def _numeric_mse(C, sigma, bits, mu=0.0):
    """Brute-force trapezoid integration of Eq. 14 (independent of the closed form)."""
    xs_in = np.linspace(C, 0, 4000)
    xs_lo = np.linspace(mu - 14 * sigma, C, 8000)
    def pdf(x):
        return np.exp(-0.5 * ((x - mu) / sigma) ** 2) / (sigma * np.sqrt(2 * np.pi))
    delta = -C / 2**bits
    quant = delta**2 / 12 * np.trapezoid(np.exp(2 * xs_in) * pdf(xs_in), xs_in)
    clip = np.trapezoid((np.exp(C) - np.exp(xs_lo)) ** 2 * pdf(xs_lo), xs_lo)
    return quant + clip


@pytest.mark.parametrize("sigma", [0.9, 1.5, 2.5, 3.4])
@pytest.mark.parametrize("bits", [2, 3])
def test_closed_form_matches_numeric_integration(sigma, bits):
    for C in (-1.0, -2.5, -5.0):
        got = clipping.exaq_mse(C, sigma, bits)
        want = _numeric_mse(C, sigma, bits)
        assert got == pytest.approx(want, rel=1e-3)


def _empirical_mse(C, sigma, bits, n=1000, trials=64, seed=0):
    rng = np.random.default_rng(seed)
    levels = 2**bits
    tot = 0.0
    for _ in range(trials):
        x = np.minimum(rng.normal(0, sigma, n), 0.0)
        delta = -C / levels
        codes = np.clip(np.floor((np.maximum(x, C) - C) / delta), 0, levels - 1)
        xq = C + (codes + 0.5) * delta
        tot += np.mean((np.exp(xq) - np.exp(x)) ** 2)
    return tot / trials


@pytest.mark.parametrize("bits", [2, 3])
def test_solver_near_optimal_empirically(bits):
    """Fig. 3 cross-check: the minimum is flat and the analytic model uses the
    linearized noise approximation, so we assert *near-optimality*: the
    empirical MSE at the analytic C* is within 25% of the empirical minimum."""
    for sigma in (1.0, 2.0):
        ana = clipping.optimal_clip_analytic(sigma, bits)
        sim = clipping.simulate_optimal_clip(sigma, bits, trials=48)
        m_at_ana = _empirical_mse(ana, sigma, bits)
        m_at_sim = _empirical_mse(sim, sigma, bits)
        # the linearized-noise model under-penalizes large Delta at 2-3 bits;
        # the gap is bounded and documented (DESIGN.md §1 / benchmarks)
        assert m_at_ana <= 1.6 * m_at_sim


def test_paper_table1_coefficients_exposed():
    assert clipping.PAPER_CLIP_COEFFS[2] == (-1.66, -1.85)
    assert clipping.PAPER_CLIP_COEFFS[3] == (-1.75, -2.06)
    r = clipping.get_clip_rule("paper", 2)
    assert r(1.0) == pytest.approx(-3.51)


def test_rederived_coefficients_stable():
    """Our Eq.-14 re-derivation (DESIGN.md §1): fit reproduces the shipped
    constants, and the M=2->M=3 deltas match the paper's deltas."""
    s2, i2 = clipping.fit_linear_rule(2, n=8)
    s3, i3 = clipping.fit_linear_rule(3, n=8)
    assert s2 == pytest.approx(clipping.REDERIVED_CLIP_COEFFS[2][0], abs=0.02)
    assert i2 == pytest.approx(clipping.REDERIVED_CLIP_COEFFS[2][1], abs=0.04)
    # paper deltas: slope -0.09, intercept -0.21
    assert (s3 - s2) == pytest.approx(-1.75 - -1.66, abs=0.03)
    assert (i3 - i2) == pytest.approx(-2.06 - -1.85, abs=0.08)


@settings(max_examples=20, deadline=None)
@given(sigma=st.floats(0.5, 4.0), bits=st.integers(2, 4))
def test_optimal_clip_properties(sigma, bits):
    c = clipping.optimal_clip_analytic(sigma, bits, grid=512, refine=24)
    assert c < 0
    # optimum: MSE at C* <= neighbours
    m0 = clipping.exaq_mse(c, sigma, bits)
    assert m0 <= clipping.exaq_mse(c * 1.15, sigma, bits) + 1e-12
    assert m0 <= clipping.exaq_mse(c * 0.85, sigma, bits) + 1e-12


@settings(max_examples=10, deadline=None)
@given(sigma=st.floats(0.8, 3.5))
def test_more_bits_clip_wider(sigma):
    """More bits -> lower quant error -> afford a more negative clip."""
    c2 = clipping.optimal_clip_analytic(sigma, 2, grid=512, refine=24)
    c3 = clipping.optimal_clip_analytic(sigma, 3, grid=512, refine=24)
    assert c3 < c2 + 1e-3


def test_naive_clip_rule():
    assert clipping.naive_clip_from_minmax(-8.0, 0.0) == -4.0
