"""launch/serve.py flag-combination validation (no devices, no model)."""

import os
import sys
from argparse import Namespace

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import validate_serve_args  # noqa: E402


def _args(**kw):
    base = dict(paged=False, fused=None, impl="exaq", kv_dtype="bf16", dp=1, tp=1,
                online=False, priority_classes=1, deadline_ms=0, max_inflight=0,
                spec_k=0, temperature=0.0)
    base.update(kw)
    return Namespace(**base)


def test_defaults_pass():
    validate_serve_args(_args())
    validate_serve_args(_args(paged=True, fused=True, kv_dtype="int8", dp=2, tp=2),
                        device_count=4)
    validate_serve_args(_args(paged=True, online=True, priority_classes=3,
                              deadline_ms=250, max_inflight=8))
    validate_serve_args(_args(paged=True, spec_k=4))


@pytest.mark.parametrize("kw,msg", [
    (dict(fused=True), "--paged"),
    (dict(fused=False), "--paged"),
    (dict(paged=True, fused=True, impl="exact"), "--impl exaq"),
    (dict(kv_dtype="int8"), "--paged"),
    (dict(dp=2), "--paged"),
    (dict(tp=2), "--paged"),
    (dict(dp=0), ">= 1"),
    (dict(tp=-1), ">= 1"),
    (dict(online=True), "--paged"),
    (dict(paged=True, online=True, dp=2), "--dp"),
    (dict(paged=True, online=True, priority_classes=0), ">= 1"),
    (dict(paged=True, online=True, deadline_ms=-1), ">= 0"),
    (dict(paged=True, online=True, max_inflight=-4), ">= 0"),
    (dict(paged=True, priority_classes=2), "--online"),
    (dict(paged=True, deadline_ms=100), "--online"),
    (dict(paged=True, max_inflight=4), "--online"),
    (dict(spec_k=4), "--paged"),
    (dict(paged=True, spec_k=-1), ">= 0"),
    (dict(paged=True, spec_k=4, temperature=0.8), "greedy-only"),
])
def test_rejections_name_the_fix(kw, msg):
    with pytest.raises(SystemExit, match=msg):
        validate_serve_args(_args(**kw))


def test_device_count_check():
    with pytest.raises(SystemExit, match="needs 8 devices"):
        validate_serve_args(_args(paged=True, dp=4, tp=2), device_count=4)
    validate_serve_args(_args(paged=True, dp=4, tp=2), device_count=8)
    # no device_count given -> the mesh builder checks at construction instead
    validate_serve_args(_args(paged=True, dp=64, tp=64))


def test_no_fused_flag_is_paged_only_but_impl_agnostic():
    validate_serve_args(_args(paged=True, fused=False, impl="exact"))
