"""Speculative decoding on the paged pool: parity, accept edges, faults.

The §12 contract under test, layer by layer:

  * Drafter layer — ``NgramDrafter`` suffix matching, the greedy accept
    rule's prefix semantics, the registry. Pure host, no jax.
  * Greedy parity — spec decode must emit BIT-IDENTICAL tokens to vanilla
    decode on the same trace for every pool format (fp32 / bf16 / int8 /
    int4) and for dp=2 fleets: the verify window writes KV through the same
    two-pass global-max histogram combine the sequential path uses, with
    scale seeding masked to the rows vanilla's first-writer rule would have
    used (``seed_first_row``), so acceptance is exact argmax equality, not
    within-tolerance. Accept-length edges are scripted with ``FnDrafter``:
    an oracle drafter (replays vanilla's own output) must accept everything;
    an always-wrong drafter must accept nothing — and both must still be
    bit-exact, because the correction token is the verify argmax.
  * Sharded parity — tp=2 pools and (dp=2, tp=2) fleets under virtual
    devices (subprocess: the device count must be set before jax
    initializes) reproduce the single-shard spec tokens exactly.
  * Fault paths — the regression layer for the drain-ordering hazard: a
    mid-verify preemption or ``PoolExhausted`` must release every draft
    branch block AND purge the branch's queued CoW fork copy, so a released
    -and-recycled block can never eat a stale copy (the same escape PR 4
    fixed for fork-destination scale resets). Verified through the full
    allocator audit plus directed refcount checks.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from bench_serving import PERIOD, TOK0
from repro.runtime.engine_core import EngineCore
from repro.runtime.faults import HostDeviceEmulator, audit_block_invariants
from repro.runtime.kv_pool import PoolExhausted
from repro.runtime.speculative import (
    FnDrafter,
    NgramDrafter,
    greedy_accept_length,
    make_drafter,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ------------------------------------------------------------- drafter layer


def test_greedy_accept_length_prefix_semantics():
    assert greedy_accept_length([], [9]) == 0
    assert greedy_accept_length([4, 5], [4, 5, 6]) == 2      # k = max, all in
    assert greedy_accept_length([4, 5], [7, 5, 6]) == 0      # k = 0, all out
    assert greedy_accept_length([4, 5, 6], [4, 9, 6, 1]) == 1  # stop at first miss
    assert greedy_accept_length(np.array([3]), np.array([3, 8])) == 1


def test_ngram_drafter_matches_periodic_pattern():
    ctx = [int(t) for t in np.arange(30) % PERIOD + TOK0]
    want = [int(t) for t in np.arange(30, 34) % PERIOD + TOK0]
    assert NgramDrafter().propose(ctx, 4) == want
    # no repeated suffix anywhere -> nothing to propose
    assert NgramDrafter().propose([1, 2, 3, 4, 5], 4) == []
    # order-1 fallback: last token seen once before, most recent occurrence
    assert NgramDrafter().propose([7, 1, 7, 2, 9, 7], 2) == [2, 9]


def test_make_drafter_registry():
    assert isinstance(make_drafter("ngram"), NgramDrafter)
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("oracle")


# ------------------------------------------------------------- greedy parity


def _pattern_prompts(rng, n):
    """Rotated in-distribution windows of the smoke model's trained pattern
    (same reasoning as the differential fuzzer: parity is exact regardless,
    but trained margins make the ngram drafter's accepts realistic)."""
    pattern = [int(t) for t in np.arange(48) % PERIOD + TOK0]
    out = []
    for _ in range(n):
        cut = int(rng.integers(0, 24))
        out.append(pattern[cut : cut + int(rng.integers(6, 20))])
    return out


def _run_engine(cfg, params, prompts, *, cache_dtype, spec_k, drafter=None,
                eos_id=None, num_blocks=None, max_new=14, audit=True):
    from repro.runtime.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_slots=3, max_seq=64, block_size=8,
                      prefill_chunk=16, eos_id=eos_id, seed=0, fused=True,
                      num_blocks=num_blocks, cache_dtype=cache_dtype,
                      spec_k=spec_k, drafter=drafter)
    uids = [eng.submit(p, max_new) for p in prompts]
    while eng.has_work():
        eng.step_chunk()
        if audit:
            audit_block_invariants(eng)
    res = eng.take_finished()
    return [res[u].tokens for u in uids], eng


@pytest.mark.parametrize("kv_dtype", ["fp32", "bf16", "int8", "int4"])
def test_spec_greedy_parity_all_pool_formats(smoke_model, test_seed, kv_dtype):
    """Property test: spec decode (k=4, ngram drafter) is bit-exact vs
    vanilla on a randomized trace for every pool format, with the allocator
    audit after every chunk; the drafter must actually be earning accepts
    (the trained pattern makes the ngram near-oracle) and spending fewer
    target-model launches per token."""
    from repro.runtime.serve import KV_DTYPES

    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    prompts = _pattern_prompts(rng, 5)
    dt = KV_DTYPES[kv_dtype]
    base, beng = _run_engine(cfg, params, prompts, cache_dtype=dt, spec_k=0)
    spec, seng = _run_engine(cfg, params, prompts, cache_dtype=dt, spec_k=4)
    assert spec == base, f"[seed {test_seed}] {kv_dtype}: spec diverged from vanilla"
    st = seng.stats
    assert st["spec_rounds"] > 0 and st["spec_accepted"] > 0
    assert st["spec_emitted"] == sum(len(t) for t in base) - len(prompts)
    # steps-per-token: every vanilla decode step serves the whole batch, a
    # spec round serves one slot — compare per-token launches conservatively
    assert st["spec_rounds"] < st["spec_emitted"], "speculation never batched tokens"


def test_spec_parity_with_eos_truncation(smoke_model, test_seed):
    """EOS landing mid-window: emissions past the hit are truncated exactly
    where vanilla would have stopped, and the finish reason matches."""
    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    prompts = _pattern_prompts(rng, 4)
    eos = TOK0 + 3  # appears in the trained pattern -> hit mid-generation
    base, _ = _run_engine(cfg, params, prompts, cache_dtype=np.float32,
                          spec_k=0, eos_id=eos)
    spec, seng = _run_engine(cfg, params, prompts, cache_dtype=np.float32,
                             spec_k=4, eos_id=eos)
    assert spec == base
    assert any(t and t[-1] == eos for t in base), "trace never hit EOS — dead test"


def test_spec_scripted_accept_edges(smoke_model, test_seed):
    """The k=max all-accepted and k=0 all-rejected edges, scripted with
    FnDrafter: an oracle replaying vanilla's own output accepts every draft;
    a drafter proposing guaranteed-wrong tokens accepts none. Both stay
    bit-exact — the correction token is the verify argmax either way."""
    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    prompts = _pattern_prompts(rng, 4)
    base, _ = _run_engine(cfg, params, prompts, cache_dtype=np.float32, spec_k=0)
    seqs = [list(p) + t for p, t in zip(prompts, base)]

    def continuation(ctx, k):
        for seq in seqs:
            if len(ctx) <= len(seq) and seq[: len(ctx)] == list(ctx):
                return seq[len(ctx) : len(ctx) + k]
        return []

    oracle, oeng = _run_engine(cfg, params, prompts, cache_dtype=np.float32,
                               spec_k=4, drafter=FnDrafter(continuation))
    assert oracle == base
    ost = oeng.stats
    assert ost["spec_drafted"] > 0 and ost["spec_accepted"] == ost["spec_drafted"]

    wrong = FnDrafter(lambda ctx, k: [(t + 1) % cfg.vocab_size
                                      for t in continuation(ctx, k)])
    rejected, reng = _run_engine(cfg, params, prompts, cache_dtype=np.float32,
                                 spec_k=4, drafter=wrong)
    assert rejected == base
    rst = reng.stats
    assert rst["spec_drafted"] > 0 and rst["spec_accepted"] == 0


def test_spec_parity_dp2_fleet(smoke_model, test_seed):
    """dp=2 replica fleets route requests by load, which greedy spec decode
    must not observe: fleet tokens == single-engine vanilla tokens."""
    from repro.runtime.engine import DataParallelEngine

    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    prompts = _pattern_prompts(rng, 5)
    base, _ = _run_engine(cfg, params, prompts, cache_dtype=np.float32, spec_k=0)
    fleet = DataParallelEngine(cfg, params, replicas=2, max_slots=3, max_seq=64,
                               block_size=8, prefill_chunk=16, eos_id=None,
                               seed=0, fused=True, cache_dtype=np.float32,
                               spec_k=4)
    uids = [fleet.submit(p, 14) for p in prompts]
    res = fleet.run()
    assert [res[u].tokens for u in uids] == base
    assert fleet.stats["spec_rounds"] > 0


def test_spec_sharded_parity_tp2_and_dp2tp2():
    """tp=2 pool sharding and a (dp=2, tp=2) fleet under 8 virtual devices:
    spec tokens must match the unsharded vanilla engine bit-exactly (the
    verify chunk runs the same shard_map'ed fused prefill as PR 5)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_replica_meshes
        from repro.models import build_model
        from repro.runtime.engine import DataParallelEngine, PagedEngine

        cfg = get_config("yi-6b").reduced(num_layers=2)
        cfg = cfg.with_quant(softmax_impl="exaq", bits=2)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), jnp.bfloat16)
        rng = np.random.default_rng(0)
        shared = rng.integers(0, cfg.vocab_size, 8)
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, n)])
                   for n in (9, 14, 11, 6)]

        def run_engine(eng):
            uids = [eng.submit(p, 8) for p in prompts]
            res = eng.run()
            return [res[u].tokens for u in uids]

        kw = dict(max_slots=2, max_seq=40, block_size=4, prefill_chunk=8,
                  fused=True, cache_dtype=jnp.int8, seed=0)
        base = run_engine(PagedEngine(cfg, params, **kw))
        mesh = make_replica_meshes(1, 2)[0]
        tp = run_engine(PagedEngine(cfg, params, mesh=mesh, spec_k=3, **kw))
        assert tp == base, (tp, base)
        fleet = DataParallelEngine(cfg, params, replicas=2,
                                   meshes=make_replica_meshes(2, 2),
                                   spec_k=3, **kw)
        got = run_engine(fleet)
        assert got == base, (got, base)
        assert fleet.stats["spec_rounds"] > 0
        print("SPEC_SHARDED_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SPEC_SHARDED_OK" in out.stdout


# ---------------------------------------------------------------- fault paths


def _core_with_decoding_slot(*, num_blocks, prompt_len=6, max_new=12,
                             block_size=4, quantized=True):
    """Host-only EngineCore driven to one decoding slot whose kv length sits
    mid-block (forces the read-fork path in plan_spec_round)."""
    rng = np.random.default_rng(0)
    core = EngineCore(max_slots=2, max_seq=32, block_size=block_size,
                      num_blocks=num_blocks, eos_id=None, quantized=quantized)
    emu = HostDeviceEmulator(rng, vocab=40, eos=None)
    core.submit(list(range(2, 2 + prompt_len)), max_new)
    while not core.num_active:
        emu.step_chunk(core, steps=1)
    core.take_pending_copies()
    core.take_fresh_scale_ids()
    slot = int(np.nonzero(core._active)[0][0])
    return core, slot


def test_mid_verify_preemption_releases_branches_and_purges_copies():
    """Regression for the fork-lifecycle drain hazard: a preemption landing
    between branch fork and verify commit must release EVERY branch block
    and purge the branch's queued CoW fork copy — a recycled destination
    must never eat the stale copy (PR 4's scale-reset escape, copy-queue
    edition)."""
    core, slot = _core_with_decoding_slot(num_blocks=16)
    plan = core.plan_spec_round(slot, [5, 6, 7])
    br = plan.branch
    assert br.forked and core.pending_copies, "setup must queue a fork copy"
    branch_blocks = list(br.table)
    assert any(d in branch_blocks for _, d in core.pending_copies)
    core._preempt(slot)
    audit_block_invariants(core)
    assert not core._branches
    for b in branch_blocks:
        assert core.pool.refcount[b] == 0, f"branch block {b} leaked"
    assert not any(d in branch_blocks for _, d in core.pending_copies), (
        "stale fork copy survived the preemption — it could land in a "
        "recycled block"
    )
    # the freed ids are allocatable again without inheriting anything: drain
    # the allocator and confirm every branch block comes back clean
    got = set()
    while True:
        try:
            got.add(core.pool.alloc())
        except PoolExhausted:
            break
    assert set(branch_blocks) <= got


def test_mid_verify_cancel_releases_branches():
    """Client disconnect between fork and commit: the cancel path (via the
    paged ``_finish``) must abort the branch exactly like a preemption."""
    core, slot = _core_with_decoding_slot(num_blocks=16)
    plan = core.plan_spec_round(slot, [5, 6])
    branch_blocks = list(plan.branch.table)
    assert core.cancel(core._slots[slot].uid)
    audit_block_invariants(core)
    assert not core._branches
    assert all(core.pool.refcount[b] == 0 for b in branch_blocks)
    assert not any(d in branch_blocks for _, d in core.pending_copies)


def test_plan_pool_exhausted_rolls_back_partial_branch():
    """PoolExhausted midway through a multi-block branch allocation: the
    plan must release what it grabbed and deregister nothing — the audit
    plus a before/after refcount snapshot catch a partial leak."""
    core, slot = _core_with_decoding_slot(num_blocks=16)
    held = []
    while True:  # pin everything but one block: k=3 needs two (fork + growth)
        try:
            held.append(core.pool.alloc())
        except PoolExhausted:
            break
    core.pool.release(held.pop())
    before = np.asarray(core.pool.refcount).copy()
    with pytest.raises(PoolExhausted):
        core.plan_spec_round(slot, [5, 6, 7])
    np.testing.assert_array_equal(np.asarray(core.pool.refcount), before)
    assert not core._branches and not core.pending_copies
    audit_block_invariants(core, held=held)


def test_engine_spec_under_pool_pressure_stays_bit_exact(smoke_model, test_seed):
    """End-to-end: a pool too small for the full working set forces the
    degrade-to-k=0 retry and preempt-and-recompute inside spec rounds; the
    final greedy tokens must still match a fully-provisioned vanilla run
    (recompute is bit-exact), with the allocator audit after every chunk."""
    cfg, params = smoke_model
    rng = np.random.default_rng(test_seed)
    prompts = _pattern_prompts(rng, 4)
    base, _ = _run_engine(cfg, params, prompts, cache_dtype=np.float32, spec_k=0)
    # 3 slots x 64/8 = 24 blocks fully provisioned; squeeze to force pressure
    spec, seng = _run_engine(cfg, params, prompts, cache_dtype=np.float32,
                             spec_k=4, num_blocks=13)
    assert spec == base, f"[seed {test_seed}] pool pressure broke spec parity"
    assert seng.stats["spec_rounds"] > 0


def test_spec_sole_slot_exhaustion_raises_non_retryable(smoke_model):
    """A sole active request whose next round cannot fund even one block
    must surface the same honest non-retryable PoolExhausted as the vanilla
    reserve path — not corrupt KV or spin."""
    cfg, params = smoke_model
    from repro.runtime.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_slots=1, max_seq=64, block_size=8,
                      prefill_chunk=16, eos_id=None, seed=0, fused=True,
                      cache_dtype=np.float32, spec_k=4)
    eng.submit([int(t) for t in np.arange(10) % PERIOD + TOK0], 40)
    eng.step_chunk()  # prefill + first spec rounds
    held = []
    while True:
        try:
            held.append(eng.pool.alloc())
        except PoolExhausted:
            break
    with pytest.raises(PoolExhausted, match="only active request") as ei:
        while eng.has_work():
            eng.step_chunk()
    assert not ei.value.retryable


def test_spec_rejects_non_greedy_sampling(smoke_model):
    from repro.runtime.engine import PagedEngine
    from repro.runtime.sampling import SamplingParams

    cfg, params = smoke_model
    eng = PagedEngine(cfg, params, max_slots=2, max_seq=64, block_size=8,
                      prefill_chunk=16, seed=0, cache_dtype=np.float32, spec_k=4)
    with pytest.raises(ValueError, match="greedy-only"):
        eng.submit([3, 4, 5], 8, SamplingParams(temperature=0.7))
