"""Mamba2 SSD and MoE routing correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import mamba2
from repro.models.moe import moe_ffn, init_moe

RNG = np.random.default_rng(7)


def _naive_ssd(xs, dt, a, Bm, Cm):
    """Step-by-step recurrence oracle: h_t = e^{a_t} h + dt_t B_t (x) x_t."""
    b, S, nh, hd = xs.shape
    ds = Bm.shape[-1]
    h = np.zeros((b, nh, hd, ds))
    ys = np.zeros((b, S, nh, hd))
    for t in range(S):
        decay = np.exp(a[:, t])  # (b, nh)
        h = decay[:, :, None, None] * h + np.einsum("bn,bs,bnh->bnhs", dt[:, t], Bm[:, t], xs[:, t])
        ys[:, t] = np.einsum("bs,bnhs->bnh", Cm[:, t], h)
    return ys, h


@pytest.mark.parametrize("chunk", [4, 8, 32])
@pytest.mark.parametrize("S", [16, 31, 64])
def test_ssd_chunked_matches_recurrence(chunk, S):
    b, nh, hd, ds = 2, 3, 4, 5
    xs = RNG.normal(0, 1, (b, S, nh, hd))
    dt = RNG.uniform(0.01, 0.2, (b, S, nh))
    a = -RNG.uniform(0.01, 0.5, (b, S, nh))
    Bm = RNG.normal(0, 1, (b, S, ds))
    Cm = RNG.normal(0, 1, (b, S, ds))
    want_y, want_h = _naive_ssd(xs, dt, a, Bm, Cm)
    got_y, got_h = mamba2.ssd_scan(
        jnp.asarray(xs, jnp.float32), jnp.asarray(dt, jnp.float32), jnp.asarray(a, jnp.float32),
        jnp.asarray(Bm, jnp.float32), jnp.asarray(Cm, jnp.float32),
        jnp.zeros((b, nh, hd, ds), jnp.float32), chunk,
    )
    np.testing.assert_allclose(np.asarray(got_y), want_y, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), want_h, atol=1e-4)


def test_mamba_prefill_state_matches_decode_continuation():
    """State from prefill over t[:n] + one decode step == prefill over t[:n+1]."""
    cfg = get_config("mamba2-1.3b").reduced()
    p = mamba2.init_mamba(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jnp.asarray(RNG.normal(0, 0.5, (B, S, cfg.d_model)), jnp.float32)
    full, cache_full = mamba2.mamba_forward(p, x, cfg, mode="prefill")
    part, cache = mamba2.mamba_forward(p, x[:, : S - 1], cfg, mode="prefill")
    step, cache2 = mamba2.mamba_forward(p, x[:, S - 1 :], cfg, mode="decode", cache=cache)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, -1]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(cache2["ssm"]), np.asarray(cache_full["ssm"]), atol=1e-3)


def _moe_cfg():
    return get_config("deepseek-moe-16b").reduced()


def test_moe_routing_conservation():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(0, 1, (2, 32, cfg.d_model)), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert 0.0 <= float(aux["moe_dropped"]) < 0.5
    assert float(aux["moe_lb"]) > 0


def test_moe_capacity_drops_when_overloaded():
    """Adversarial routing (all tokens to one expert) must drop beyond capacity."""
    from dataclasses import replace

    cfg0 = _moe_cfg()
    cfg = replace(cfg0, moe=replace(cfg0.moe, capacity_factor=0.5))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    p = dict(p)
    router = np.zeros((cfg.d_model, cfg.moe.num_experts), np.float32)
    router[:, 0] = 10.0  # everyone wants expert 0
    p["router"] = jnp.asarray(router)
    x = jnp.abs(jnp.asarray(RNG.normal(0, 1, (1, 32, cfg.d_model)), jnp.float32))
    out, aux = moe_ffn(p, x, cfg)
    assert float(aux["moe_dropped"]) > 0.1


def test_moe_matches_dense_when_one_expert():
    """With E=1, k=1, generous capacity, MoE == that expert's MLP on all tokens."""
    from dataclasses import replace

    from repro.configs.base import MoEConfig
    from repro.models.layers import gated_mlp

    cfg0 = _moe_cfg()
    cfg = replace(cfg0, moe=MoEConfig(num_experts=1, top_k=1, num_shared=0, d_expert=64,
                                      capacity_factor=4.0, group_size=16))
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(0, 1, (2, 16, cfg.d_model)), jnp.float32)
    out, _ = moe_ffn(p, x, cfg)
    dense = gated_mlp({"wi": p["moe_wi"][0], "wo": p["moe_wo"][0]}, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), atol=1e-4)
