"""Per-architecture smoke tests (required deliverable f): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs,
plus prefill->decode parity against the train-mode forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_model
from repro.optim.adamw import AdamW
from repro.runtime.train import init_train_state, make_train_step

ALL_ARCHS = [a for a in list_configs() if a != "llama1-7b"]


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.frontend == "vlm":
        b["vision_embeds"] = jnp.asarray(rng.normal(0, 1, (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "audio":
        b["audio_embeds"] = jnp.asarray(rng.normal(0, 1, (B, cfg.enc_seq, cfg.frontend_dim)), jnp.float32)
    return b


def test_all_ten_assigned_archs_present():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = m.forward_train(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    opt = AdamW(lr=1e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    state2, metrics = step(state, _batch(cfg))
    assert int(state2["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), state["params"], state2["params"])
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b", "zamba2-2.7b", "whisper-large-v3"])
def test_prefill_decode_parity(arch):
    """prefill(t[:n]) + decode(t[n]) logits == forward_train(t)[:, n] — the
    serving path is consistent with the training forward (exact softmax to
    isolate cache correctness from quantization semantics)."""
    cfg = get_config(arch).reduced().with_quant(softmax_impl="exact")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S, seed=3)
    full_logits, _ = m.forward_train(params, batch)

    n = S - 1
    pre = {k: (v[:, :n] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    cache = m.init_cache(B, S + 4, dtype=jnp.float32)
    lg, cache = m.prefill(params, pre, cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, n - 1]), atol=2e-2)
    lg2, cache = m.decode_step(params, batch["tokens"][:, n : n + 1], cache)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full_logits[:, n]), atol=2e-2)


@pytest.mark.parametrize("arch", ["internlm2-1.8b"])
def test_exaq_serving_close_to_exact(arch):
    """EXAQ INT2 logits track exact-softmax logits — after calibration
    (paper §5.1.1: the clip must come from observed sigma; the uncalibrated
    default is visibly worse, which is itself part of the paper's claim)."""
    base = get_config(arch).reduced()
    m_exact = build_model(base.with_quant(softmax_impl="exact"))
    cfg_q = base.with_quant(softmax_impl="exaq", bits=2)
    m_exaq = build_model(cfg_q)
    params = m_exact.init(jax.random.PRNGKey(2))
    batch = _batch(base, 2, 16, seed=5)
    le, _ = m_exact.forward_train(params, batch)

    def corr_of(qstate):
        lq, _ = m_exaq.forward_train(params, batch, qstate)
        a, b = np.asarray(le).ravel(), np.asarray(lq).ravel()
        return np.corrcoef(a, b)[0, 1]

    corr_default = corr_of(None)
    stats = m_exact.calibrate(params, batch)
    qs = m_exaq.qstate_from_stats(stats)
    corr_calibrated = corr_of(qs)
    # paper Table 2: INT2 ~2% degradation, INT3 near-lossless
    assert corr_calibrated > 0.98, (corr_default, corr_calibrated)
    assert corr_calibrated >= corr_default - 1e-3
    m3 = build_model(base.with_quant(softmax_impl="exaq", bits=3))
    l3, _ = m3.forward_train(params, batch, m3.qstate_from_stats(stats))
    le, _ = m_exact.forward_train(params, batch)
    corr3 = np.corrcoef(np.asarray(le).ravel(), np.asarray(l3).ravel())[0, 1]
    assert corr3 > 0.995 and corr3 > corr_calibrated


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (deliverable f)."""
    c = get_config("qwen3-32b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        64, 5120, 64, 8, 25600, 151936,
    ) and c.qk_norm
    c = get_config("deepseek-moe-16b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared) == (64, 6, 2)
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.moe.num_experts, c.moe.top_k) == (16, 2)
    c = get_config("mamba2-1.3b")
    assert c.ssm_state == 128 and c.num_heads == 0
    c = get_config("zamba2-2.7b")
    assert c.ssm_state == 64 and c.hybrid_period == 6
    c = get_config("whisper-large-v3")
    assert c.enc_layers == 32 and c.num_layers == 32 and c.d_model == 1280
    c = get_config("internvl2-1b")
    assert c.frontend == "vlm" and c.num_kv_heads == 2
    c = get_config("stablelm-12b")
    assert c.d_ff == 13824 and c.vocab_size == 100352
