"""Sharded paged-engine parity on a virtual 8-device mesh (subprocess:
device count must be set before jax initializes).

The §9 contract under test: greedy decode is bit-exact across every mesh
layout — single shard, tp=2 tensor-parallel pool, and (dp=2, tp=2) replica
fleets — for fp32, bf16 AND int8 pools. Parameters stay replicated and the
shard_map around the fused kernels splits heads without reassociating any
accumulation, so tokens (not just logits-within-tolerance) must agree.
Also: a 'model' axis that does not divide the kv heads must fall back to
the replicated single-shard path, not crash.
"""

import os
import subprocess
import sys
import textwrap


ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_replica_meshes
        from repro.models import build_model
        from repro.runtime.engine import DataParallelEngine, PagedEngine

        cfg = get_config("yi-6b").reduced(num_layers=2)
        cfg = cfg.with_quant(softmax_impl="exaq", bits=2)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), jnp.bfloat16)
        rng = np.random.default_rng(0)
        shared = rng.integers(0, cfg.vocab_size, 8)
        prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, n)])
                   for n in (9, 14, 11, 6)]
        GEN = 8

        def run_engine(eng):
            uids = [eng.submit(p, GEN) for p in prompts]
            res = eng.run()
            return [res[u].tokens for u in uids]

        def engine_kw(dtype):
            return dict(max_slots=2, max_seq=40, block_size=4, prefill_chunk=8,
                        fused=True, cache_dtype=dtype, seed=0)
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_tp2_pool_matches_single_shard_all_dtypes():
    """tp=2 shards the pool's kv-head axis (and q heads inside the kernel
    dispatch); greedy tokens must be bit-identical to the single-shard
    engine for all three pool dtypes."""
    print(_run("""
        mesh = make_replica_meshes(1, 2)[0]
        for dtype in (jnp.float32, jnp.bfloat16, jnp.int8):
            base = run_engine(PagedEngine(cfg, params, **engine_kw(dtype)))
            eng = PagedEngine(cfg, params, mesh=mesh, **engine_kw(dtype))
            # the mesh path must actually engage: local shards hold half the heads
            shard = eng._pool["k"].addressable_shards[0].data
            assert shard.shape[2] == cfg.num_kv_heads // 2, shard.shape
            if dtype == jnp.int8:
                sshard = eng._pool["k_scale"].addressable_shards[0].data
                assert sshard.shape[2] == cfg.num_kv_heads // 2, sshard.shape
            got = run_engine(eng)
            assert got == base, (str(dtype), got, base)
            print("TP2_OK", jnp.dtype(dtype).name)
    """))


def test_dp2_tp2_fleet_matches_single_shard_all_dtypes():
    """dp=2 replicas x tp=2 shards behind the shared admission queue: the
    fleet's greedy tokens must match the single unsharded engine bit-exactly
    (dispatch changes batch composition, which greedy decode ignores)."""
    print(_run("""
        for dtype in (jnp.float32, jnp.bfloat16, jnp.int8):
            base = run_engine(PagedEngine(cfg, params, **engine_kw(dtype)))
            fleet = DataParallelEngine(cfg, params, replicas=2,
                                       meshes=make_replica_meshes(2, 2),
                                       **engine_kw(dtype))
            got = run_engine(fleet)
            assert got == base, (str(dtype), got, base)
            # both replicas actually served requests
            per = fleet.per_replica_stats
            assert all(s["prefills"] > 0 for s in per), per
            assert fleet.stats["prefills"] == len(prompts)
            print("DP2TP2_OK", jnp.dtype(dtype).name)
    """))


def test_tp_indivisible_kv_heads_falls_back_replicated():
    """tp=4 over 2 kv heads: block_pool_spec replicates and ops._tp_mesh
    declines, so the engine runs the single-shard path on a 4-device mesh
    and still matches exactly."""
    print(_run("""
        base = run_engine(PagedEngine(cfg, params, **engine_kw(jnp.bfloat16)))
        mesh = make_replica_meshes(1, 4)[0]
        eng = PagedEngine(cfg, params, mesh=mesh, **engine_kw(jnp.bfloat16))
        shard = eng._pool["k"].addressable_shards[0].data
        assert shard.shape[2] == cfg.num_kv_heads  # replicated fallback
        got = run_engine(eng)
        assert got == base
        print("TP_FALLBACK_OK")
    """))


def test_make_replica_meshes_validates():
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            from repro.launch.mesh import make_replica_meshes
            meshes = make_replica_meshes(2, 4)
            assert len(meshes) == 2
            devs = [d for m in meshes for d in m.devices.flat]
            assert len(set(devs)) == 8  # disjoint slices cover all devices
            for m in meshes:
                assert m.shape == {"data": 1, "model": 4}
            try:
                make_replica_meshes(3, 4)
                raise SystemExit("expected ValueError")
            except ValueError as e:
                assert "12 devices" in str(e), e
            try:
                make_replica_meshes(0, 2)
                raise SystemExit("expected ValueError")
            except ValueError:
                pass
            print("MESHES_OK")
        """)],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")),
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "MESHES_OK" in out.stdout
