"""Calibration collector (paper §5.1.1) and the trip-counted HLO cost model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import Calibrator, SiteStats
from repro.utils import hlo_cost


# ----------------------------------------------------------- calibration

def test_welford_matches_numpy():
    rng = np.random.default_rng(0)
    st = SiteStats()
    chunks = [rng.normal(2.0, 1.5, 997) for _ in range(5)]
    for c in chunks:
        st.update(c)
    allv = np.concatenate(chunks)
    assert st.std == pytest.approx(allv.std(), rel=1e-6)
    assert st.min == pytest.approx(allv.min())
    assert st.count == allv.size


def test_calibrator_observe_and_params():
    rng = np.random.default_rng(1)
    cal = Calibrator()
    for _ in range(4):
        x = jnp.asarray(rng.normal(0, 1.8, (4, 128)), jnp.float32)
        cal.observe("attn/0", x)
    sigma = cal.sigma("attn/0")
    assert 1.0 < sigma < 2.5
    p = cal.exaq_params("attn/0", 2, rule="paper")
    assert p.clip == pytest.approx(-1.66 * sigma - 1.85, rel=1e-6)
    pn = cal.naive_params("attn/0", 2)
    assert pn.clip < 0


def test_calibrator_mask_excludes_invalid():
    cal = Calibrator()
    x = jnp.zeros((2, 8), jnp.float32)
    x = x.at[:, 4:].set(-1e9)  # junk that a mask must exclude
    mask = jnp.arange(8)[None, :] < 4
    cal.observe("s", x, where=jnp.broadcast_to(mask, x.shape))
    assert cal.sigma("s") == pytest.approx(0.0, abs=1e-6)


def test_calibrator_json_roundtrip():
    cal = Calibrator()
    cal.observe("a", jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 64)), jnp.float32))
    cal2 = Calibrator.from_json(cal.to_json())
    assert cal2.sigma("a") == pytest.approx(cal.sigma("a"))


# ------------------------------------------------------------- hlo cost

def _flops_of(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return hlo_cost.analyze(c.as_text(), 1)


def test_trip_counted_scan_flops():
    x = jnp.zeros((128, 128), jnp.float32)
    w = jnp.zeros((6, 128, 128), jnp.float32)

    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    cs = _flops_of(scanned, x, w)
    assert cs.flops == pytest.approx(6 * 2 * 128**3)
    # XLA's own analysis counts the body once — the bug this module fixes
    # (xla_cost_analysis shims the dict-vs-list-of-dicts return across JAX versions)
    xla = hlo_cost.xla_cost_analysis(jax.jit(scanned).lower(x, w).compile())["flops"]
    # rel=1e-4: XLA adds a handful of scalar flops for the loop counter
    assert xla == pytest.approx(2 * 128**3, rel=1e-4)


def test_nested_scan_flops():
    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((4, 64, 64), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            return jax.lax.scan(lambda cc, wi: (cc @ wi, None), c, w)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    assert _flops_of(nested, x, w).flops == pytest.approx(3 * 4 * 2 * 64**3)


def test_bytes_model_add():
    x = jnp.zeros((256, 256), jnp.float32)
    cs = _flops_of(lambda a, b: a + b, x, x)
    assert cs.bytes == pytest.approx(3 * 256 * 256 * 4)  # 2 reads + 1 write


def test_dynamic_slice_charged_at_slice_granularity():
    big = jnp.zeros((64, 1024), jnp.float32)

    def f(big):
        def body(c, i):
            return c + jax.lax.dynamic_slice_in_dim(big, i, 1, 0)[0], None
        return jax.lax.scan(body, jnp.zeros(1024), jnp.arange(64))[0]

    cs = _flops_of(f, big)
    # 64 iterations x ~3 row-sized touches (slice r/w + add) << full-array x 64
    assert cs.bytes < 64 * 1024 * 4 * 8
    assert cs.bytes > 64 * 1024 * 4  # but not free either


def test_collective_parse_on_sharded_module():
    # single-device module has no collectives
    x = jnp.zeros((128, 128), jnp.float32)
    cs = _flops_of(lambda a: a @ a, x)
    assert cs.collective_total == 0.0
