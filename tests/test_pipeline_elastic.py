"""Pipeline parallelism (GPipe over 'pod') and elastic re-mesh restore —
subprocess tests (virtual device count must precede jax init)."""

import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True,
                         env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_gpipe_pipeline_matches_sequential():
    print(_run("""
        from repro.runtime.pipeline_parallel import pipeline_forward, stack_stages
        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        L, D = 8, 16
        W = jnp.asarray(rng.normal(0, 0.3, (L, D, D)), jnp.float32)

        def layer(w, h):
            return jnp.tanh(h @ w)

        def stage(ws, h):   # ws: (L/P, D, D)
            def body(hh, w):
                return layer(w, hh), None
            return jax.lax.scan(body, h, ws)[0]

        x = jnp.asarray(rng.normal(0, 1, (6, 4, D)), jnp.float32)  # 6 microbatches
        # sequential reference
        ref = x
        def seq_body(hh, w):
            return layer(w, hh), None
        ref = jax.lax.scan(seq_body, x.reshape(-1, D), W)[0].reshape(x.shape)

        got = pipeline_forward(stage, stack_stages(W, 4), x, mesh, axis="pod")
        err = float(jnp.abs(got - ref).max())
        assert err < 1e-5, err
        print("GPIPE_OK", err)
    """))


def test_elastic_restore_on_different_mesh(tmp_path):
    """Checkpoint saved from a (4,2) mesh restores onto a (2,4) mesh and
    training continues with identical losses (mesh-agnostic checkpoints)."""
    print(_run(f"""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpointing.manager import CheckpointManager
        from repro.configs import get_config
        from repro.optim.adamw import AdamW
        from repro.runtime import sharding as shd, train as train_rt
        from repro.data.pipeline import SyntheticLMData

        cfg = get_config("internlm2-1.8b").reduced(num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        opt = AdamW(lr=1e-3)
        data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=0)
        state = train_rt.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        step = train_rt.make_train_step(cfg, opt, compute_dtype=jnp.float32)

        def run_on(mesh_shape, state, batches):
            mesh = jax.make_mesh(mesh_shape, ("data", "model"))
            rules = shd.make_activation_rules(cfg, mesh)
            losses = []
            with mesh, shd.activation_rules(mesh, rules):
                st_sh = train_rt.state_shardings(cfg, mesh, jax.eval_shape(lambda: state))
                state = jax.device_put(jax.device_get(state), st_sh)
                f = jax.jit(step, in_shardings=(st_sh, None), out_shardings=(st_sh, None))
                for b in batches:
                    state, m = f(state, b)
                    losses.append(float(m["loss"]))
            return jax.device_get(state), losses

        batches = [{{k: jnp.asarray(v) for k, v in data.next_batch().items()}} for _ in range(4)]
        # reference: all 4 steps on (4,2)
        s_ref, l_ref = run_on((4, 2), state, batches)
        # elastic: 2 steps on (4,2), checkpoint, restore onto (2,4), 2 more
        s_a, l_a = run_on((4, 2), state, batches[:2])
        mgr = CheckpointManager(r"{tmp_path}", async_write=False)
        mgr.save(2, s_a, extra_meta={{"step": 2}})
        s_b, meta = mgr.restore(jax.eval_shape(lambda: s_a))
        s_b, l_b = run_on((2, 4), s_b, batches[2:])
        diff = [abs(x - y) for x, y in zip(l_ref, l_a + l_b)]
        assert max(diff) < 2e-4, (l_ref, l_a + l_b)
        print("ELASTIC_OK", max(diff))
    """))
