import os
import sys
import zlib

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

# ----------------------------------------------------------- seed discipline
#
# Every randomized test derives its seed from the PYTEST_SEED env var (default
# 0) XOR a stable hash of the test's nodeid, so (a) the whole suite is
# reproducible run-to-run, (b) each test draws an independent stream, and
# (c) CI can diversify coverage by exporting a different PYTEST_SEED per
# scheduled run. The fixture prints the derivation; pytest shows captured
# output only for failing tests, so the repro line surfaces exactly when
# it is needed.

PYTEST_SEED = int(os.environ.get("PYTEST_SEED", "0"))

try:  # optional dep: the property suite degrades to a seeded fallback driver
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("default", max_examples=50, deadline=None)
    _hyp_settings.register_profile(
        "long", max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "500")),
        deadline=None,
    )
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:
    pass


def derive_seed(nodeid: str, base: int = PYTEST_SEED) -> int:
    """Per-test seed: crc32 of the nodeid XOR the suite-wide PYTEST_SEED."""
    return zlib.crc32(nodeid.encode()) ^ (base & 0xFFFFFFFF)


@pytest.fixture
def test_seed(request):
    """Reproducible per-test seed (int). Prints the repro recipe so a failing
    test's report carries everything needed to replay it."""
    seed = derive_seed(request.node.nodeid)
    print(f"[seed] PYTEST_SEED={PYTEST_SEED} nodeid={request.node.nodeid!r} "
          f"-> derived seed {seed} (replay: PYTEST_SEED={PYTEST_SEED} pytest "
          f"'{request.node.nodeid}')")
    return seed


@pytest.fixture
def rng(test_seed):
    """numpy Generator seeded per-test from PYTEST_SEED (see ``test_seed``)."""
    import numpy as np

    return np.random.default_rng(test_seed)


@pytest.fixture(scope="session")
def smoke_model():
    """Trained 2-layer smoke model (the bench's recipe, briefly overfit on a
    periodic stream so greedy margins are confident, not argmax noise).
    Session-scoped and shared by the differential-fuzz, SLA-scheduler, and
    chaos suites — training dominates their cost."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
    from bench_serving import make_smoke_model

    cfg, params, loss = make_smoke_model("yi-6b", train_steps=60)
    assert loss < 0.2, f"smoke model failed to overfit (loss {loss})"
    return cfg, params


@pytest.fixture
def quantize_pool():
    """fp pool -> (int8 codes, per-(block, kv-head) scales) the way the write
    path would store it (DESIGN.md §6): scale = margin * amax / 127. The ONE
    test-side encoding of the write-path contract, shared by the paged-decode
    and paged-prefill kernel suites."""
    import jax.numpy as jnp

    from repro.kernels.ops import KV_QMAX, KV_SCALE_MARGIN, kv_quantize

    def _quantize(pk, pv):
        def q(pool):
            amax = jnp.max(jnp.abs(pool), axis=(2, 3))  # (N, KV)
            scale = KV_SCALE_MARGIN * amax / KV_QMAX
            return kv_quantize(pool, scale[:, :, None, None]), scale

        qk, ks = q(pk.astype(jnp.float32))
        qv, vs = q(pv.astype(jnp.float32))
        return qk, qv, ks, vs

    return _quantize


@pytest.fixture
def quantize_pool_int4():
    """fp pool -> (packed uint8 nibbles, fp32 block scales, uint8 sub codes)
    the way the int4 write path would store it (DESIGN.md §10). The test-side
    twin of the scatter's first-write seeding: block scale = margin * amax /
    7, sub code = ceil(15 * margin * amax_sub / (7 * block_scale)) in [1, 15]
    (0 where the sub-block is all-zero). Null block 0 is zeroed everywhere —
    payload, scale, sub codes — matching a fresh pool's reserved sink."""
    import jax.numpy as jnp

    from repro.kernels.ops import (
        kv4_effective_scale,
        kv4_num_sub,
        kv4_quantize,
        kv4_sub_block,
        kv4_write_block_scales,
        kv4_write_sub_scales,
    )

    def _quantize(pk, pv):
        def q(pool):  # (N, KV, bs, D) fp -> packed + scales
            N, KV, bs, D = pool.shape
            sub_bs = kv4_sub_block(bs)
            n_sub = kv4_num_sub(bs)
            pool = pool.astype(jnp.float32)
            amax = jnp.max(jnp.abs(pool), axis=(2, 3))  # (N, KV)
            scale = kv4_write_block_scales(amax, jnp.zeros_like(amax))
            amax_sub = jnp.max(
                jnp.abs(pool.reshape(N, KV, n_sub, sub_bs, D)), axis=(3, 4)
            )  # (N, KV, n_sub)
            codes = kv4_write_sub_scales(amax_sub, scale, jnp.zeros(amax_sub.shape, jnp.uint8))
            per_tok = jnp.repeat(kv4_effective_scale(scale, codes), sub_bs, axis=-1)
            packed = kv4_quantize(pool, per_tok)
            # block 0 is the reserved null sink: unset grid, zero payload
            return packed.at[0].set(0), scale.at[0].set(0.0), codes.at[0].set(0)

        qk, ks, ksub = q(pk)
        qv, vs, vsub = q(pv)
        return qk, qv, ks, vs, ksub, vsub

    return _quantize
