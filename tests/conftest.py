import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture
def quantize_pool():
    """fp pool -> (int8 codes, per-(block, kv-head) scales) the way the write
    path would store it (DESIGN.md §6): scale = margin * amax / 127. The ONE
    test-side encoding of the write-path contract, shared by the paged-decode
    and paged-prefill kernel suites."""
    import jax.numpy as jnp

    from repro.kernels.ops import KV_QMAX, KV_SCALE_MARGIN, kv_quantize

    def _quantize(pk, pv):
        def q(pool):
            amax = jnp.max(jnp.abs(pool), axis=(2, 3))  # (N, KV)
            scale = KV_SCALE_MARGIN * amax / KV_QMAX
            return kv_quantize(pool, scale[:, :, None, None]), scale

        qk, ks = q(pk.astype(jnp.float32))
        qv, vs = q(pv.astype(jnp.float32))
        return qk, qv, ks, vs

    return _quantize
