"""Training loop, optimizer, checkpoint/restart, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.optim.adamw import AdamW, apply_updates
from repro.optim.compression import dequantize_int8, ef_compress, init_error_state, quantize_int8
from repro.optim.schedule import cosine_with_warmup
from repro.runtime.train import cross_entropy, init_train_state, make_train_step


def _tiny_cfg():
    return get_config("internlm2-1.8b").reduced(num_layers=2, d_model=64, d_ff=128, vocab_size=128)


def _batches(cfg, n, B=4, S=32, seed=0):
    data = SyntheticLMData(cfg.vocab_size, S, B, seed=seed)
    return [
        {k: jnp.asarray(v) for k, v in data.next_batch().items()} for _ in range(n)
    ]


def test_loss_decreases():
    cfg = _tiny_cfg()
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for b in _batches(cfg, 30):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]


def test_microbatch_equivalence():
    """Grad accumulation: 1 vs 4 microbatches give identical updates
    (fp32 compute isolates the mechanism from bf16 reduction-order noise)."""
    cfg = _tiny_cfg()
    opt = AdamW(lr=1e-3, clip_norm=0.0, weight_decay=0.0)
    s0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    b = _batches(cfg, 1, B=8)[0]
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1, compute_dtype=jnp.float32))(s0, b)
    s4, m4 = jax.jit(make_train_step(cfg, opt, microbatches=4, compute_dtype=jnp.float32))(s0, b)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, c: float(jnp.abs(a - c).max()), s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 1e-4


def test_cross_entropy_matches_reference():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 2, (2, 8, 32)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32)
    got = float(cross_entropy(logits, labels))
    lse = np.log(np.exp(np.asarray(logits)).sum(-1))
    ll = np.take_along_axis(np.asarray(logits), np.asarray(labels)[..., None], -1)[..., 0]
    assert got == pytest.approx(float((lse - ll).mean()), rel=1e-5)


def test_adamw_quadratic_convergence():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}  # d/dw w^2
        upd, st, _ = opt.update(g, st, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    st = opt.init(params)
    _, _, m = opt.update({"w": jnp.asarray([100.0, 0.0, 0.0])}, st, params)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_schedule_warmup_and_decay():
    lr = cosine_with_warmup(1e-3, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=0.01)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}, "step": jnp.asarray(7)}
    for s in (1, 2, 3):
        mgr.save(s, state, extra_meta={"data": {"seed": 0, "step": s}})
    assert mgr.latest_step() == 3
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2  # keep-k GC
    restored, meta = mgr.restore(jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), np.arange(6).reshape(2, 3))
    assert meta["data"]["step"] == 3


def test_checkpoint_async_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    state = {"x": jnp.ones((128, 128))}
    mgr.save(1, state)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_training_resume_is_deterministic(tmp_path):
    """Crash/restart: resume from checkpoint reproduces the uninterrupted run
    exactly (params + data stream)."""
    cfg = _tiny_cfg()
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=1)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))

    # uninterrupted: 6 steps
    s_ref, d_ref = state, SyntheticLMData(cfg.vocab_size, 32, 4, seed=1)
    for _ in range(6):
        s_ref, _ = step(s_ref, {k: jnp.asarray(v) for k, v in d_ref.next_batch().items()})

    # interrupted at step 3
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    s = state
    for _ in range(3):
        s, _ = step(s, {k: jnp.asarray(v) for k, v in data.next_batch().items()})
    mgr.save(3, s, extra_meta={"data": data.state_dict()})
    del s, data

    # "new process": restore and continue
    template = jax.eval_shape(lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    s2, meta = mgr.restore(template)
    data2 = SyntheticLMData(cfg.vocab_size, 32, 4, seed=1)
    data2.load_state_dict(meta["data"])
    for _ in range(3):
        s2, _ = step(s2, {k: jnp.asarray(v) for k, v in data2.next_batch().items()})

    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), s_ref["params"], s2["params"])
    assert max(jax.tree.leaves(diff)) < 1e-6


# ------------------------------------------------------------------- data

def test_data_deterministic_and_restartable():
    d1 = SyntheticLMData(100, 16, 2, seed=5)
    b1 = [d1.next_batch() for _ in range(3)]
    d2 = SyntheticLMData(100, 16, 2, seed=5)
    d2.load_state_dict({"seed": 5, "step": 2})
    np.testing.assert_array_equal(b1[2]["tokens"], d2.next_batch()["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticLMData(100, 16, 2, seed=5)
    b = d.next_batch()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_data_has_learnable_structure():
    """Cluster-conditional emissions: bigram MI should beat random tokens."""
    d = SyntheticLMData(64, 512, 4, seed=0)
    t = d.next_batch()["tokens"]
    # same-cluster spans repeat tokens more than uniform sampling would
    rep = (t[:, 1:] == t[:, :-1]).mean()
    assert rep > 2.0 / 64


# ------------------------------------------------------------ compression

def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (1024,)), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x)).max()
    assert err <= float(scale) / 2 + 1e-7


def test_error_feedback_reduces_bias():
    """EF: quantization error injected back — averaged compressed grads converge
    to the true mean (bias shrinks vs no-EF)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)
    err = jnp.zeros_like(g)
    acc = np.zeros(512)
    n = 50
    for _ in range(n):
        q, scale, err = ef_compress(g, err)
        acc += np.asarray(dequantize_int8(q, scale))
    bias_ef = np.abs(acc / n - np.asarray(g)).mean()
    q0, s0 = quantize_int8(g)
    bias_plain = np.abs(np.asarray(dequantize_int8(q0, s0)) - np.asarray(g)).mean()
    assert bias_ef <= bias_plain * 0.5


def test_init_error_state_shapes():
    p = {"a": jnp.zeros((2, 3)), "b": jnp.zeros(5)}
    e = init_error_state(p)
    assert e["a"].shape == (2, 3) and e["b"].shape == (5,)
