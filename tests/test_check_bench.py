"""Comparison rules of the CI bench-regression gate (tools/check_bench.py):
within-run timing ratios (machine-portable), directional tolerances,
exact-or-better floors for parity/hit-rate/ratio metrics,
missing-gated-metric failures, and new-metric notes."""

import importlib.util
from pathlib import Path

_spec = importlib.util.spec_from_file_location(
    "check_bench", Path(__file__).resolve().parent.parent / "tools" / "check_bench.py"
)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _report(tok_per_s=100.0, agree=1.0, parity=True, step_ms=5.0, reduction=4.0,
            gather_ms=2.0, exact_tok=125.0, dp_parity=True, dp_hit=0.75, dp_occ=2.5,
            p99_ttft=28.0, p99_itl=6.0, overload_done=7, shed_retryable=True,
            spec_parity=True, spec_apv=3.1, spec_spt_x=2.0):
    return {
        "serving": {
            "impls": {
                "exact": {"tok_per_s": exact_tok},
                "exaq-int2": {"tok_per_s": tok_per_s, "agreement_vs_exact": agree},
            },
            "paged": {"exaq": {"greedy_parity_vs_slot": parity, "prefix_hit_rate": 0.8}},
            "kv_dtype": {"agreement_int8_vs_fp32": 1.0, "pool_shrink_x": 3.9},
            "dp": {
                "replicas": 2,
                "greedy_parity_vs_single": dp_parity,
                "aggregate": {"prefix_hit_rate": dp_hit, "mean_occupancy": dp_occ},
                "per_replica": [{"requests": 6}, {"requests": 6}],
            },
            "bursty": {
                "requests": 12,
                "p50_ttft_steps": 14.0, "p99_ttft_steps": p99_ttft,
                "p50_itl_steps": 1.0, "p99_itl_steps": p99_itl,
                "overload": {"max_inflight": 4, "completed": overload_done,
                             "shed": 5, "all_shed_retryable": shed_retryable},
            },
            "spec": {
                "spec_k": 4, "drafter": "ngram",
                "greedy_parity_vs_vanilla": spec_parity,
                "rounds": 50, "drafted": 200, "accepted": 155, "tokens": 205,
                "accepted_per_verify": spec_apv,
                "steps_per_token_reduction_x": spec_spt_x,
            },
        },
        "micro": {
            "fused_step_ms": step_ms,
            "gather_step_ms": gather_ms,
            "bytes_reduction_x": reduction,
            "prefill": {
                "fused_chunk_ms": step_ms,
                "gather_chunk_ms": gather_ms,
                "bytes_reduction_x": reduction,
            },
        },
    }


def test_identical_run_passes():
    fails, notes = check_bench.compare(_report(), _report(), 0.2)
    assert fails == []
    # the only notes are the informational latency ratios, never gate chatter
    assert all(n.startswith("informational") for n in notes)


def test_improvements_always_pass():
    fails, _ = check_bench.compare(
        _report(), _report(tok_per_s=250.0, step_ms=1.0, reduction=9.0), 0.2
    )
    assert fails == []


def test_machine_speed_shift_passes():
    """A uniformly 3x slower runner moves every absolute timing but no
    within-run ratio — the gate must not care what machine it runs on."""
    slow = _report(tok_per_s=100.0 / 3, exact_tok=125.0 / 3, step_ms=15.0, gather_ms=6.0)
    fails, _ = check_bench.compare(_report(), slow, 0.2)
    assert fails == []


def test_relative_throughput_dip_within_tolerance_passes_beyond_fails():
    fails, _ = check_bench.compare(_report(), _report(tok_per_s=85.0), 0.2)
    assert fails == []
    fails, _ = check_bench.compare(_report(), _report(tok_per_s=79.0), 0.2)
    assert any("tok_per_s_rel_exact" in f for f in fails)


def test_latency_ratios_are_informational_never_gated():
    """Interpret-mode wall-clock ratios (fused/gather step + chunk) are
    reported as notes but must not fail the gate however far they move."""
    fails, notes = check_bench.compare(_report(), _report(step_ms=500.0), 0.2)
    assert fails == []
    assert sum("over_gather" in n for n in notes) == 2  # decode step + prefill chunk
    assert all("not gated" in n for n in notes if "over_gather" in n)
    # the compat flag changes nothing
    fails, _ = check_bench.compare(_report(), _report(step_ms=500.0), 0.2, latency_tolerance=2.0)
    assert fails == []


def test_informational_latency_does_not_mask_throughput_gate():
    fails, _ = check_bench.compare(_report(), _report(step_ms=500.0, tok_per_s=79.0), 0.2)
    assert not any("over_gather" in f for f in fails)
    assert any("tok_per_s_rel_exact" in f for f in fails)


def test_dp_fleet_metrics_are_gated():
    fails, _ = check_bench.compare(_report(), _report(dp_parity=False), 0.2)
    assert any("dp.greedy_parity_vs_single" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(dp_hit=0.6), 0.2)
    assert any("dp.aggregate.prefix_hit_rate" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(dp_occ=2.0), 0.2)
    assert any("dp.aggregate.mean_occupancy" in f for f in fails)
    # improvements and ungated per-replica details pass
    fails, _ = check_bench.compare(_report(), _report(dp_hit=0.9, dp_occ=3.0), 0.2)
    assert fails == []


def test_parity_and_ratio_metrics_are_exact_or_better():
    fails, _ = check_bench.compare(_report(), _report(parity=False), 0.2)
    assert any("greedy_parity_vs_slot" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(agree=0.999), 0.2)
    assert any("agreement_vs_exact" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(reduction=3.5), 0.2)
    assert sum("bytes_reduction_x" in f for f in fails) == 2


def test_bursty_latency_ceilings_are_exact_or_lower():
    """Tick-clocked TTFT/ITL percentiles are deterministic: any rise fails,
    any improvement passes — direction is the mirror image of "floor"."""
    fails, _ = check_bench.compare(_report(), _report(p99_ttft=29.0), 0.2)
    assert any("p99_ttft_steps" in f and "rose above" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(p99_itl=7.0), 0.2)
    assert any("p99_itl_steps" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(p99_ttft=20.0, p99_itl=2.0), 0.2)
    assert fails == []


def test_spec_decode_metrics_are_gated():
    """Speculative-decoding gates: vanilla parity must stay truthy, and the
    deterministic speedup counters (accepted drafts per verify round,
    target-model steps-per-token reduction) are exact-or-better floors."""
    fails, _ = check_bench.compare(_report(), _report(spec_parity=False), 0.2)
    assert any("spec.greedy_parity_vs_vanilla" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(spec_apv=2.9), 0.2)
    assert any("spec.accepted_per_verify" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(spec_spt_x=1.4), 0.2)
    assert any("spec.steps_per_token_reduction_x" in f and "regressed below" in f for f in fails)
    # a better drafter round-trips: improvements never trip the floors
    fails, _ = check_bench.compare(_report(), _report(spec_apv=4.0, spec_spt_x=3.0), 0.2)
    assert fails == []


def test_overload_arm_is_gated():
    fails, _ = check_bench.compare(_report(), _report(overload_done=6), 0.2)
    assert any("overload.completed" in f for f in fails)
    fails, _ = check_bench.compare(_report(), _report(shed_retryable=False), 0.2)
    assert any("all_shed_retryable" in f for f in fails)


def test_missing_gated_metric_fails_new_metric_notes():
    fresh = _report()
    del fresh["micro"]["prefill"]["bytes_reduction_x"]
    fresh["serving"]["paged"]["exact"] = {"prefix_hit_rate": 0.9}  # new gated metric
    fails, notes = check_bench.compare(_report(), fresh, 0.2)
    assert any("missing from the fresh run" in f for f in fails)
    assert any("paged.exact.prefix_hit_rate" in n and "--update" in n for n in notes)


def test_committed_baseline_matches_gate_schema():
    """The committed BENCH_baseline.json actually exercises the gate: it
    holds both halves and every SPEC rule matches at least one metric
    (after the within-run ratios are derived)."""
    import json

    baseline = json.loads((Path(check_bench.ROOT) / "BENCH_baseline.json").read_text())
    assert set(baseline) == {"serving", "micro"}
    flat = check_bench.derive(check_bench.flatten(baseline))
    for pattern, _ in check_bench.SPEC:
        assert any(
            check_bench.fnmatch.fnmatch(p, pattern) for p in flat
        ), f"no baseline metric matches gate rule {pattern!r}"
