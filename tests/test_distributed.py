"""Distributed correctness on a small virtual mesh (subprocess: device count
must be set before jax initializes).

  * sharded train step == single-device train step (bitwise-ish)
  * sharded EXAQ serve decode == single-device decode
  * compressed_psum (shard_map) == plain mean within EF error bounds
  * tiny-config dry-run (lower+compile+cost extraction) end-to-end
"""

import os
import subprocess
import sys
import textwrap


ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.optim.adamw import AdamW
        from repro.runtime import sharding as shd, train as train_rt
        from repro.data.pipeline import SyntheticLMData

        cfg = get_config("internlm2-1.8b").reduced(num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        opt = AdamW(lr=1e-3)
        state = train_rt.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=0)
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        # fp32 compute isolates the sharding mechanism from bf16 Adam
        # sign-flips on near-zero gradients (2*lr excursions)
        step = train_rt.make_train_step(cfg, opt, compute_dtype=jnp.float32)

        s1, m1 = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = shd.make_activation_rules(cfg, mesh)
        with mesh, shd.activation_rules(mesh, rules):
            st_sh = train_rt.state_shardings(cfg, mesh, jax.eval_shape(lambda: state))
            b_sh = train_rt.batch_shardings(mesh, jax.eval_shape(lambda: batch))
            state_p = jax.device_put(state, st_sh)
            batch_p = jax.device_put(batch, b_sh)
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))(state_p, batch_p)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), s1["params"], jax.device_get(s2["params"]))
        md = max(jax.tree.leaves(d))
        assert md < 1e-4, md
        print("SHARDED_TRAIN_OK", float(m1["loss"]))
    """))


def test_sharded_exaq_decode_matches_single_device():
    print(_run("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.runtime import serve as serve_rt, sharding as shd

        cfg = get_config("yi-6b").reduced(num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0), jnp.bfloat16)
        B, S = 8, 16
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        cache = serve_rt.init_cache(cfg, B, S + 4)
        pre, dec = serve_rt.make_serve_fns(cfg)
        lg1, cache1 = jax.jit(pre)(params, {"tokens": toks}, cache)
        nxt1, cache1, logits1 = jax.jit(dec)(params, jnp.zeros((B, 1), jnp.int32), cache1)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = shd.make_activation_rules(cfg, mesh)
        with mesh, shd.activation_rules(mesh, rules):
            p_sh = shd.tree_shardings(jax.eval_shape(lambda: params), cfg, mesh, mode="serve")
            c_sh = serve_rt.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache))
            tok_sh = NamedSharding(mesh, P(("data",), None))
            params_p = jax.device_put(params, p_sh)
            cache_p = jax.device_put(cache, c_sh)
            lg2, cache2 = jax.jit(pre, in_shardings=(p_sh, {"tokens": tok_sh}, c_sh), out_shardings=(None, c_sh))(
                params_p, {"tokens": jax.device_put(toks, tok_sh)}, cache_p)
            nxt2, cache2, logits2 = jax.jit(dec, in_shardings=(p_sh, tok_sh, c_sh), out_shardings=(tok_sh, c_sh, None))(
                params_p, jax.device_put(jnp.zeros((B, 1), jnp.int32), tok_sh), cache2)

        a, b = np.asarray(logits1, np.float32), np.asarray(jax.device_get(logits2), np.float32)
        assert np.abs(a - b).max() < 0.15, np.abs(a - b).max()   # bf16 + collective reassoc
        agree = (np.asarray(nxt1) == np.asarray(jax.device_get(nxt2))).mean()
        assert agree >= 0.8, agree
        print("SHARDED_DECODE_OK")
    """))


def test_compressed_psum_shard_map():
    print(_run("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compression import compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1, (8, 256)), jnp.float32)   # one row per device
        err = jnp.zeros_like(g)

        @partial(shard_map, mesh=mesh, in_specs=(P("data", None), P("data", None)),
                 out_specs=(P("data", None), P("data", None)))
        def sync(gi, ei):
            m, e2 = compressed_psum(gi[0], ei[0], "data")
            return m[None], e2[None]

        mean, err2 = sync(g, err)
        true_mean = np.asarray(g).mean(0)
        got = np.asarray(mean)[0]
        rel = np.abs(got - true_mean).max() / (np.abs(true_mean).max() + 1e-9)
        assert rel < 0.15, rel
        print("COMPRESSED_PSUM_OK", rel)
    """))


def test_tiny_dryrun_end_to_end():
    """dryrun machinery on a reduced config + 8-device mesh: lower, compile,
    trip-counted costs, collective extraction."""
    print(_run("""
        import repro.launch.dryrun as dr
        from repro.configs import get_config
        from repro.optim.adamw import AdamW
        from repro.runtime import sharding as shd, train as train_rt
        from repro.utils import hlo_cost

        cfg = get_config("yi-6b").reduced(num_layers=2, d_model=64, d_ff=128, vocab_size=256)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = AdamW(lr=1e-3)
        rules = shd.make_activation_rules(cfg, mesh)
        with mesh, shd.activation_rules(mesh, rules):
            state_struct = jax.eval_shape(lambda k: train_rt.init_train_state(cfg, opt, k),
                                          jax.ShapeDtypeStruct((2,), jnp.uint32))
            st_sh = train_rt.state_shardings(cfg, mesh, state_struct)
            specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
            b_sh = train_rt.batch_shardings(mesh, specs)
            step = train_rt.make_train_step(cfg, opt)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None)).lower(state_struct, specs)
            compiled = lowered.compile()
        txt = compiled.as_text()
        cs = hlo_cost.analyze(txt, 8)
        assert cs.flops > 0 and cs.bytes > 0
        assert cs.collective_total > 0  # grad sync must appear
        print("TINY_DRYRUN_OK flops=%.3g coll=%.3g" % (cs.flops, cs.collective_total))
    """))
